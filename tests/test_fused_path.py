"""Device-resident fast path: fused identify numerics vs the unfused
oracle, transfer-tax accounting, and end-to-end pipeline equivalence."""
import numpy as np
import pytest

from repro.core import facerec
from repro.core.events import EventLog
from repro.core.pipeline import StreamingPipeline
from repro.data.video import VideoStream


@pytest.fixture(scope="module")
def models():
    emb = facerec.Embedder()
    rng = np.random.default_rng(0)
    thumbs = rng.uniform(0, 255, (6, facerec.THUMB, facerec.THUMB, 3)) \
        .astype(np.float32)
    gal = {f"p{i}": e for i, e in enumerate(emb.embed_batch(thumbs))}
    return emb, facerec.Classifier(gal)


def _oracle(frames, centers, emb, clf):
    """The unfused chain: crop -> device resize -> embed -> classify."""
    thumbs_per = facerec.crop_thumbnails_batch(frames, centers)
    flat = [t for ts in thumbs_per for t in ts]
    if not flat:
        return []
    return clf.identify_batch(emb.embed_batch(np.stack(flat)))


def _frames_with_faces(n, seed=3):
    vs = VideoStream(seed=seed)
    frames, centers = [], []
    while sum(len(c) for c in centers) < n:
        f = vs.next_frame().pixels
        c = facerec.detect_faces(f)
        frames.append(f)
        centers.append(c)
    return frames, centers


@pytest.mark.parametrize("n_faces", [1, 3, 8])
def test_fused_matches_unfused_oracle(models, n_faces):
    """Fold numerics: fused == crop+resize+embed+identify within 1e-4,
    including ragged (non-pow2) batches that hit the padding path."""
    emb, clf = models
    frames, centers = _frames_with_faces(n_faces)
    # trim to exactly n_faces detections so each case is a ragged batch
    total = 0
    for i, c in enumerate(centers):
        keep = min(len(c), n_faces - total)
        centers[i] = c[:keep]
        total += keep
    want = _oracle(frames, centers, emb, clf)
    got = facerec.identify_fused_batch(frames, centers, emb, clf)
    flat = [p for ps in got for p in ps]
    assert len(flat) == len(want) == n_faces
    for (n1, s1), (n2, s2) in zip(want, flat):
        assert n1 == n2
        assert s1 == pytest.approx(s2, abs=1e-4)


def test_fused_empty_and_single(models):
    emb, clf = models
    fused = facerec.FusedIdentifier(emb, clf)
    frames = [VideoStream(seed=1).next_frame().pixels]
    assert fused.identify_batch(frames, [[]]) == [[]]
    # B=1 degenerates through the same padded path
    out = fused.identify_batch(frames, [[(60, 100)]])
    assert len(out[0]) == 1
    name, score = out[0][0]
    assert name in clf.names and -1.0 <= score <= 1.0 + 1e-6


def test_fused_grouping_matches_centers(models):
    emb, clf = models
    frames, centers = _frames_with_faces(5)
    out = facerec.FusedIdentifier(emb, clf).identify_batch(frames, centers)
    assert [len(o) for o in out] == [len(c) for c in centers]


def test_transfer_event_accounting():
    log = EventLog()
    log.log_transfer(0, "h2d", 1000, "embed")
    log.log_transfer(0, "d2h", 24, "embed")
    log.log_transfer(1, "h2d", 500, "identify_fused")
    tb = log.transfer_bytes()
    assert (tb["h2d"], tb["d2h"], tb["total"]) == (1500, 24, 1524)
    assert log.transfer_bytes(boundary="embed")["total"] == 1024
    tax = log.ai_tax(ai_stages=set())
    assert tax["transfer_bytes"]["total"] == 1524
    assert "transfer_fraction" in tax


@pytest.fixture(scope="module")
def pipe_results():
    kw = dict(n_frames=24, seed=0, batch_size=4, batch_timeout_ms=100.0,
              n_identify_workers=2)
    return {fast: StreamingPipeline(fast_path=fast, **kw).run()
            for fast in (False, True)}


def test_pipeline_fast_path_equivalent_results(pipe_results):
    slow, fast = pipe_results[False], pipe_results[True]
    assert (fast.detected, fast.ground_truth, fast.matched) == \
        (slow.detected, slow.ground_truth, slow.matched)
    ids = lambda r: sorted((rid, name) for rid, name, _ in r.identities)
    assert ids(fast) == ids(slow)


def test_pipeline_fast_path_cuts_face_transfer_bytes_4x(pipe_results):
    """The acceptance bar: >=4x fewer boundary bytes per identified face."""
    def face_bytes(r):
        return sum(e.payload_bytes for e in r.log.events
                   if e.meta.get("kind") == "transfer"
                   and e.meta.get("boundary") in
                   ("crop_resize", "embed", "identify_fused"))
    slow, fast = pipe_results[False], pipe_results[True]
    assert fast.detected > 0
    per_slow = face_bytes(slow) / slow.detected
    per_fast = face_bytes(fast) / fast.detected
    assert per_slow >= 4 * per_fast, (per_slow, per_fast)


def test_pipeline_transfer_split_in_tax(pipe_results):
    tax = pipe_results[True].ai_tax()
    assert tax["transfer_bytes"]["total"] > 0
    assert 0.0 <= tax["transfer_fraction"] <= tax["tax_fraction"] + 1e-9
    # uint8 ingest satellite: broker frame payloads are uint8-sized
    waits = [e for e in pipe_results[True].log.events
             if e.stage == "wait_frames"]
    assert waits and all(e.payload_bytes == 108 * 192 * 3 for e in waits)


def test_pipeline_fast_path_batch1_and_ragged_flush():
    r = StreamingPipeline(n_frames=12, seed=0, batch_size=1,
                          fast_path=True).run()
    assert len(r.identities) == r.detected
    r2 = StreamingPipeline(n_frames=12, seed=0, batch_size=64,
                           batch_timeout_ms=2.0, fast_path=True).run()
    assert len(r2.identities) == r2.detected    # linger flush, ragged B
