"""Multi-replica serving cluster: scheduler, load generators, admission,
tail-latency SLOs — and the acceptance gate: live cluster, DES, and
closed-form queueing agree on the destabilizing acceleration S.
"""
from dataclasses import replace

import pytest

from repro.cluster import (
    ClusterSpec, ConsumerGroup, OpenLoopLoadGen, ServingCluster, TailSLO,
)
from repro.cluster.crossval import DES_TOL, LIVE_TOL, des_knee, live_knee
from repro.cluster.metrics import LatencyStats, percentile
from repro.core.broker import BrokerConfig
from repro.core.simulator import FaceRecWorkload


# ---- consumer-group scheduler ----------------------------------------------

def test_assignment_partitions_disjoint_and_complete():
    g = ConsumerGroup(n_partitions=13)
    for m in ("a", "b", "c", "d", "e"):
        g.join(m)
        table = g.table()
        owned = [p for parts in table.values() for p in parts]
        # max one consumer per partition, nothing orphaned
        assert sorted(owned) == list(range(13))
        assert len(owned) == len(set(owned))
    # near-even spread
    sizes = [len(p) for p in g.table().values()]
    assert max(sizes) - min(sizes) <= 1


def test_rebalance_on_join_and_leave_bumps_generation():
    g = ConsumerGroup(n_partitions=4)
    a0 = g.join("a")
    assert a0.partitions == (0, 1, 2, 3)
    gen0 = g.generation
    g.join("b")
    assert g.generation > gen0
    assert len(g.assignment("a").partitions) == 2
    g.leave("a")
    assert g.assignment("b").partitions == (0, 1, 2, 3)
    assert g.assignment("a").partitions == ()
    assert g.owner_of(2) == "b"


# ---- load generators --------------------------------------------------------

def test_open_loop_schedule_deterministic_and_rate_matched():
    a = OpenLoopLoadGen(4, period_s=0.05, process="poisson", seed=3)
    b = OpenLoopLoadGen(4, period_s=0.05, process="poisson", seed=3)
    c = OpenLoopLoadGen(4, period_s=0.05, process="poisson", seed=4)
    assert a.schedule(0, 10.0) == b.schedule(0, 10.0)     # seeded: identical
    assert a.schedule(0, 10.0) != c.schedule(0, 10.0)     # seed-sensitive
    assert a.schedule(0, 10.0) != a.schedule(1, 10.0)     # per-producer streams
    n = len(a.schedule(0, 10.0))
    assert 10.0 / 0.05 * 0.6 < n < 10.0 / 0.05 * 1.4      # ~rate-matched
    periodic = OpenLoopLoadGen(1, period_s=0.1, seed=0).schedule(0, 1.0)
    gaps = [b_ - a_ for a_, b_ in zip(periodic, periodic[1:])]
    assert all(abs(gap - 0.1) < 1e-9 for gap in gaps)


def test_metrics_percentiles():
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 0.50) == 50.0
    assert percentile(xs, 0.99) == 99.0
    st = LatencyStats.from_samples(xs)
    assert st.n == 100 and st.p50 == 50.0 and st.max == 100.0
    # EventLog speaks the same nearest-rank convention
    from repro.core.events import EventLog
    log = EventLog()
    for rid, dur in enumerate(xs):
        log.log(rid, "stage", 0.0, dur)
    ps = log.percentiles((0.5, 0.99))
    assert ps[0.5] == 50.0 and ps[0.99] == log.tail(0.99) == 99.0


# ---- live cluster runs ------------------------------------------------------

def _small_spec(**kw):
    kw.setdefault("sim_time", 3.0)
    kw.setdefault("warmup", 1.0)
    kw.setdefault("speedup", 4.0)
    return ClusterSpec(**kw)


def test_cluster_stable_run_completes_and_reports():
    slo = TailSLO(p99_s=3.0, max_drop_fraction=0.0)
    res = ServingCluster(_small_spec(), slo=slo).run()
    assert res.produced > 100
    assert res.completed > 0.8 * res.produced
    assert not res.diverged
    assert res.latency.p50 <= res.latency.p95 <= res.latency.p99
    assert res.slo.ok, res.slo.violations
    # wait + identify flow through the same EventLog accounting as the
    # single-replica pipeline
    tax = res.ai_tax()
    assert 0.0 < tax["ai_fraction"] < 1.0
    assert "wait" in tax["per_stage"]
    # measured broker utilization tracks the closed-form rho
    rho = res.predicted_rho["broker_storage_write"]
    assert abs(res.utilization["broker_storage_write"] - rho) < 0.25 * rho + 0.05


def test_cluster_rebalances_on_replica_add_remove():
    spec = _small_spec(n_replicas=2, n_partitions=4, n_producers=1,
                       fetch_max_wait_s=0.05)
    cl = ServingCluster(spec)
    cl.start()
    base = cl.group.rebalances
    name = cl.add_replica()
    assert cl.group.rebalances > base
    assert len(cl.group.assignment(name).partitions) >= 1
    cl.remove_replica(name)
    assert cl.group.assignment(name).partitions == ()
    # surviving replicas own everything again
    owned = sorted(p for parts in cl.group.table().values() for p in parts)
    assert owned == list(range(4))
    for t in cl._feeder_threads:
        t.join()
    for t in cl._replica_threads:
        t.join()
    cl.topic.join()
    res = cl._result()
    assert res.completed > 0.8 * res.produced
    assert not res.diverged


def test_admission_drop_policy_sheds_load_and_logs_rejects():
    # consumer-starved on purpose: 1 slow replica, tiny in-flight bound
    spec = _small_spec(n_replicas=1, speedup=0.35, admission="drop",
                       partition_capacity=4, fetch_max_wait_s=0.05)
    res = ServingCluster(spec).run()
    assert res.dropped > 0
    assert res.drop_fraction > 0.05
    rejects = [e for e in res.log.events if e.stage == "reject"]
    assert len(rejects) == res.dropped
    # admitted traffic stays bounded: backlog can't exceed the bound
    assert res.backlog <= spec.partition_capacity * spec.partitions + 8
    slo = TailSLO(max_drop_fraction=0.01).check(res.latency,
                                                res.drop_fraction)
    assert not slo.ok


def test_admission_block_policy_bounds_inflight_via_backpressure():
    # same starved shape as the drop test, but blocking: nothing is
    # shed, the bound holds exactly, pressure surfaces as producer lag
    spec = _small_spec(n_replicas=1, speedup=0.5, admission="block",
                       partition_capacity=6, fetch_max_wait_s=0.05)
    res = ServingCluster(spec).run()
    assert res.dropped == 0
    assert res.backlog <= spec.partition_capacity
    assert res.producer_lag_mean > spec.period_s


def test_closed_loop_saturates_instead_of_diverging():
    # far beyond the open-loop knee: closed loop self-throttles
    spec = _small_spec(loop="closed", n_clients=6, speedup=16.0,
                      fetch_max_wait_s=0.02)
    res = ServingCluster(spec).run()
    assert not res.diverged
    assert res.completed > 0.9 * res.produced
    # population bound: never more in flight than clients
    assert res.backlog <= spec.n_clients


@pytest.mark.slow
def test_real_service_mode_runs_the_pipeline_identify_stage():
    """service="real": replicas serve actual crops through the SAME
    facerec.build_identify_stack device program as StreamingPipeline
    (jit buckets pre-warmed so compiles don't read as divergence)."""
    spec = _small_spec(service="real", n_replicas=2, n_producers=1,
                       fetch_max_wait_s=0.05)
    res = ServingCluster(spec).run()
    assert res.completed > 0.8 * res.produced
    assert not res.diverged
    tax = res.ai_tax()
    assert 0.0 < tax["ai_fraction"] < 1.0


# ---- the acceptance gate: measured vs modeled knee --------------------------

@pytest.mark.slow
@pytest.mark.parametrize("drives,replicas", [(1, 8), (2, 10)])
def test_knee_agreement_live_des_closed_form(drives, replicas):
    """Live cluster, DES, and closed form locate the same destabilizing
    S (documented tolerances: DES_TOL/LIVE_TOL in repro.cluster.crossval)
    for two (replicas, drives) configurations."""
    spec = ClusterSpec(bk=BrokerConfig(drives_per_broker=drives),
                       n_replicas=replicas, sim_time=6.0, warmup=1.5)
    closed = spec.closed_form_knee()
    des = des_knee(spec, iters=5)
    assert abs(des - closed) / closed <= DES_TOL, (des, closed)
    live = live_knee(spec, iters=3)
    if abs(live - closed) / closed > LIVE_TOL:
        # the live knee rides a real clock: one retry guards against a
        # transiently loaded box (persistent disagreement still fails)
        live = live_knee(spec, iters=3)
    assert abs(live - closed) / closed <= LIVE_TOL, (live, closed)


@pytest.mark.slow
def test_live_cluster_brackets_the_closed_form_knee():
    """Direct bracket (no bisection): clearly below the analytic knee
    the live cluster is stable, clearly above it diverges."""
    spec = ClusterSpec(sim_time=5.0, warmup=1.5)
    knee = spec.closed_form_knee()
    stable = ServingCluster(replace(spec, speedup=0.65 * knee)).run()
    assert not stable.diverged, stable.inflight_growth
    sat = ServingCluster(replace(spec, speedup=1.4 * knee)).run()
    assert sat.diverged
    # and the saturated run's tail is visibly worse
    assert sat.latency.p99 > 2 * stable.latency.p99


# ---- provisioning from measurements ----------------------------------------

@pytest.mark.slow
def test_measured_knees_reproduce_paper_provisioning():
    """DES-measured knees drive the Tables 3/4 provisioning choice to
    the same design the paper reached (4 drives for 32x)."""
    from repro.core import tco
    knees = {}
    for d in (3, 4):
        spec = ClusterSpec(bk=BrokerConfig(drives_per_broker=d))
        knees[d] = des_knee(spec, iters=5)
    d = tco.provision_drives(32.0, knees, tolerance=0.05)
    assert d == 4
    comp = tco.measured_comparison(32.0, knees, tolerance=0.05)
    paper = tco.paper_comparison(support_32x=True)
    assert (comp.homogeneous.equipment_cost
            == paper.homogeneous.equipment_cost)
    assert comp.saving_fraction >= 0.15


# ---- determinism ------------------------------------------------------------

def test_des_repeat_run_determinism():
    """Same seed -> bit-identical SimResult; the RNG is threaded through
    ClusterSim (no module-level randomness anywhere on the sim path)."""
    from repro.core.simulator import ClusterSim

    def once(seed):
        wl = FaceRecWorkload(face_dist="empirical", faces_per_frame=0.64)
        return ClusterSim(wl, BrokerConfig(), speedup=4.0, scale=0.02,
                          sim_time=10, warmup=2, seed=seed).run()

    a, b, c = once(7), once(7), once(8)
    assert a.to_dict() == b.to_dict()
    assert c.to_dict() != a.to_dict()     # seed actually flows


def test_batcher_bounded_first_wait():
    """Batcher.next_batch(max_wait=...) hands control back on an idle
    queue (empty list) instead of parking the consumer forever."""
    import queue

    from repro.core.batching import Batcher
    q: queue.Queue = queue.Queue()
    b = Batcher(q, batch_size=4, timeout_s=0.01, stop=None)
    assert b.next_batch(max_wait=0.01) == []
    q.put(1)
    q.put(2)
    assert b.next_batch(max_wait=0.01) == [1, 2]
