"""Paper-core tests: events, DES calibration against the paper's measured
claims, Amdahl analytics, queueing stability, and the TCO tables."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # deterministic single-example shim
    from hypothesis_fallback import given, settings, st

from repro.core import acceleration as acc
from repro.core.broker import BrokerConfig
from repro.core.events import EventLog
from repro.core.queueing import bottleneck, max_stable_speedup, utilizations
from repro.core.simulator import (
    ClusterSim, FaceRecWorkload, object_detection_workload,
)
from repro.core.tco import homogeneous_design, paper_comparison


# ---- events ---------------------------------------------------------------

def test_event_log_breakdown_and_tax():
    log = EventLog()
    log.log(0, "ingest", 0.0, 0.02)
    log.log(0, "detect", 0.02, 0.09)
    log.log(0, "wait", 0.09, 0.22)
    log.log(0, "identify", 0.22, 0.35)
    bd = log.breakdown()
    assert abs(bd["wait"] - 0.13) < 1e-9
    tax = log.ai_tax(ai_stages={"detect", "identify"})
    assert abs(tax["ai_fraction"] - (0.07 + 0.13) / 0.35) < 1e-9
    assert abs(log.mean_e2e() - 0.35) < 1e-9


# ---- Amdahl (paper §5.1) ---------------------------------------------------

def test_amdahl_asymptotes_match_paper():
    # detection 42% AI -> asymptote 1.72x; identification 88% -> 8.3x
    assert abs(acc.DETECTION.asymptote - 1.0 / 0.58) < 1e-9
    assert abs(acc.IDENTIFICATION.asymptote - 1.0 / 0.12) < 1e-9
    # paper: detection 1.59x overall at 8x AI accel, 1.66x at 16x
    assert acc.DETECTION.amdahl_speedup(8) == pytest.approx(1.59, abs=0.02)
    assert acc.DETECTION.amdahl_speedup(16) == pytest.approx(1.66, abs=0.02)
    # identification: 5.6x at 16x, 6.6x at 32x (paper rounds from
    # measured data; 0.88 exactly gives 5.70/6.78)
    assert acc.IDENTIFICATION.amdahl_speedup(16) == pytest.approx(5.6, abs=0.25)
    assert acc.IDENTIFICATION.amdahl_speedup(32) == pytest.approx(6.6, abs=0.25)
    assert acc.INGESTION.amdahl_speedup(32) == 1.0


@settings(max_examples=20, deadline=None)
@given(st.floats(0.01, 0.99), st.floats(1.0, 64.0))
def test_amdahl_properties(f, s):
    p = acc.StageProfile("x", f)
    sp = p.amdahl_speedup(s)
    assert 1.0 <= sp <= s + 1e-9
    assert sp <= p.asymptote + 1e-9


# ---- queueing stability (paper §5.3-5.4) ------------------------------------

def test_storage_is_first_bottleneck():
    wl, bk = FaceRecWorkload(), BrokerConfig()
    b = bottleneck(wl, bk, speedup=8.0)
    assert b.name == "broker_storage_write"
    assert not b.stable
    assert bottleneck(wl, bk, speedup=6.0).stable


def test_paper_fig15_unlock_thresholds():
    wl = FaceRecWorkload()
    # drives: paper unlocks 12x@2, 24x@3, 32x@4; 8x infinite @1
    s1 = max_stable_speedup(wl, BrokerConfig(drives_per_broker=1))
    s2 = max_stable_speedup(wl, BrokerConfig(drives_per_broker=2))
    s3 = max_stable_speedup(wl, BrokerConfig(drives_per_broker=3))
    s4 = max_stable_speedup(wl, BrokerConfig(drives_per_broker=4))
    assert s1 < 8.0
    assert 12.0 <= s2 < 16.0
    assert 24.0 <= s3 < 32.0
    assert s4 >= 32.0
    # brokers monotonically unlock higher speedups
    sb = [max_stable_speedup(wl, BrokerConfig(n_brokers=n))
          for n in (3, 4, 6, 8)]
    assert sb[0] < 8.0 <= sb[1] and all(a < b for a, b in zip(sb, sb[1:]))
    # thumbnail shrink raises the limit (Fig 15c)
    s_half = max_stable_speedup(FaceRecWorkload(face_bytes=37300 / 2),
                                BrokerConfig())
    assert s_half > 1.8 * s1


def test_network_never_binds_before_storage():
    wl, bk = FaceRecWorkload(), BrokerConfig()
    for s in (1, 2, 4, 8, 16, 32):
        u = utilizations(wl, bk, s)
        assert u["broker_network"].rho < u["broker_storage_write"].rho


# ---- DES (paper Figs 6/10/11/14) --------------------------------------------

def _run(wl, bk, s, **kw):
    kw.setdefault("scale", 0.04)
    kw.setdefault("sim_time", 20)
    kw.setdefault("warmup", 5)
    return ClusterSim(wl, bk, speedup=s, **kw).run()


def test_des_storage_util_matches_paper_10pct_at_1x():
    r = _run(FaceRecWorkload(), BrokerConfig(), 1)
    assert 0.07 <= r.broker_write_util <= 0.13       # paper: ~10%
    assert not r.unstable


def test_des_unstable_at_8x_stable_at_6x():
    assert not _run(FaceRecWorkload(), BrokerConfig(), 6).unstable
    r8 = _run(FaceRecWorkload(), BrokerConfig(), 8)
    assert r8.unstable and r8.mean_latency == float("inf")


def test_des_network_stays_below_paper_bound():
    # paper Fig 11a: broker net read ~6% of 100 Gbps at 8x
    r = _run(FaceRecWorkload(), BrokerConfig(), 8)
    assert r.broker_net_util < 0.10


def test_des_latency_improves_with_acceleration_until_saturation():
    lats = [_run(FaceRecWorkload(), BrokerConfig(), s).mean_latency
            for s in (1, 4)]
    assert lats[1] < lats[0]


def test_des_fig6_realistic_video_breakdown():
    """Empirical face distribution: waiting is a large share (paper: >33%)
    and mean e2e latency lands in the paper's few-hundred-ms regime."""
    wl = FaceRecWorkload(face_dist="empirical", faces_per_frame=0.64)
    r = _run(wl, BrokerConfig(), 1)
    assert not r.unstable
    assert 0.15 <= r.waiting_share <= 0.8
    assert 0.15 <= r.mean_latency <= 1.5


def test_object_detection_second_app():
    wl = object_detection_workload()
    r1 = _run(wl, BrokerConfig(), 1, scale=0.3)
    assert not r1.unstable
    r8 = _run(wl, BrokerConfig(), 8, scale=0.3)
    assert not r8.unstable and r8.throughput > 4 * r1.throughput
    r16 = _run(wl, BrokerConfig(), 16, scale=0.3)
    assert r16.unstable                      # paper: infinite at >=16x
    assert r16.ingest_delay_mean > 0.1       # the producer-side Delay tax


# ---- TCO (paper Tables 3/4) --------------------------------------------------

def test_tco_tables_match_paper_to_the_dollar():
    h = homogeneous_design(drives_per_node=1)
    assert h.equipment_cost == 33_577_760            # Table 3 total
    p = paper_comparison().purpose_built
    assert p.equipment_cost == 27_878_431            # Table 4 total


def test_tco_saving_exceeds_paper_15pct():
    c = paper_comparison(support_32x=True)
    assert c.saving_fraction >= 0.15                 # paper: >15% (16.6%)
    # even vs the base homogeneous design the saving is close to paper's
    from repro.core.tco import TCOComparison, purpose_built_design
    c2 = TCOComparison(homogeneous_design(drives_per_node=1),
                       purpose_built_design())
    assert 0.13 <= c2.saving_fraction <= 0.20
