"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
each asserting allclose against the pure-jnp oracle in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # deterministic single-example shim
    from hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.linear_scan import mamba_scan, rwkv_scan
from repro.kernels.resize import resize_bilinear

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, seed=0, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Skv,H,KV,D,causal,window", [
    (2, 256, 256, 4, 2, 64, True, None),
    (1, 256, 256, 4, 4, 64, False, None),
    (2, 256, 256, 8, 2, 128, True, 128),
    (1, 128, 256, 4, 2, 32, True, None),
    (1, 128, 128, 2, 1, 256, True, None),
])
def test_flash_attention_vs_ref(B, Sq, Skv, H, KV, D, causal, window):
    q = _rand((B, Sq, H, D), seed=1)
    k = _rand((B, Skv, KV, D), seed=2)
    v = _rand((B, Skv, KV, D), seed=3)
    off = Skv - Sq
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=off, interpret=True)
    want = ref.attention(q, k, v, causal=causal, window=window, q_offset=off)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_flash_attention_bf16():
    q = _rand((1, 128, 4, 64), jnp.bfloat16, seed=4)
    k = _rand((1, 128, 2, 64), jnp.bfloat16, seed=5)
    v = _rand((1, 128, 2, 64), jnp.bfloat16, seed=6)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=3e-2, rtol=3e-2)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128, 192]),
       st.sampled_from([(4, 1), (4, 2), (4, 4)]),
       st.sampled_from([32, 64]), st.booleans())
def test_flash_attention_property(B, S, heads, D, causal):
    """Property: kernel == oracle for arbitrary GQA geometry."""
    H, KV = heads
    q = _rand((B, S, H, D), seed=S + H)
    k = _rand((B, S, KV, D), seed=S + KV)
    v = _rand((B, S, KV, D), seed=S + 7)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          blk_q=64, blk_k=64)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,KV,D,window", [
    (3, 1024, 8, 2, 64, None),
    (2, 512, 4, 4, 128, None),
    (2, 1024, 8, 2, 64, 100),
])
def test_decode_attention_vs_ref(B, L, H, KV, D, window):
    q = _rand((B, 1, H, D), seed=1)
    k = _rand((B, L, KV, D), seed=2)
    v = _rand((B, L, KV, D), seed=3)
    kv_len = jnp.asarray([L, L // 2, 17][:B])
    out = decode_attention(q, k, v, kv_len=kv_len, window=window,
                           interpret=True, blk_k=256)
    want = ops.decode_attention(q, k, v, kv_len=kv_len, window=window,
                                impl="xla")
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 4), st.sampled_from([256, 512]),
       st.integers(1, 200))
def test_decode_attention_kvlen_property(B, L, kvl):
    """Property: entries beyond kv_len never influence the output."""
    q = _rand((B, 1, 4, 32), seed=9)
    k = _rand((B, L, 2, 32), seed=10)
    v = _rand((B, L, 2, 32), seed=11)
    kv_len = jnp.full((B,), min(kvl, L))
    out1 = decode_attention(q, k, v, kv_len=kv_len, interpret=True, blk_k=128)
    # poison the invalid region
    mask = jnp.arange(L)[None, :, None, None] >= kv_len[:, None, None, None]
    k2 = jnp.where(mask, 1e4, k)
    v2 = jnp.where(mask, -1e4, v)
    out2 = decode_attention(q, k2, v2, kv_len=kv_len, interpret=True, blk_k=128)
    np.testing.assert_allclose(out1, out2, atol=1e-5, rtol=1e-5)


def test_decode_attention_legal_blk_k():
    """Tile legalization: largest lane-aligned divisor <= requested."""
    from repro.kernels.decode_attention import legal_blk_k
    assert legal_blk_k(512, 512) == 512
    assert legal_blk_k(512, 768) == 384      # the cache_len=768 crash
    assert legal_blk_k(512, 640) == 128
    assert legal_blk_k(512, 1024) == 512
    assert legal_blk_k(128, 1024) == 128
    assert legal_blk_k(512, 17) == 17        # no aligned divisor: exact L
    for L in (768, 640, 384, 96, 17):
        b = legal_blk_k(512, L)
        assert 0 < b <= min(512, L) and L % b == 0


def test_decode_attention_nonaligned_cache_default_tile():
    """cache_len=768 with the default (autotuned) blk_k used to crash at
    trace time on ``L % blk_k == 0``; legalization must round the tile
    down to a divisor and still match the oracle."""
    B, L = 2, 768
    q = _rand((B, 1, 4, 64), seed=20)
    k = _rand((B, L, 2, 64), seed=21)
    v = _rand((B, L, 2, 64), seed=22)
    kv_len = jnp.asarray([L, 300])
    out = decode_attention(q, k, v, kv_len=kv_len, interpret=True)
    want = ops.decode_attention(q, k, v, kv_len=kv_len, impl="xla")
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_decode_attention_kvlen_zero_row_is_zeros():
    """A slot with no valid cache (kv_len=0 — a freed/never-filled lane)
    must come back as exact zeros, not NaN from an empty softmax."""
    B, L = 3, 256
    q = _rand((B, 1, 4, 32), seed=23)
    k = _rand((B, L, 2, 32), seed=24)
    v = _rand((B, L, 2, 32), seed=25)
    kv_len = jnp.asarray([0, 128, 0])
    out = decode_attention(q, k, v, kv_len=kv_len, interpret=True, blk_k=128)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 0.0)
    want = ops.decode_attention(q, k, v, kv_len=kv_len, impl="xla")
    np.testing.assert_allclose(out[1], want[1], atol=3e-5, rtol=3e-5)


def test_decode_attention_window_straddles_tile_boundary():
    """Sliding window [kv_len-window, kv_len) crossing a blk_k edge:
    both the partially-masked leading tile and the partially-valid
    trailing tile must agree with the oracle."""
    B, L = 2, 512
    q = _rand((B, 1, 4, 64), seed=26)
    k = _rand((B, L, 2, 64), seed=27)
    v = _rand((B, L, 2, 64), seed=28)
    # window [201, 300] straddles the 256 tile edge; [412, 511] the 384 one
    kv_len = jnp.asarray([300, 511])
    out = decode_attention(q, k, v, kv_len=kv_len, window=100,
                           interpret=True, blk_k=128)
    want = ops.decode_attention(q, k, v, kv_len=kv_len, window=100,
                                impl="xla")
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_decode_attention_heterogeneous_kvlen_batch():
    """A continuous-batching tick's worth of raggedness in one call:
    empty, single-token, mid-cache, and full slots side by side."""
    B, L = 4, 512
    q = _rand((B, 1, 8, 64), seed=29)
    k = _rand((B, L, 2, 64), seed=30)
    v = _rand((B, L, 2, 64), seed=31)
    kv_len = jnp.asarray([0, 1, 250, 512])
    out = decode_attention(q, k, v, kv_len=kv_len, interpret=True, blk_k=256)
    want = ops.decode_attention(q, k, v, kv_len=kv_len, impl="xla")
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    np.testing.assert_allclose(out[1:], want[1:], atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# linear scans
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Di,N,blk_t,blk_c", [
    (2, 64, 256, 8, 16, 128),
    (1, 32, 128, 16, 8, 128),
])
def test_mamba_scan_vs_ref(B, S, Di, N, blk_t, blk_c):
    delta = jax.nn.softplus(_rand((B, S, Di), seed=1))
    A = -jnp.exp(_rand((Di, N), seed=2))
    Bt = _rand((B, S, N), seed=3)
    Ct = _rand((B, S, N), seed=4)
    x = _rand((B, S, Di), seed=5)
    h0 = _rand((B, Di, N), seed=6, scale=0.1)
    y, h = mamba_scan(delta, A, Bt, Ct, x, h0, interpret=True,
                      blk_t=blk_t, blk_c=blk_c)
    yr, hr = ref.mamba_scan(delta, A, Bt, Ct, x, h0)
    np.testing.assert_allclose(y, yr, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, hr, atol=2e-4, rtol=2e-4)


def test_mamba_xla_chunked_vs_ref():
    B, S, Di, N = 2, 100, 24, 4
    delta = jax.nn.softplus(_rand((B, S, Di), seed=1))
    A = -jnp.exp(_rand((Di, N), seed=2))
    Bt, Ct = _rand((B, S, N), seed=3), _rand((B, S, N), seed=4)
    x = _rand((B, S, Di), seed=5)
    y, h = ops.mamba_scan(delta, A, Bt, Ct, x, impl="xla", chunk=32)
    yr, hr = ref.mamba_scan(delta, A, Bt, Ct, x)
    np.testing.assert_allclose(y, yr, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, hr, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,K,V,blk_t", [
    (2, 64, 3, 32, 32, 16),
    (1, 48, 2, 64, 64, 16),
])
def test_rwkv_scan_vs_ref(B, S, H, K, V, blk_t):
    r = _rand((B, S, H, K), seed=1)
    w = jax.nn.sigmoid(_rand((B, S, H, K), seed=2)) * 0.5 + 0.45
    k = _rand((B, S, H, K), seed=3, scale=0.3)
    v = _rand((B, S, H, V), seed=4)
    u = _rand((H, K), seed=5, scale=0.1)
    h0 = _rand((B, H, K, V), seed=6, scale=0.1)
    o, h = rwkv_scan(r, w, k, v, u, h0, interpret=True, blk_t=blk_t)
    orf, hrf = ref.rwkv_scan(r, w, k, v, u, h0)
    np.testing.assert_allclose(o, orf, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, hrf, atol=2e-4, rtol=2e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.sampled_from([16, 32, 48]))
def test_rwkv_chunk_invariance(B, S):
    """Property: the chunked XLA path is chunk-size invariant."""
    r = _rand((B, S, 2, 16), seed=1)
    w = jax.nn.sigmoid(_rand((B, S, 2, 16), seed=2)) * 0.5 + 0.45
    k = _rand((B, S, 2, 16), seed=3, scale=0.3)
    v = _rand((B, S, 2, 16), seed=4)
    u = _rand((2, 16), seed=5, scale=0.1)
    o1, h1 = ops.rwkv_scan(r, w, k, v, u, impl="xla", chunk=8)
    o2, h2 = ops.rwkv_scan(r, w, k, v, u, impl="xla", chunk=16)
    np.testing.assert_allclose(o1, o2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-4)


def test_scan_state_chaining():
    """Running two half-sequences with carried state == one full scan."""
    B, S, H, K, V = 1, 32, 2, 16, 16
    r = _rand((B, S, H, K), seed=1)
    w = jax.nn.sigmoid(_rand((B, S, H, K), seed=2)) * 0.5 + 0.45
    k = _rand((B, S, H, K), seed=3, scale=0.3)
    v = _rand((B, S, H, V), seed=4)
    u = _rand((H, K), seed=5, scale=0.1)
    o_full, h_full = ref.rwkv_scan(r, w, k, v, u)
    o1, h1 = ref.rwkv_scan(r[:, :16], w[:, :16], k[:, :16], v[:, :16], u)
    o2, h2 = ref.rwkv_scan(r[:, 16:], w[:, 16:], k[:, 16:], v[:, 16:], u, h1)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), o_full,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h2, h_full, atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# resize
# --------------------------------------------------------------------------

@pytest.mark.parametrize("H,W,oh,ow", [
    (54, 96, 27, 48),      # 2x downscale (the paper's 1080->540 analogue)
    (64, 64, 128, 128),    # upscale
    (37, 53, 16, 24),      # ragged
])
def test_resize_vs_ref(H, W, oh, ow):
    img = jax.random.uniform(KEY, (2, H, W, 3), jnp.float32) * 255
    out = resize_bilinear(img, oh, ow, interpret=True)
    want = ref.resize_bilinear(img, oh, ow)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 40), st.integers(8, 40))
def test_resize_identity_property(H, W):
    """Property: resizing to the same size is the identity."""
    img = jax.random.uniform(jax.random.PRNGKey(H * W), (H, W, 1))
    out = ref.resize_bilinear(img, H, W)
    np.testing.assert_allclose(out, img, atol=1e-6)


def test_attention_xla_chunk_invariance():
    q = _rand((2, 200, 4, 32), seed=1)
    k = _rand((2, 200, 2, 32), seed=2)
    v = _rand((2, 200, 2, 32), seed=3)
    a = ops.attention(q, k, v, causal=True, impl="xla", q_chunk=64)
    b = ops.attention(q, k, v, causal=True, impl="xla", q_chunk=512)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,blk", [
    (8, 128, 128, 128),      # single tile
    (128, 512, 256, 128),    # multi-tile, k accumulation
    (13, 200, 37, 128),      # ragged: host-side padding on every dim
    (3072, 256, 128, 128),   # the Embedder's layer-1 shape (d_in x 256)
])
def test_matmul_vs_ref(M, K, N, blk):
    a = _rand((M, K), seed=1, scale=0.5)
    b = _rand((K, N), seed=2, scale=0.5)
    out = ops.matmul(a, b, impl="pallas_interpret", blk_m=blk, blk_n=blk,
                     blk_k=blk)
    np.testing.assert_allclose(out, ref.matmul(a, b), atol=1e-4, rtol=1e-4)


def test_matmul_small_blocks_accumulate():
    """k-loop accumulation across many blocks stays exact vs one block."""
    a = _rand((16, 1024), seed=3)
    b = _rand((1024, 128), seed=4)
    small = ops.matmul(a, b, impl="pallas_interpret", blk_k=128)
    one = ops.matmul(a, b, impl="pallas_interpret", blk_k=1024)
    np.testing.assert_allclose(small, one, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(small, ref.matmul(a, b), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("M,K,N,with_bias,epi", [
    (16, 256, 128, True, "tanh"),     # the fused MLP-layer shape class
    (13, 200, 37, True, "tanh"),      # ragged: epilogue on padded blocks
    (16, 256, 128, True, "none"),     # bias only
    (16, 256, 128, False, "tanh"),    # tanh only
])
def test_matmul_epilogue_vs_ref(M, K, N, with_bias, epi):
    """Fused epilogue == tanh(ref.matmul(a, b) + bias) elementwise."""
    a = _rand((M, K), seed=1, scale=0.3)
    b = _rand((K, N), seed=2, scale=0.3)
    bias = _rand((N,), seed=3) if with_bias else None
    want = ref.matmul(a, b).astype(jnp.float32)
    if bias is not None:
        want = want + bias
    if epi == "tanh":
        want = jnp.tanh(want)
    for impl in ("pallas_interpret", "xla"):
        out = ops.matmul(a, b, bias=bias, epilogue=epi, impl=impl,
                         blk_m=8, blk_n=128, blk_k=128)
        np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


def test_matmul_epilogue_applied_once_across_k_blocks():
    """The epilogue must fire only on the last k step: many k blocks
    and one k block agree exactly."""
    a = _rand((8, 512), seed=5, scale=0.2)
    b = _rand((512, 128), seed=6, scale=0.2)
    bias = _rand((128,), seed=7)
    many = ops.matmul(a, b, bias=bias, epilogue="tanh",
                      impl="pallas_interpret", blk_m=8, blk_n=128, blk_k=128)
    one = ops.matmul(a, b, bias=bias, epilogue="tanh",
                     impl="pallas_interpret", blk_m=8, blk_n=128, blk_k=512)
    np.testing.assert_allclose(many, one, atol=1e-5, rtol=1e-5)
