"""Micro-batching subsystem: Batcher semantics, batched model paths vs
per-item oracles, and end-to-end batched-pipeline equivalence."""
import queue
import threading
import time

import numpy as np
import pytest

from repro.core import facerec
from repro.core.batching import Batcher, BatchStats
from repro.core.pipeline import StreamingPipeline
from repro.data.video import VideoStream

STOP = object()


# ---- Batcher ---------------------------------------------------------------

def test_batcher_size_flush_and_stop_flush():
    q = queue.Queue()
    for i in range(10):
        q.put(i)
    q.put(STOP)
    b = Batcher(q, batch_size=4, timeout_s=10.0, stop=STOP)
    batches = list(b)
    assert batches == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9]]
    assert b.stats.n_batches == 3 and b.stats.n_items == 10
    assert b.stats.flush_size == 2 and b.stats.flush_stop == 1
    assert b.next_batch() is None          # stays stopped


def test_batcher_timeout_flush():
    q = queue.Queue()
    b = Batcher(q, batch_size=8, timeout_s=0.05, stop=STOP)

    def produce():
        q.put("a")
        q.put("b")
        time.sleep(0.3)                    # longer than the linger
        q.put(STOP)

    t = threading.Thread(target=produce)
    t.start()
    first = b.next_batch()
    t.join()
    assert first == ["a", "b"]
    assert b.stats.flush_timeout == 1
    assert b.next_batch() is None


def test_batcher_poll_is_nonblocking():
    q = queue.Queue()
    b = Batcher(q, batch_size=4, stop=STOP)
    assert b.poll() == []                  # empty queue: returns immediately
    for i in range(3):
        q.put(i)
    assert b.poll(2) == [0, 1]
    assert b.poll() == [2]


def test_batcher_push_flush():
    b = Batcher(batch_size=3, timeout_s=10.0)
    assert b.push(1) is None and b.push(2) is None
    assert b.push(3) == [1, 2, 3]                  # size bound
    assert b.push(4) is None
    assert b.flush() == [4]                        # end-of-stream partial
    assert b.flush() is None
    assert b.stats.flush_size == 1 and b.stats.flush_stop == 1


def test_batcher_push_linger_bound():
    b = Batcher(batch_size=100, timeout_s=0.01)
    assert b.push("a") is None
    time.sleep(0.05)
    assert b.push("b") == ["a", "b"]               # linger tripped at push
    assert b.stats.flush_timeout == 1


def test_batcher_guards_misuse():
    with pytest.raises(ValueError):                # no sentinel -> no end
        iter(Batcher(queue.Queue(), batch_size=2))
    with pytest.raises(ValueError):                # push-fed has no source
        Batcher(batch_size=2).next_batch()
    with pytest.raises(ValueError):
        Batcher(batch_size=2).poll()


def test_batch_stats_merge():
    a = BatchStats(n_batches=2, n_items=5, flush_size=1, flush_timeout=1)
    c = a.merge(BatchStats(n_batches=1, n_items=3, flush_stop=1))
    assert (c.n_batches, c.n_items, c.flush_stop) == (3, 8, 1)
    assert c.mean_batch_size == pytest.approx(8 / 3)


# ---- batched model paths vs per-item oracles --------------------------------

@pytest.fixture(scope="module")
def frames():
    vs = VideoStream(seed=3)
    return [vs.next_frame().pixels for _ in range(6)]


def test_detect_batch_matches_single(frames):
    stack = np.stack(frames)
    batched = facerec.detect_faces_batch(stack)
    singles = [facerec.detect_faces(f) for f in frames]
    assert batched == singles


def test_crop_batch_matches_single(frames):
    centers = facerec.detect_faces_batch(np.stack(frames))
    batched = facerec.crop_thumbnails_batch(
        [f.astype(np.float32) for f in frames], centers)
    for frame, cs, thumbs in zip(frames, centers, batched):
        assert len(thumbs) == len(cs)
        for (y, x), thumb in zip(cs, thumbs):
            single = facerec.crop_thumbnail(frame.astype(np.float32), y, x)
            np.testing.assert_allclose(thumb, single, rtol=1e-5, atol=1e-4)


def test_embed_and_identify_batch_match_single():
    rng = np.random.default_rng(0)
    thumbs = rng.uniform(0, 255, (5, facerec.THUMB, facerec.THUMB, 3)) \
        .astype(np.float32)
    emb = facerec.Embedder()
    batched = emb.embed_batch(thumbs)
    assert batched.shape == (5, facerec.EMBED_DIM)
    np.testing.assert_allclose(np.linalg.norm(batched, axis=1), 1.0,
                               rtol=1e-5)
    for i in range(5):
        np.testing.assert_allclose(batched[i], emb(thumbs[i]),
                                   rtol=1e-5, atol=1e-6)
    gal = {f"p{i}": emb(rng.uniform(0, 255, thumbs.shape[1:])
                        .astype(np.float32)) for i in range(4)}
    clf = facerec.Classifier(gal)
    pairs = clf.identify_batch(batched)
    assert len(pairs) == 5
    for e, (name, sim) in zip(batched, pairs):
        n1, s1 = clf.identify(e)
        assert n1 == name and s1 == pytest.approx(sim)


# ---- end-to-end pipeline equivalence ---------------------------------------

def _ids(result):
    return sorted((rid, name) for rid, name, _ in result.identities)


@pytest.mark.parametrize("fused", [True, False])
def test_pipeline_batched_equals_unbatched(fused):
    kw = dict(n_frames=20, fuse_ingest_detect=fused,
              n_identify_workers=2, seed=0, batch_timeout_ms=100.0)
    r1 = StreamingPipeline(batch_size=1, **kw).run()
    r8 = StreamingPipeline(batch_size=8, **kw).run()
    assert (r8.detected, r8.matched, r8.ground_truth) == \
        (r1.detected, r1.matched, r1.ground_truth)
    assert _ids(r8) == _ids(r1)


def test_pipeline_batched_per_request_events_survive():
    r = StreamingPipeline(n_frames=20, seed=0, batch_size=8,
                          batch_timeout_ms=100.0).run()
    waits = [e for e in r.log.events if e.stage == "wait"]
    idents = [e for e in r.log.events if e.stage == "identify"]
    # every face logs its own queue wait and its own identify slice
    assert len(waits) == r.detected == len(idents)
    assert all(e.meta.get("batch_size", 0) >= 1 for e in idents)
    assert any(e.meta.get("batch_size", 0) > 1 for e in idents)
    stats = r.batch_stats["identify"]
    assert stats.n_items == r.detected
    assert stats.mean_batch_size > 1.0


def test_pipeline_timeout_flush_drains_stragglers():
    # faces arrive slower than the batch fills -> linger must flush
    r = StreamingPipeline(n_frames=12, seed=0, batch_size=64,
                          batch_timeout_ms=2.0).run()
    assert len(r.identities) == r.detected         # nothing stranded
    stats = r.batch_stats["identify"]
    assert stats.flush_timeout + stats.flush_stop >= 1
