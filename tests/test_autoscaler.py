"""SLO-driven autoscaler: control-law units, DES runs, diurnal trace.

Three harnesses drive the ONE control law (``Autoscaler.decide`` is
pure state + arithmetic):
  * direct unit tests — hysteresis dead band, cooldown lockout, bounds;
  * the DES — an underprovisioned cluster that would diverge statically
    is rescued by scale-up before the knee, converging on at least the
    closed-form minimum replica count;
  * a fluid-queue replay of the golden diurnal trace — scale-down fires
    on the night-side drain yet the p99 SLO is never violated (the
    shrink guards are the thing under test).
"""
import math

import pytest

from repro.cluster import AutoscalerConfig, ClusterSpec
from repro.cluster.autoscaler import Autoscaler
from repro.cluster.loadgen import diurnal_profile
from repro.core.queueing import utilizations


# ---- config validation ------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError):
        AutoscalerConfig(min_replicas=0)
    with pytest.raises(ValueError):
        AutoscalerConfig(min_replicas=8, max_replicas=4)
    with pytest.raises(ValueError):
        AutoscalerConfig(up_backlog=4.0, down_backlog=4.0)
    with pytest.raises(ValueError):
        AutoscalerConfig(step=0)
    assert isinstance(AutoscalerConfig().controller(), Autoscaler)


# ---- control-law units ------------------------------------------------------

def test_dead_band_holds_on_constant_load():
    """Per-replica backlog inside (down, up) never triggers an action,
    no matter how long it persists — hysteresis cannot oscillate."""
    ctl = AutoscalerConfig(up_backlog=8, down_backlog=2,
                           cooldown_s=1.0).controller()
    for k in range(200):
        assert ctl.decide(k * 0.25, backlog=5.0 * 4, n_replicas=4) == 0
    assert ctl.actions == []


def test_cooldown_blocks_consecutive_actions():
    cfg = AutoscalerConfig(cooldown_s=2.0, interval_s=0.25)
    ctl = cfg.controller()
    assert ctl.decide(0.0, backlog=100, n_replicas=2) == 1
    # high pressure throughout the cooldown: still held
    for k in range(1, 8):
        assert ctl.decide(k * 0.25, backlog=100, n_replicas=3) == 0
    assert ctl.decide(2.0, backlog=100, n_replicas=3) == 1
    ts = [a.t for a in ctl.actions]
    assert all(b - a >= cfg.cooldown_s for a, b in zip(ts, ts[1:]))


def test_bounds_respected():
    cfg = AutoscalerConfig(min_replicas=2, max_replicas=4, cooldown_s=0.0)
    ctl = cfg.controller()
    assert ctl.decide(0.0, backlog=1000, n_replicas=4) == 0     # at max
    assert ctl.decide(1.0, backlog=0, n_replicas=2) == 0        # at min
    assert ctl.decide(2.0, backlog=0, n_replicas=3) == -1


def test_scale_down_guards():
    """Shrink is refused when the post-removal depth would cross the
    growth threshold or the tail lacks SLO headroom — scale-down can
    never be the cause of the next breach."""
    cfg = AutoscalerConfig(up_backlog=8, down_backlog=2, cooldown_s=0.0,
                           slo_p99_s=0.5, slo_margin=0.8)
    ctl = cfg.controller()
    # depth guard: 2 replicas at backlog 3 -> 1 replica would hold 3 < 8: ok
    # but 16 replicas at backlog 130 -> per=8.1 is above the band anyway;
    # craft the marginal case: per=1.9 now, 9.5 after removing 4 of 5
    cfg2 = AutoscalerConfig(up_backlog=8, down_backlog=2, cooldown_s=0.0,
                            step=4, slo_p99_s=None)
    ctl2 = cfg2.controller()
    assert ctl2.decide(0.0, backlog=9.5, n_replicas=5) == 0
    # SLO guard: depth says shrink, tail says no
    assert ctl.decide(0.0, backlog=1.0, n_replicas=4, p99=0.45) == 0
    assert ctl.decide(1.0, backlog=1.0, n_replicas=4, p99=None) == 0
    assert ctl.decide(2.0, backlog=1.0, n_replicas=4, p99=0.2) == -1


# ---- DES: scale-up rescues an underprovisioned cluster ----------------------

@pytest.mark.slow
def test_des_scale_up_beats_the_knee():
    """Start with 2 consumers where the closed form needs 6: the static
    run diverges, the autoscaled run does not, and the controller
    converges on at least the closed-form minimum replica count."""
    spec = ClusterSpec(n_replicas=2, n_producers=4, n_partitions=12,
                       speedup=4)
    # closed-form minimum: smallest R with consumer rho < 1
    wl = spec.scaled_workload()
    need = next(r for r in range(1, 32)
                if utilizations(wl, spec.scaled_broker(), spec.speedup,
                                n_consumers=r)["consumers"].rho < 1.0)
    assert need >= 3                      # the scenario is real

    static = spec.des_sim(sim_time=20, warmup=4).run()
    assert static.diverged                # underprovisioned, no rescue

    auto = ClusterSpec(
        n_replicas=2, n_producers=4, n_partitions=12, speedup=4,
        autoscale=AutoscalerConfig(min_replicas=2, max_replicas=12,
                                   interval_s=0.25, cooldown_s=0.75))
    sim = auto.des_sim(sim_time=20, warmup=4)
    r = sim.run()
    assert not r.diverged
    assert r.scale_events > 0
    assert r.final_consumers >= need
    # the rescue happened early — before the backlog ran away
    assert sim.scale_actions[0].t < 2.0


# ---- fluid-queue replay of the golden diurnal trace -------------------------

def _replay_diurnal(cfg: AutoscalerConfig, mu: float, n0: int,
                    seed: int = 0):
    """Deterministic fluid M/D/R replay: backlog integrates
    (rate - R*mu), the p99 proxy is the drain time of the current
    backlog plus one service — the same signals both real engines feed
    the controller, minus their noise, so guard violations are
    attributable to the control law alone."""
    ctl = cfg.controller()
    trace = diurnal_profile(horizon_s=120.0, base_rate=20.0,
                            peak_rate=60.0, period_s=60.0, seed=seed,
                            dt=cfg.interval_s)
    R, backlog, hist = n0, 0.0, []
    for t, rate in trace:
        backlog = max(0.0, backlog + (rate - R * mu) * cfg.interval_s)
        p99 = backlog / (R * mu) + 1.0 / mu
        R = max(cfg.min_replicas, R + ctl.decide(t, backlog, R, p99))
        hist.append((t, R, backlog, p99))
    return ctl, hist


def test_diurnal_scale_down_never_violates_slo():
    cfg = AutoscalerConfig(min_replicas=2, max_replicas=16,
                           interval_s=0.25, cooldown_s=0.5,
                           up_backlog=4.0, down_backlog=1.0,
                           slo_p99_s=0.5, slo_margin=0.6)
    ctl, hist = _replay_diurnal(cfg, mu=5.0, n0=8)
    downs = [a for a in ctl.actions if a.delta < 0]
    ups = [a for a in ctl.actions if a.delta > 0]
    assert downs and ups                  # both sides exercised
    # THE property: no breach is ever attributable to a shrink. During
    # each scale-down's lockout window (cooldown + one interval — the
    # span in which the controller cannot yet correct itself) the SLO
    # must hold at every step. Breaches on demand up-ramps are the
    # reactive controller's nature and are allowed; breaches after a
    # shrink would mean the guards are broken.
    lockout = cfg.cooldown_s + cfg.interval_s
    for a in downs:
        window = [p99 for t, _, _, p99 in hist if a.t < t <= a.t + lockout]
        assert all(p <= cfg.slo_p99_s for p in window), (a, window)
    # and the whole trace stays within sane reach of the objective
    settle = 5.0
    assert max(p99 for t, _, _, p99 in hist if t > settle) \
        <= 1.5 * cfg.slo_p99_s
    # the controller actually tracks the diurnal shape
    day = max(R for t, R, _, _ in hist if t > settle)
    night = min(R for t, R, _, _ in hist if t > settle)
    assert day > night


def test_diurnal_replay_is_deterministic():
    cfg = AutoscalerConfig(min_replicas=2, max_replicas=16,
                           interval_s=0.25, cooldown_s=0.5,
                           up_backlog=4.0, down_backlog=1.0,
                           slo_p99_s=0.5, slo_margin=0.6)
    a_ctl, a_hist = _replay_diurnal(cfg, mu=5.0, n0=8)
    b_ctl, b_hist = _replay_diurnal(cfg, mu=5.0, n0=8)
    assert a_hist == b_hist               # exact float equality
    assert [(x.t, x.delta) for x in a_ctl.actions] \
        == [(x.t, x.delta) for x in b_ctl.actions]
