"""Fixture: host side effects reachable from a jitted function.

Must trip jit-purity-check and ONLY jit-purity-check — one effect
directly in the decorated function, one two call-hops down.
"""
import time

import jax


@jax.jit
def step(x):
    time.sleep(0.001)                # traced-in host effect
    return helper(x)


def helper(x):
    return deeper(x)


def deeper(x):
    with open("/tmp/out.txt", "w") as f:   # reachable host I/O
        f.write("x")
    return x
