"""Fixture: a stage literal that the canonical table cannot place.

Must trip tax-stage-check and ONLY tax-stage-check — "bogus_stage"
matches no exact entry, no prefix/suffix convention, and contains no
"wait", so it would silently land in the residual "pre" bucket.
"""


def record(log):
    log.log(1, "bogus_stage", 0.0, 1.0)
