"""Fixture: two locks always nested in one order — no cycle.

Must produce zero findings, including across a call edge (the inner
lock is taken inside a callee while the outer is held).
"""
import threading


class Pair:
    def __init__(self):
        self.a = 0
        self.b = 0
        self._lock_a = threading.Lock()
        self._lock_b = threading.Lock()
        self.t = threading.Thread(target=self.forward)
        self.u = threading.Thread(target=self.also_forward)

    def forward(self):
        with self._lock_a:
            with self._lock_b:
                self.a += 1

    def also_forward(self):
        with self._lock_a:
            self._inner()

    def _inner(self):
        with self._lock_b:
            self.b += 1
