"""Fixture: blocking waits while holding a lock in thread code.

Must trip sleep-under-lock and ONLY sleep-under-lock: a lexical
time.sleep under `with self._lock`, an Event.wait under the same, and
a helper with no `with` of its own that every caller invokes while
holding the lock (the interprocedural lock-context rule).
"""
import threading
import time


class Poller:
    def __init__(self):
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._loop)

    def _loop(self):
        while True:
            with self._lock:
                time.sleep(0.01)
                self._nap()
            self._wait_locked()

    def _wait_locked(self):
        with self._lock:
            self._stop.wait(0.01)

    def _nap(self):
        # inherits the lock context: its only caller holds _lock
        time.sleep(0.01)
