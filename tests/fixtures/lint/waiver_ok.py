"""Fixture: a real race finding suppressed by a well-formed waiver.

Must produce zero findings — the waiver names the rule and carries a
non-empty reason.
"""
import threading


class Worker:
    def __init__(self):
        self.count = 0
        self.t = threading.Thread(target=self._loop)

    def _loop(self):
        self.count += 1  # lint: waive race-check -- fixture: single owning thread by contract
