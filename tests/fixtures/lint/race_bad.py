"""Fixture: unguarded counter write in a thread-reachable method.

Must trip race-check and ONLY race-check.
"""
import threading


class Worker:
    def __init__(self):
        self.count = 0
        self.t = threading.Thread(target=self._loop)

    def _loop(self):
        for _ in range(10):
            self.count += 1          # racy: no lock, not a primitive
