"""Fixture: the same shape as sleepunderlock_bad, waits done right.

Must produce zero findings: Condition.wait on the condition's own lock
(wait atomically releases it — the sanctioned pattern), time.sleep
with no lock held, and an Event.wait outside any critical section.
"""
import threading
import time


class Poller:
    def __init__(self):
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._loop)

    def _loop(self):
        while True:
            with self._cv:
                self._cv.wait(0.01)
            time.sleep(0.01)
            self._stop.wait(0.01)
