"""Fixture: a waiver comment with no reason.

Must trip BOTH race-check (a reasonless waiver waives nothing) and
waiver-format (the malformed waiver is itself a finding).
"""
import threading


class Worker:
    def __init__(self):
        self.count = 0
        self.t = threading.Thread(target=self._loop)

    def _loop(self):
        self.count += 1  # lint: waive race-check
