"""Fixture: the same shape as race_bad, but properly guarded.

Must produce zero findings: one write is lock-guarded, one target is a
threading primitive, and one method is only ever called with the lock
held (the interprocedural lock-context rule).
"""
import queue
import threading


class Worker:
    def __init__(self):
        self.count = 0
        self._lock = threading.Lock()
        self.outbox = queue.Queue()
        self.t = threading.Thread(target=self._loop)

    def _loop(self):
        for _ in range(10):
            with self._lock:
                self.count += 1
            self.outbox.put(self.count)

    def bump(self):
        with self._lock:
            self._bump_locked()

    def _bump_locked(self):
        # no `with` of its own — guarded because every caller holds
        # the lock (lock-context fixpoint)
        self.count += 1
