"""Fixture: stage names that all resolve through the canonical table.

Must produce zero findings: an exact entry, a prefix-typed name, a
suffix-typed f-string, a keyword log_transfer stage, and a shadowing
``math.log``-style call that the import-table resolution must NOT
mistake for an EventLog sink.
"""
import math


def record(log, name):
    log.log(1, "ingest", 0.0, 1.0)
    log.log(2, "pre_decode", 0.0, 1.0)
    log.log(3, f"{name}/compute", 0.0, 1.0)
    log.log_transfer(4, "h2d", 1024, "crop", stage="transfer")
    return math.log(2.0)
