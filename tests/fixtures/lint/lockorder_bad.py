"""Fixture: inconsistent nesting order over two locks.

Must trip lock-order-check and ONLY lock-order-check (the writes
inside are lock-guarded, so race-check stays quiet).
"""
import threading


class Pair:
    def __init__(self):
        self.a = 0
        self.b = 0
        self._lock_a = threading.Lock()
        self._lock_b = threading.Lock()
        self.t = threading.Thread(target=self.forward)
        self.u = threading.Thread(target=self.backward)

    def forward(self):
        with self._lock_a:
            with self._lock_b:
                self.a += 1

    def backward(self):
        with self._lock_b:
            with self._lock_a:
                self.b += 1
