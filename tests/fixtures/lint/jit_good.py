"""Fixture: pure jitted code next to hosty-but-unjitted code.

Must produce zero findings: the jitted function is pure; the module's
other function touches the clock but is never reachable from a
jit/pallas seed.
"""
import time

import jax


@jax.jit
def step(x):
    return x * 2 + 1


def host_timer():
    return time.perf_counter()
