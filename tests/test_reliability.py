"""Reliability layer: policies, breaker state machine, both engines.

Property-style tests use hypothesis when installed and degrade to one
representative example via the deterministic fallback otherwise; the
engine-level tests drive the DES (and one small live cluster) with the
same policy objects the benchmark uses.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_fallback import given, settings, st

from repro.cluster.cluster import ClusterSpec, ServingCluster
from repro.cluster.faults import FaultPlan
from repro.cluster.reliability import (CLOSED, HALF_OPEN, OPEN,
                                       BreakerConfig, DegradeLevel,
                                       DegradePolicy, RetryPolicy,
                                       open_fraction)
from repro.configs import get_config
from repro.core import facerec
from repro.core.broker import BrokerConfig
from repro.core.metrics import goodput_timeline, reliability_report
from repro.core.simulator import ClusterSim, FaceRecWorkload
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine


# ---- retry policy -----------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(0, 500), st.integers(1, 8), st.integers(0, 99))
def test_backoff_jitter_bounded_and_deterministic(rid, attempt, seed):
    p = RetryPolicy(backoff_base_s=0.02, backoff_cap_s=0.25, seed=seed)
    d = p.backoff_s(rid, attempt)
    hi = min(p.backoff_cap_s, p.backoff_base_s * 2.0 ** (attempt - 1))
    assert p.backoff_base_s <= d <= hi + 1e-12
    # same (seed, rid, attempt) -> same draw, in any engine
    assert p.backoff_s(rid, attempt) == d


def test_backoff_seed_actually_jitters():
    # attempt 1 has a degenerate [base, base] range; from attempt 2 on
    # different seeds must not resynchronize a storm into lockstep
    a = RetryPolicy(seed=0)
    b = RetryPolicy(seed=1)
    draws_a = [a.backoff_s(rid, 2) for rid in range(8)]
    draws_b = [b.backoff_s(rid, 2) for rid in range(8)]
    assert draws_a != draws_b
    assert len(set(draws_a)) > 1          # jitter across request ids too


def test_retry_allowed_caps_attempts_and_respects_deadline():
    p = RetryPolicy(deadline_s=1.0, attempt_timeout_s=0.3, max_attempts=3)
    assert p.retry_allowed(0.1, 0.0, 1)
    assert not p.retry_allowed(0.1, 0.0, 3)          # attempt cap
    # a retry that could not publish before the deadline is pointless
    assert not p.retry_allowed(0.999, 0.0, 1)
    with pytest.raises(ValueError):
        p.backoff_s(0, 0)


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_base_s=0.5, backoff_cap_s=0.1)
    with pytest.raises(ValueError):
        RetryPolicy(hedge_delay_s=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(deadline_s=0.0)


# ---- circuit breaker --------------------------------------------------------

def _trip(b, t0, n=4):
    for i in range(n):
        b.record(t0 + 0.01 * i, False)


def test_breaker_full_state_walk():
    cfg = BreakerConfig(window_s=1.0, failure_threshold=0.5, min_volume=4,
                        open_s=0.5, probe_rate=1.0, close_after=2, seed=0)
    b = cfg.make(0)
    assert b.state == CLOSED and b.allow(0.0)
    _trip(b, 0.0)                                  # 4/4 failures in window
    assert b.state == OPEN
    assert not b.allow(0.2)                        # open: everything shed
    assert b.allow(0.03 + 0.5 + 0.01)              # open_s elapsed -> probe
    assert b.state == HALF_OPEN
    b.record(0.6, True)
    assert b.state == HALF_OPEN                    # 1 of close_after=2
    b.record(0.7, True)
    assert b.state == CLOSED                       # probe streak closed it
    _trip(b, 1.0)                                  # window cleared on close,
    assert b.state == OPEN                         # so it trips fresh
    assert b.allow(1.7)                            # half-open again
    b.record(1.7, False)                           # probe failure
    assert b.state == OPEN                         # -> straight back open
    states = [s for _, s in b.timeline]
    assert states[0] == CLOSED and states.count(OPEN) == 3


def test_breaker_needs_min_volume_and_window_prunes():
    cfg = BreakerConfig(window_s=0.5, failure_threshold=0.5, min_volume=5,
                        open_s=1.0)
    b = cfg.make(0)
    _trip(b, 0.0, n=4)                             # below min_volume
    assert b.state == CLOSED
    b.record(5.0, False)                           # old failures pruned:
    assert b.state == CLOSED                       # 1/1 but volume 1 < 5


def test_breaker_probe_admission_seeded_deterministic():
    cfg = BreakerConfig(min_volume=2, open_s=0.1, probe_rate=0.5, seed=7)
    a, b = cfg.make(3), cfg.make(3)
    for br in (a, b):
        _trip(br, 0.0, n=2)
    seq_a = [a.allow(1.0 + 0.01 * i) for i in range(20)]
    seq_b = [b.allow(1.0 + 0.01 * i) for i in range(20)]
    assert seq_a == seq_b                          # same (seed, key)
    assert True in seq_a and False in seq_a        # it is actually a rate


def test_open_fraction():
    cfg = BreakerConfig(min_volume=2)
    bs = [cfg.make(i) for i in range(4)]
    _trip(bs[0], 0.0, n=2)
    _trip(bs[1], 0.0, n=2)
    assert open_fraction(bs) == 0.5
    assert open_fraction([]) == 0.0


def test_breaker_config_validation():
    with pytest.raises(ValueError):
        BreakerConfig(probe_rate=0.0)
    with pytest.raises(ValueError):
        BreakerConfig(failure_threshold=1.5)
    with pytest.raises(ValueError):
        BreakerConfig(min_volume=0)


# ---- degradation ladder -----------------------------------------------------

def test_degrade_ladder_engage_override_and_hysteresis():
    p = DegradePolicy()                            # enter 16 / exit 4
    assert p.decide(0.0, 0.0, 0) == 0
    assert p.decide(17.0, 0.0, 0) == 1             # one rung per 16
    assert p.decide(33.0, 0.0, 0) == 2
    assert p.decide(999.0, 0.0, 0) == 2            # clamped to ladder depth
    assert p.decide(0.0, 0.6, 0) == 2              # breakers open: deepest
    # hysteresis: above exit_backlog the depth holds...
    assert p.decide(10.0, 0.0, 2) == 2
    # ...and recovery climbs ONE rung at a time, only under exit_backlog
    assert p.decide(3.0, 0.0, 2) == 1
    assert p.decide(3.0, 0.0, 1) == 0


def test_degrade_levels_and_validation():
    p = DegradePolicy()
    assert p.level(0).service_factor == 1.0 and p.level(0).post_nms
    assert p.level(1).name == "skip_rerank" and not p.level(1).post_nms
    assert p.level(99) is p.levels[-1]             # deeper than the ladder
    assert p.level(2).letterbox_scale < 1.0
    with pytest.raises(ValueError):
        DegradePolicy(enter_backlog=4.0, exit_backlog=4.0)
    with pytest.raises(ValueError):
        DegradeLevel(service_factor=1.5)
    with pytest.raises(ValueError):
        DegradeLevel(accuracy_proxy=0.0)


# ---- report plumbing --------------------------------------------------------

def test_reliability_report_math():
    rep = reliability_report([(1.0, 0.5), (2.0, 1.5)], 1.0, 10.0,
                             offered=4, attempts=6)
    assert rep.completed == 2 and rep.in_deadline == 1
    assert rep.throughput == pytest.approx(0.2)
    assert rep.goodput == pytest.approx(0.1)
    assert rep.amplification == pytest.approx(1.5)
    assert rep.deadline_miss_rate == pytest.approx(0.75)
    with pytest.raises(ValueError):
        reliability_report([], 1.0, 0.0, offered=0, attempts=0)


def test_goodput_timeline_emits_empty_windows():
    tl = goodput_timeline([(0.5, 0.1), (3.5, 0.1), (3.6, 9.9)], 1.0, 1.0)
    assert tl == [(1.0, 1.0), (2.0, 0.0), (3.0, 0.0), (4.0, 1.0)]
    assert goodput_timeline([], 1.0, 1.0) == []


# ---- DES lifecycle ----------------------------------------------------------

def _storm(**kw):
    kw.setdefault("retry", RetryPolicy(deadline_s=2.0, attempt_timeout_s=0.6,
                                       max_attempts=4, backoff_base_s=0.02,
                                       backoff_cap_s=0.2, seed=1))
    return ClusterSim(FaceRecWorkload(), BrokerConfig(), speedup=4.0,
                      scale=0.01, sim_time=8.0, warmup=1.0, seed=0,
                      fault_plan=FaultPlan.kill_revive(2.0, 4.0, n=6), **kw)


def test_des_reliability_deterministic_per_seed():
    cfg = BreakerConfig(min_volume=5, open_s=1.0, probe_rate=0.1, seed=2)
    r1 = _storm(breaker=cfg).run()
    r2 = _storm(breaker=cfg).run()
    assert r1.reliability == r2.reliability
    assert r1.reliability["retries"] > 0           # the storm actually ran


def test_des_attempt_accounting_identity():
    # every publish is the first attempt, a retry, or a hedge — nothing
    # else mints attempts, in either engine
    rel = _storm().run().reliability
    assert rel["attempts"] == (rel["offered"] + rel["retries"]
                               + rel["hedges"])
    assert rel["amplification"] == pytest.approx(
        rel["attempts"] / rel["offered"])
    assert rel["completed"] <= rel["offered"]


def test_des_hedging_never_double_counts():
    sim = ClusterSim(FaceRecWorkload(), BrokerConfig(), speedup=4.0,
                     scale=0.01, sim_time=6.0, warmup=1.0, seed=0,
                     retry=RetryPolicy(deadline_s=2.0, attempt_timeout_s=1.0,
                                       max_attempts=2, hedge_delay_s=0.2,
                                       seed=3))
    rel = sim.run().reliability
    assert rel["hedges"] > 0
    # a duplicate is cancelled at dequeue or served-and-wasted; never both
    assert rel["hedge_cancels"] + rel["hedge_wastes"] <= rel["hedges"]
    assert rel["completed"] <= rel["offered"]      # dedupe by request id
    fw = sim.log.five_way(facerec.stage_category)
    assert sum(fw.values()) == pytest.approx(1.0)


def test_des_breaker_sheds_and_timeline_under_storm():
    rel = _storm(breaker=BreakerConfig(window_s=1.0, min_volume=5,
                                       open_s=1.0, probe_rate=0.1,
                                       seed=2)).run().reliability
    assert rel["breaker_sheds"] > 0
    opens = [s for _, _, s in rel["breaker_timeline"] if s == OPEN]
    assert opens                                   # the outage tripped it
    assert rel["deadline_misses"] > 0


def test_des_degrade_books_accuracy_cost():
    r = ClusterSim(FaceRecWorkload(), BrokerConfig(), speedup=4.0,
                   scale=0.01, sim_time=8.0, warmup=1.0, seed=0,
                   fault_plan=FaultPlan.kill_revive(2.0, 4.0, n=10),
                   degrade=DegradePolicy()).run()
    rel = r.reliability
    assert rel["degrade_timeline"]                 # ladder engaged
    assert rel["accuracy_proxy_mean"] < 1.0        # cost on the books


# ---- live cluster -----------------------------------------------------------

def test_live_cluster_reliability_smoke():
    spec = ClusterSpec(
        sim_time=3.0, warmup=1.0, speedup=4.0,
        retry=RetryPolicy(deadline_s=2.0, attempt_timeout_s=1.0,
                          max_attempts=2, seed=1),
        breaker=BreakerConfig(min_volume=5, open_s=1.0, seed=2))
    res = ServingCluster(spec).run()
    rel = res.reliability
    assert rel is not None and rel["offered"] > 0
    assert rel["attempts"] == (rel["offered"] + rel["retries"]
                               + rel["hedges"])
    # healthy cluster: little to no retry amplification, real goodput
    assert 1.0 <= rel["amplification"] < 1.5
    assert rel["goodput"] > 0
    fw = res.log.five_way(facerec.stage_category)
    assert sum(fw.values()) == pytest.approx(1.0)


# ---- serving engine degradation --------------------------------------------

def test_engine_degrade_clamps_generation_under_pressure():
    cfg = get_config("llama3-8b", smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=1, cache_len=48,
                        degrade=DegradePolicy(enter_backlog=2.0,
                                              exit_backlog=1.0))
    rng = np.random.default_rng(0)
    for rid in range(6):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8),
                           max_tokens=12))
    done = eng.run()
    assert len(done) == 6
    degrades = [e for e in eng.log.events if e.stage == "degrade"]
    assert degrades, "queue pressure never engaged the ladder"
    assert any(len(r.tokens) < 12 for r in done)   # generations clamped
    assert all(r.tokens for r in done)             # but never to zero
    assert eng.degrade_timeline
    # the bound counts generated tokens exactly — the clamp is the cap,
    # not cap+1 (the old engine's finish check missed the prefill token)
    assert all(len(r.tokens) <= r.max_tokens for r in done)


def test_engine_degrade_clamp_to_one_token_emits_exactly_one():
    """A ladder clamp down to max_tokens=1 must emit exactly one token
    (the prefill token) and skip decode entirely — the off-by-one used
    to produce two."""
    cfg = get_config("llama3-8b", smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=1, cache_len=48,
                        degrade=DegradePolicy(enter_backlog=2.0,
                                              exit_backlog=1.0))
    rng = np.random.default_rng(1)
    for rid in range(6):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8),
                           max_tokens=2))
    done = eng.run()
    assert len(done) == 6
    clamped = {e.request_id for e in eng.log.events if e.stage == "degrade"}
    assert clamped, "queue pressure never engaged the ladder"
    by_rid = {r.rid: r for r in done}
    for rid in clamped:
        assert by_rid[rid].max_tokens == 1
        assert len(by_rid[rid].tokens) == 1
