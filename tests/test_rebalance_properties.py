"""Property tests for consumer-group rebalance invariants under churn.

The fault engine turns membership churn from a rare administrative
event into the workload itself, so the group's invariants are checked
under arbitrary seeded join/leave/kill sequences (hypothesis when
available, its deterministic single-example fallback otherwise):

  * at most one consumer owns a partition at any generation;
  * every partition is owned whenever >= 1 member is alive;
  * the generation is strictly monotonic across rebalances;
  * a write stamped with a stale generation is never accepted
    (``check_fence``), so a zombie that was rebalanced away cannot
    commit against a partition it no longer owns.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # deterministic single-example shim
    from hypothesis_fallback import given, settings, st

from repro.core.broker import range_assignment
from repro.cluster.scheduler import ConsumerGroup


def _churn(group: ConsumerGroup, seed: int, steps: int) -> list[str]:
    """Seeded random membership churn; returns the alive member list.

    ``kill`` and ``leave`` are the SAME group transition (the fault
    engine's whole point — the group just sees a member vanish), so the
    sequence only distinguishes join from departure.
    """
    rng = random.Random(seed)
    alive: list[str] = []
    spawned = 0
    for _ in range(steps):
        if not alive or rng.random() < 0.55:
            name = f"m{spawned}"
            spawned += 1
            group.join(name)
            alive.append(name)
        else:
            victim = alive.pop(rng.randrange(len(alive)))
            group.leave(victim)
    return alive


def _assert_invariants(group: ConsumerGroup, alive: list[str]):
    table = group.table()
    assert set(table) == set(alive)
    owned: list[int] = []
    for parts in table.values():
        owned.extend(parts)
    # disjointness: <= 1 owner per partition
    assert len(owned) == len(set(owned))
    # coverage: every partition owned whenever anyone is alive
    if alive:
        assert sorted(owned) == list(range(group.n_partitions))
    else:
        assert owned == []


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 24), st.integers(1, 40))
def test_churn_preserves_disjoint_full_coverage(seed, n_partitions, steps):
    group = ConsumerGroup(n_partitions)
    alive = _churn(group, seed, steps)
    _assert_invariants(group, alive)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 12))
def test_generation_strictly_monotonic(seed, n_partitions):
    group = ConsumerGroup(n_partitions)
    rng = random.Random(seed)
    alive: list[str] = []
    last = group.generation
    for i in range(30):
        if not alive or rng.random() < 0.6:
            name = f"m{i}"
            group.join(name)
            alive.append(name)
        else:
            group.leave(alive.pop(rng.randrange(len(alive))))
        assert group.generation > last
        last = group.generation


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 16))
def test_stale_generation_writes_rejected(seed, n_partitions):
    """A member holding a pre-rebalance assignment can commit nothing:
    every (member, partition, generation) stamp from before the churn
    must fail the fence, and post-churn stamps succeed exactly on the
    partitions the member now owns."""
    group = ConsumerGroup(n_partitions)
    group.join("a")
    group.join("b")
    stale = {m: group.assignment(m) for m in ("a", "b")}
    alive = ["a", "b"] + _churn(group, seed, 10)
    alive = [m for m in alive if m in group.members]
    for m, asg in stale.items():
        for pi in asg.partitions:
            assert not group.check_fence(m, pi, asg.generation)
    for m in group.members:
        asg = group.assignment(m)
        for pi in asg.partitions:
            assert group.check_fence(m, pi, asg.generation)
        for pi in range(group.n_partitions):
            if pi not in asg.partitions:
                assert not group.check_fence(m, pi, asg.generation)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 48), st.integers(1, 12))
def test_range_assignment_shape(n_partitions, n_members):
    """The shared assignment function splits contiguously with sizes
    differing by at most one — and is what the live group actually
    serves (single implementation, checked end to end)."""
    members = [f"m{i}" for i in range(n_members)]
    table = range_assignment(members, n_partitions)
    sizes = sorted(len(p) for p in table.values())
    assert sum(sizes) == n_partitions
    assert sizes[-1] - sizes[0] <= 1
    for parts in table.values():
        assert list(parts) == sorted(parts)
        if parts:
            assert parts[-1] - parts[0] == len(parts) - 1   # contiguous
    group = ConsumerGroup(n_partitions)
    for m in members:
        group.join(m)
    assert group.table() == range_assignment(members, n_partitions)


def test_empty_group_owns_nothing_then_recovers():
    group = ConsumerGroup(6)
    group.join("a")
    group.leave("a")
    assert group.table() == {}
    assert group.owner_of(3) is None
    group.join("b")
    _assert_invariants(group, ["b"])
    assert group.assignment("b").partitions == tuple(range(6))
