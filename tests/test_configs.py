"""Config-registry integrity: the published numbers, verbatim."""
import pytest

from repro.configs import ARCHS, SHAPES, get_config, list_configs, supports_shape


def test_registry_lists_all_ten():
    assert len(list_configs()) == 10


EXPECTED = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_published_dimensions(arch):
    cfg = get_config(arch)
    L, d, H, kv, ff, V = EXPECTED[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V


def test_moe_configs():
    j = get_config("jamba-v0.1-52b").moe
    assert (j.n_experts, j.top_k) == (16, 2)
    g = get_config("granite-moe-3b-a800m").moe
    assert (g.n_experts, g.top_k) == (40, 8)
    d = get_config("deepseek-v2-236b").moe
    assert (d.n_experts, d.top_k, d.n_shared) == (160, 6, 2)


def test_mla_config():
    m = get_config("deepseek-v2-236b").mla
    assert m.kv_lora == 512 and m.qk_rope == 64


def test_block_patterns():
    g = get_config("gemma3-12b").block_pattern
    assert len(g) == 6 and sum(s.window is not None for s in g) == 5
    j = get_config("jamba-v0.1-52b").block_pattern
    assert len(j) == 8
    assert sum(s.kind == "attn" for s in j) == 1          # 1:7 interleave
    assert sum(s.moe for s in j) == 4                     # every other layer
    r = get_config("rwkv6-3b").block_pattern
    assert all(s.kind == "rwkv" for s in r)


def test_qkv_bias_flags():
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("qwen1.5-110b").qkv_bias
    assert not get_config("llama3-8b").qkv_bias


def test_long_context_support_matrix():
    runs_long = {a for a in ARCHS if supports_shape(get_config(a), "long_500k")}
    assert runs_long == {"gemma3-12b", "jamba-v0.1-52b", "rwkv6-3b"}
    for a in ARCHS:   # every other shape runs everywhere
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(get_config(a), s)


def test_param_count_headlines():
    """Total params should be in the ballpark the model names claim."""
    expect = {"llama3-8b": (7e9, 9e9),
              "qwen2.5-14b": (12e9, 16e9),
              "gemma3-12b": (10e9, 14e9),
              "qwen1.5-110b": (95e9, 120e9),
              "chameleon-34b": (30e9, 38e9),
              "jamba-v0.1-52b": (45e9, 58e9),
              "rwkv6-3b": (2.2e9, 3.6e9),
              "deepseek-v2-236b": (200e9, 260e9),
              "granite-moe-3b-a800m": (2.4e9, 4.0e9),
              "whisper-large-v3": (1.2e9, 2.0e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, (arch, n)


def test_smoke_configs_are_small():
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        assert cfg.param_counts()["total"] < 5e6, arch
        assert cfg.vocab_size <= 512
