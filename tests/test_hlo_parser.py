"""The structured HLO parser vs golden fixture text (no jax needed).

The fixtures under ``tests/fixtures/`` are the optimized HLO this
container's jax 0.4.37 emits for the calibration battery, checked in
verbatim so parser regressions show up without re-lowering (and so the
parser keeps handling this exact text even if the container's jax
moves).
"""
import pathlib

import pytest

from repro.roofline import hlo_cost
from repro.roofline import hlo_parser as hp

FIXTURES = pathlib.Path(__file__).parent / "fixtures"


def _load(name: str) -> str:
    return (FIXTURES / name).read_text()


def test_parses_matmul_structure():
    mod = hp.parse_module(_load("matmul_32x64x128.hlo"))
    entry = mod.entry
    assert entry is not None and entry.is_entry
    dots = [i for i in entry.instructions if i.opcode == "dot"]
    assert len(dots) == 1
    dot = dots[0]
    assert dot.is_root
    assert dot.shapes == (hp.TensorShape("f32", (32, 128)),)
    assert dot.lhs_contracting == (1,)
    assert dot.rhs_contracting == (0,)
    # inline operand types are captured
    assert dot.operands[0].shapes == (hp.TensorShape("f32", (32, 64)),)
    assert dot.operands[1].shapes == (hp.TensorShape("f32", (64, 128)),)


def test_parses_while_with_trip_count_and_callees():
    mod = hp.parse_module(_load("scan_dot_tanh_t7.hlo"))
    whiles = [i for c in mod.computations.values()
              for i in c.instructions if i.opcode == "while"]
    assert len(whiles) == 1
    w = whiles[0]
    assert w.trip_count == 7
    assert w.body in mod.computations
    assert w.condition in mod.computations
    # the body holds the dot; the fusion's callee edge is captured too
    body = mod.get(w.body)
    fusions = [i for i in body.instructions if i.opcode == "fusion"]
    assert fusions and fusions[0].callees[0] in mod.computations


def test_nested_while_trips_compose():
    mod = hp.parse_module(_load("nested_scan_t3x5.hlo"))
    trips = sorted(i.trip_count for c in mod.computations.values()
                   for i in c.instructions if i.opcode == "while")
    assert trips == [3, 5]


def test_alias_resolution_through_chains():
    """origin_param follows bitcast/convert/copy chains back to params."""
    mod = hp.parse_module(_load("dus_carry_t16.hlo"))
    fused = next(c for c in mod.computations.values()
                 if any(i.opcode == "dynamic-update-slice"
                        for i in c.instructions))
    dus = next(i for i in fused.instructions
               if i.opcode == "dynamic-update-slice")
    # the DUS buffer operand is a parameter (directly or via aliases)
    assert fused.origin_param(dus.operands[0].ref) is not None
    # its update operand is a dynamic-slice, not a parameter
    upd_def = fused.resolve(dus.operands[1].ref)
    assert upd_def is not None and upd_def.opcode == "dynamic-slice"


def test_tuple_shapes_flatten_to_leaves():
    mod = hp.parse_module(_load("scan_dot_tanh_t7.hlo"))
    tuples = [i for c in mod.computations.values()
              for i in c.instructions if i.opcode == "tuple"]
    assert tuples
    t = tuples[0]
    assert len(t.shapes) >= 2                   # flattened leaves
    assert all(isinstance(s, hp.TensorShape) for s in t.shapes)


def test_legacy_text_without_inline_operand_types():
    txt = """
HloModule m
ENTRY %main (a: f32[256,64], b: f32[64,32]) -> f32[256,32] {
  %c = f32[256,64]{1,0} copy(%a)
  ROOT %d = f32[256,32]{1,0} dot(%c, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    mod = hp.parse_module(txt)
    entry = mod.entry
    dot = entry.root
    assert dot.opcode == "dot"
    assert dot.operands[0].shapes == ()         # legacy: no inline type
    # def-use resolution recovers the shape through the copy
    assert entry.operand_shapes(dot, 0) == (hp.TensorShape("f32", (256, 64)),)
    cost = hlo_cost.analyze(txt)
    assert cost.dot_flops == 2 * 256 * 64 * 32


# ---- golden cost numbers: exact, text-only (no lowering at test time) ----

def test_golden_matmul_cost():
    cost = hlo_cost.analyze(_load("matmul_32x64x128.hlo"))
    assert cost.dot_flops == 2 * 32 * 64 * 128
    assert cost.hbm_bytes == (32 * 64 + 64 * 128 + 32 * 128) * 4


def test_golden_scan_trip_multiplication():
    cost = hlo_cost.analyze(_load("scan_dot_tanh_t7.hlo"))
    assert cost.dot_flops == 7 * 2 * 8 * 16 * 16
    flat = hlo_cost.analyze(_load("scan_dot_tanh_t7.hlo"),
                            count_trips=False)
    assert flat.dot_flops == 2 * 8 * 16 * 16


def test_golden_nested_scan_multiplicative_trips():
    cost = hlo_cost.analyze(_load("nested_scan_t3x5.hlo"))
    assert cost.dot_flops == 3 * 5 * 2 * 8 * 8 * 8


def test_golden_dus_carry_charges_touched_slice_only():
    cost = hlo_cost.analyze(_load("dus_carry_t16.hlo"))
    full_buffer_per_step = 16 * 16 * 1024 * 4
    assert cost.hbm_bytes < full_buffer_per_step
    # but it must charge at least the 16 touched slices, read+write
    assert cost.hbm_bytes >= 16 * 2 * 1024 * 4


def test_golden_attention_dot_flops():
    cost = hlo_cost.analyze(_load("attention_b2_s128.hlo"))
    # qk^T + att@v: 2 * B*H*S*S*D each, with H=4 query heads, D=32
    expected = 2 * (2 * 2 * 4 * 128 * 128 * 32)
    assert cost.dot_flops == pytest.approx(expected, rel=0.01)


def test_while_reached_through_wrapping_call_multiplies():
    """Trip counts compose through a wrapping call/fusion layer."""
    txt = """
HloModule m
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element((s32[], f32[8,8]{1,0}) %p), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element((s32[], f32[8,8]{1,0}) %p), index=1
  ROOT %d = f32[8,8]{1,0} dot(f32[8,8]{1,0} %g1, f32[8,8]{1,0} %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %lt = pred[] compare(s32[] %g, s32[] %g), direction=LT
}
%wrapper (q: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %q = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %w = (s32[], f32[8,8]{1,0}) while((s32[], f32[8,8]{1,0}) %q), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
ENTRY %main (a: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %a = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %c = (s32[], f32[8,8]{1,0}) call((s32[], f32[8,8]{1,0}) %a), to_apply=%wrapper
}
"""
    cost = hlo_cost.analyze(txt)
    assert cost.dot_flops == 5 * 2 * 8 * 8 * 8
