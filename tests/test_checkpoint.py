"""Checkpointing: async atomic writes, retention, restore, elastic reshard."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(12, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t, blocking=True)
    restored, step = ck.restore(t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_overlaps_and_waits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))       # non-blocking
    ck.save(2, _tree(2))       # waits for the previous write internally
    ck.wait()
    assert ck.all_steps() == [1, 2]


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_restore_missing_leaf_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.ones((2,))}, blocking=True)
    with pytest.raises(KeyError):
        ck.restore({"a": jnp.ones((2,)), "zz": jnp.ones((2,))})


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.ones((2,))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"a": jnp.ones((3,))})


ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
    from repro.train.checkpoint import Checkpointer

    path = sys.argv[1]
    ck = Checkpointer(path)
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}

    # save from a 4-device layout
    mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
    t4 = {"w": jax.device_put(t["w"], NamedSharding(mesh4, PS("data")))}
    ck.save(3, t4, blocking=True)

    # restore onto an 8-device layout (elastic scale-up)
    mesh8 = Mesh(np.array(jax.devices()).reshape(8), ("data",))
    sh8 = {"w": NamedSharding(mesh8, PS("data"))}
    restored, step = ck.restore(t, shardings=sh8)
    assert step == 3
    assert restored["w"].sharding.num_devices == 8
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_reshard_across_device_counts(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", ELASTIC, str(tmp_path)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
