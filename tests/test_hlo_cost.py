"""The trip-count-aware HLO cost walker vs known-cost programs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline import hlo_cost
from repro.roofline.analysis import model_flops_estimate
from repro.configs import SHAPES, get_config


def test_scan_trip_count_multiplication():
    def f(xs, w):
        def body(c, x):
            return jnp.tanh(c @ w) + x, ()
        c, _ = jax.lax.scan(body, xs[0], xs)
        return c

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((7, 8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.dot_flops == 7 * 2 * 8 * 16 * 16
    assert cost.ew_flops >= 7 * 2 * 8 * 16          # tanh + add per step


def test_nested_scan():
    def f(xs, w):
        def outer(c, x):
            def inner(ci, xi):
                return ci @ w, ()
            ci, _ = jax.lax.scan(inner, c, x)
            return ci, ()
        c, _ = jax.lax.scan(outer, xs[0, 0], xs)
        return c

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((3, 5, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.dot_flops == 3 * 5 * 2 * 8 * 8 * 8


def test_plain_matmul_flops():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((32, 64), jnp.float32),
                jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.dot_flops == 2 * 32 * 64 * 128


def test_collective_bytes_parsing():
    txt = """
HloModule m
ENTRY %main (a: f32[256,64]) -> f32[256,64] {
  %ar = f32[256,64]{1,0} all-reduce(%a), replica_groups={}
  %ag = bf16[128,32]{1,0} all-gather(%x), dimensions={0}
  ROOT %r = f32[256,64]{1,0} copy(%ar)
}
"""
    cost = hlo_cost.analyze(txt)
    assert cost.coll["all-reduce"] == 256 * 64 * 4
    assert cost.coll["all-gather"] == 128 * 32 * 2


def test_model_flops_estimates_scale_sanely():
    cfg = get_config("llama3-8b")
    t = model_flops_estimate(cfg, SHAPES["train_4k"])
    p = model_flops_estimate(cfg, SHAPES["prefill_32k"])
    d = model_flops_estimate(cfg, SHAPES["decode_32k"])
    n = cfg.param_counts()["active"]
    assert t == pytest.approx(6 * n * 4096 * 256)
    assert p == pytest.approx(2 * n * 32768 * 32)
    assert d == pytest.approx(2 * n * 128)
    # ~8B params for llama3-8b
    assert 7.0e9 < n < 9.0e9
