"""Fault-injection engine: plan determinism, recovery, both runtimes.

The battery the ISSUE calls for: seeded timelines are bit-identical;
a kill-revive window spikes the windowed p99 and recovers after the
repair with every in-flight record requeued (never dropped); a dropped
drive moves the DES-measured stability knee to where the degraded
closed form says it should sit (within DES_TOL); a stalled broker
channel builds backlog that drains after restore; and the LIVE cluster
replays the same plan through real threads with the same accounting.
"""
import math

import pytest

from repro.cluster import (AutoscalerConfig, ClusterSpec, FaultEvent,
                           FaultPlan, ServingCluster)
from repro.cluster.crossval import DES_TOL, fault_knees
from repro.cluster.faults import pick_victim
from repro.cluster.metrics import recovery_report
from repro.core.broker import BrokerConfig
from repro.core.events import five_way_fractions
from repro.core.facerec import stage_category
from repro.core.simulator import ClusterSim, FaceRecWorkload

_SIM_KW = dict(scale=0.04, sim_time=20, warmup=5, seed=0)


# ---- plan construction + determinism ----------------------------------------

def test_plan_validates_actions_and_ordering():
    with pytest.raises(ValueError):
        FaultEvent(1.0, "explode")
    with pytest.raises(ValueError):
        FaultEvent(-1.0, "kill")
    with pytest.raises(ValueError):
        FaultPlan((FaultEvent(5.0, "kill"), FaultEvent(1.0, "revive")))
    with pytest.raises(ValueError):
        FaultPlan.kill_revive(4.0, 2.0)
    with pytest.raises(ValueError):
        FaultPlan.stall(3.0, 3.0)
    with pytest.raises(ValueError):
        FaultPlan.drive_drop(3.0, t_restore=2.0)
    assert not FaultPlan()
    plan = FaultPlan.kill_revive(1.0, 2.0, n=3)
    assert plan and len(plan.events) == 6 and plan.horizon == 2.0


def test_same_seed_random_timeline_is_bit_identical():
    a = FaultPlan.random(seed=7, horizon=20.0)
    b = FaultPlan.random(seed=7, horizon=20.0)
    assert a.events == b.events            # exact float equality
    assert a.events != FaultPlan.random(seed=8, horizon=20.0).events
    # every down transition has its paired up transition, in order
    downs = [e for e in a.events if e.action in
             ("kill", "stall", "drive_drop")]
    ups = [e for e in a.events if e.action in
           ("revive", "restore", "drive_restore")]
    assert len(downs) == len(ups) == 3


def test_pick_victim_is_rank_into_sorted_members():
    assert pick_victim([], 0) is None
    assert pick_victim({"b", "a", "c"}, 0) == "a"
    assert pick_victim({"b", "a", "c"}, 2) == "c"
    assert pick_victim({"b", "a", "c"}, 5) == "c"   # wraps
    assert pick_victim({3, 1, 2}, None) == 1


# ---- DES scenarios ----------------------------------------------------------

def _fault_sim(plan, speedup=6, **over):
    kw = dict(_SIM_KW, **over)
    return ClusterSim(FaceRecWorkload(), BrokerConfig(), speedup=speedup,
                      fault_plan=plan, **kw)


def test_des_kill_revive_requeues_and_recovers():
    """30 of 67 consumers die at t=6 (rho 0.69 -> 1.25), return at
    t=10: in-flight work is requeued (never dropped), the windowed p99
    spikes, and the tail is back near baseline before the run ends."""
    sim = _fault_sim(FaultPlan.kill_revive(6.0, 10.0, n=30))
    r = sim.run()
    assert r.fault_events == 60
    assert r.requeues > 0
    assert r.final_consumers == sim.n_cons     # all 30 revived (new ids)
    assert not r.diverged
    rep = recovery_report(sim.completions, 6.0, 10.0, window_s=1.0,
                          depth_samples=sim.depth_samples)
    assert rep.spike_p99 > 3 * rep.baseline_p99
    assert math.isfinite(rep.recovery_s)
    assert math.isfinite(rep.drain_s)
    # requeued, not dropped: throughput within a few % of the no-fault run
    base = ClusterSim(FaceRecWorkload(), BrokerConfig(), speedup=6,
                      **_SIM_KW).run()
    assert r.throughput > 0.95 * base.throughput


def test_des_same_seed_fault_run_bit_identical():
    plan = FaultPlan.kill_revive(6.0, 10.0, n=10)
    a, b = _fault_sim(plan), _fault_sim(plan)
    ra, rb = a.run(), b.run()
    assert a.completions == b.completions      # exact float equality
    assert a.depth_samples == b.depth_samples
    assert a.fault_applied == b.fault_applied
    assert ra.to_dict() == rb.to_dict()


def test_des_stall_restore_builds_then_drains_backlog():
    """All broker write channels stall for 2s: depth spikes while the
    deferred writes pile up, then drains once restore replays them."""
    sim = _fault_sim(FaultPlan.stall(6.0, 8.0, broker=None), speedup=4)
    r = sim.run()
    assert not r.diverged
    pre = max(d for t, d in sim.depth_samples if t <= 6.0)
    during = max(d for t, d in sim.depth_samples if 6.0 < t <= 8.5)
    tail = [d for t, d in sim.depth_samples if t >= 16.0]
    assert during > 3 * max(pre, 1)
    assert max(tail) < 0.25 * during           # drained after restore
    assert r.requeues == 0                     # no membership change


def test_des_drive_drop_knee_matches_degraded_closed_form():
    """The knee while a drive is out must sit where the closed form
    prices the degraded config — measured via the dynamic fault path,
    not a statically reconfigured sim (non-circular by construction)."""
    spec = ClusterSpec(n_replicas=8, n_producers=4,
                       bk=BrokerConfig(drives_per_broker=2))
    degraded = ClusterSpec(n_replicas=8, n_producers=4,
                           bk=BrokerConfig(drives_per_broker=1))
    fk = fault_knees(spec, FaultPlan.drive_drop(2.0), degraded, iters=5)
    assert fk.closed_degraded < fk.closed_healthy
    assert fk.agree, fk.row()
    assert abs(fk.des_degraded - fk.closed_degraded) \
        / fk.closed_degraded <= DES_TOL


def test_des_post_recovery_knee_unchanged():
    """A repaired fault must not move the knee: with kill+revive early
    in the run, divergence at the end-state reflects the HEALTHY
    config, so the measured knee matches the no-fault closed form."""
    from repro.cluster.crossval import des_knee
    from dataclasses import replace
    spec = ClusterSpec(n_replicas=8, n_producers=4)
    plan = FaultPlan.kill_revive(5.0, 7.0, n=2)
    knee = des_knee(replace(spec, fault_plan=plan), iters=5)
    closed = spec.closed_form_knee()
    assert abs(knee - closed) / closed <= DES_TOL


# ---- five-way attribution through faults ------------------------------------

def test_requeue_stage_is_queue_bucket():
    assert stage_category("requeue") == "queue"


def test_five_way_sums_to_one_during_faults():
    """The latent-gap fix: requeued work is logged, lands in the queue
    bucket, and the five-way attribution still sums to 1 (it would
    raise or leak into `pre` if `requeue` were unmapped)."""
    sim = _fault_sim(FaultPlan.kill_revive(6.0, 10.0, n=30))
    r = sim.run()
    assert r.requeues > 0
    frac = sim.log.five_way(stage_category)
    assert set(frac) == {"pre", "ai", "post", "transfer", "queue"}
    assert math.isclose(sum(frac.values()), 1.0, abs_tol=1e-9)
    assert frac["queue"] > 0
    # and directly at the attribution layer, with requeue + reject mixed
    per_stage = {"identify": 0.1, "wait": 0.2, "requeue": 0.0,
                 "reject": 0.01}
    f = five_way_fractions(per_stage, stage_category)
    assert math.isclose(sum(f.values()), 1.0, abs_tol=1e-9)


# ---- live cluster -----------------------------------------------------------

@pytest.mark.slow
def test_live_kill_revive_recovers_with_requeues():
    """The same plan through real threads: kills land as abrupt member
    departures, held-back records are requeued with logged events, the
    tail spikes and recovers, and no work is lost. (One retry on a
    requeue-free run: whether a victim held records at kill time is
    thread-timing dependent on a busy container.)"""
    def run(seed):
        spec = ClusterSpec(n_replicas=8, n_producers=4, speedup=4,
                           sim_time=6.0, warmup=1.0, seed=seed,
                           fetch_max_wait_s=0.35,
                           fault_plan=FaultPlan.kill_revive(1.2, 2.4, n=3))
        return ServingCluster(spec).run()

    r = run(0)
    if r.requeues == 0:          # timing-dependent; one retry
        r = run(1)
    assert [f.action for f in r.faults] == ["kill"] * 3 + ["revive"] * 3
    assert all(f.target is not None for f in r.faults)
    assert r.requeues >= 1
    assert r.rebalances >= 8 + 6           # initial joins + 6 transitions
    assert not r.diverged
    rep = recovery_report(r.samples, 1.2, 2.4, window_s=0.5)
    assert rep.spike_p99 > rep.baseline_p99
    assert math.isfinite(rep.recovery_s)
    frac = r.log.five_way(stage_category)
    assert math.isclose(sum(frac.values()), 1.0, abs_tol=1e-9)


@pytest.mark.slow
def test_live_drive_drop_and_stall_change_channel_state():
    """Broker-side faults through the live engine: a stalled writer
    stops draining (backlog grows), a dropped drive repaces the channel
    config; both restore cleanly by the end of the run."""
    plan = FaultPlan((FaultEvent(1.0, "stall", 0),
                      FaultEvent(2.0, "restore", 0),
                      FaultEvent(2.5, "drive_drop"),
                      FaultEvent(4.0, "drive_restore")))
    spec = ClusterSpec(n_replicas=8, n_producers=4, speedup=4,
                       bk=BrokerConfig(drives_per_broker=2),
                       sim_time=6.0, warmup=1.0, fault_plan=plan)
    cluster = ServingCluster(spec)
    r = cluster.run()
    assert [f.action for f in r.faults] == [
        "stall", "restore", "drive_drop", "drive_restore"]
    assert not r.diverged
    for w in cluster.topic.writers:
        assert not w.stalled.is_set()
        assert w.cfg.drives_per_broker == 2    # restored
    assert r.completed > 0
