"""Deterministic stand-in for hypothesis when it isn't installed.

The container doesn't ship hypothesis (and the no-new-deps rule forbids
installing it), so property tests degrade to a single representative
example per test instead of being skipped: ``given`` binds each
strategy's smallest/first element and runs the body once. Real
hypothesis (requirements-dev.txt) takes over automatically when
present — import via:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from hypothesis_fallback import given, settings, st
"""
from __future__ import annotations


class _Strategy:
    def __init__(self, example):
        self.example = example


class st:  # noqa: N801 — mirrors `strategies as st`
    @staticmethod
    def integers(lo, hi):
        return _Strategy(lo)

    @staticmethod
    def floats(lo, hi):
        return _Strategy(lo)

    @staticmethod
    def sampled_from(xs):
        return _Strategy(xs[0])

    @staticmethod
    def booleans():
        return _Strategy(False)


def settings(**_kw):
    def deco(fn):
        return fn
    return deco


def given(*strats):
    def deco(fn):
        def wrapper():
            return fn(*(s.example for s in strats))
        # no functools.wraps: pytest would follow __wrapped__ and treat
        # the example parameters as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        # keep @pytest.mark.* applied beneath @given working
        wrapper.pytestmark = list(getattr(fn, "pytestmark", []))
        return wrapper
    return deco
