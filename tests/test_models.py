"""Per-architecture smoke tests (reduced family-preserving configs) +
decode-vs-forward consistency — the core model-correctness invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, supports_shape
from repro.models import transformer as tf
from repro.models import encdec as ed
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, seed=1):
    k = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(k, (B, S, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    """One forward + loss on CPU: output shapes right, no NaNs."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    hidden, aux = model.forward(params, batch)
    B = batch["tokens"].shape[0]
    assert hidden.shape[0] == B and hidden.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(hidden)))
    loss = model.loss(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One gradient step on CPU: loss finite, grads finite, params move."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in gleaves)


def _no_drop(cfg):
    if cfg.moe:
        return cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill + T decode steps reproduce full-forward logits (f32,
    no-drop MoE capacity — capacity dropping is the one legitimate
    difference between the batched and incremental paths)."""
    cfg = _no_drop(get_config(arch, smoke=True).replace(dtype="float32"))
    model = build_model(cfg)
    params = model.init(KEY)
    B, S, T = 2, 24, 3
    k = jax.random.PRNGKey(2)
    tokens = jax.random.randint(k, (B, S + T), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :S]}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(k, (B, 12, cfg.d_model),
                                            jnp.float32)
        hidden, _ = ed.encdec_forward(cfg, params, batch["frames"], tokens,
                                      remat=False)
    else:
        hidden, _ = tf.lm_forward(cfg, params, tokens, remat=False)
    full = tf.lm_logits(cfg, params, hidden)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    lp, cache = model.prefill(params, batch, cache_len=S + T)
    np.testing.assert_allclose(lp, full[:, S - 1], atol=2e-4 * scale,
                               rtol=1e-4)
    for t in range(T):
        lg, cache = model.decode_step(params, cache, tokens[:, S + t:S + t + 1])
        np.testing.assert_allclose(lg, full[:, S + t], atol=2e-4 * scale,
                                   rtol=1e-4)


def test_gemma_sliding_window_masks_distant_tokens():
    """Local layers must not see past the window."""
    cfg = get_config("gemma3-12b", smoke=True).replace(
        dtype="float32", n_layers=5,
        block_pattern=tuple(
            [type(get_config("gemma3-12b").block_pattern[0])(window=4)] * 5))
    model = build_model(cfg)
    params = model.init(KEY)
    S = 20
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % cfg.vocab_size)
    h1, _ = tf.lm_forward(cfg, params, t1, remat=False)
    h2, _ = tf.lm_forward(cfg, params, t2, remat=False)
    # with window 4 and 5 layers, receptive field = 5*(4-1)=15 < 19
    np.testing.assert_allclose(h1[:, -1], h2[:, -1], atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """Even with drops, MoE output stays finite and close in norm."""
    cfg = get_config("granite-moe-3b-a800m", smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=2, S=32)
    hidden, aux = model.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    assert float(aux) >= 0.0


def test_mla_cache_is_compressed():
    """DeepSeek MLA decode cache must be the low-rank latent, not full KV."""
    cfg = get_config("deepseek-v2-236b", smoke=True)
    model = build_model(cfg)
    cache = model.abstract_cache(batch=2, cache_len=16)
    layer = cache["blocks"]["l0"]
    assert set(layer) == {"ckv", "kr"}
    assert layer["ckv"].shape[-1] == cfg.mla.kv_lora
    full_kv = 2 * cfg.n_heads * cfg.head_dim
    assert layer["ckv"].shape[-1] + layer["kr"].shape[-1] < full_kv / 4


def test_param_counts_match_init():
    """cfg.param_counts() total tracks the real initialized count."""
    for arch in ("llama3-8b", "granite-moe-3b-a800m", "jamba-v0.1-52b"):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        n_real = model.n_params()
        n_est = cfg.param_counts()["total"]
        assert abs(n_real - n_est) / n_real < 0.35, (arch, n_real, n_est)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    for name, shape in SHAPES.items():
        if not supports_shape(cfg, name):
            continue
        specs = model.input_specs(shape)
        assert "tokens" in specs
        for s in specs.values():
            assert all(d > 0 for d in s.shape)
