"""Calibration: analyze() vs XLA's cost_analysis() on live lowerings.

The comparable convention is ``count_trips=False`` (XLA counts a while
body once); the acceptance bar is dot-FLOP/FLOP agreement within 5% on
the dot-dominated fixtures.
"""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_fallback import given, settings, st

from repro.roofline import calibrate, hlo_cost


@pytest.fixture(scope="module")
def rows():
    return calibrate.calibrate()


def test_battery_flops_within_5pct(rows):
    gated = [r for r in rows if r.gate]
    assert len(gated) >= 3
    for r in gated:
        assert r.ok(0.05), (r.name, r.deltas)


def test_battery_report_is_well_formed(rows):
    lines = calibrate.report(rows)
    assert len(lines) > len(rows)               # header + trip annotations
    assert all(isinstance(l, str) for l in lines)
    assert "matmul" in "\n".join(lines)


def test_trip_multiplied_terms_scale_by_trip_count(rows):
    by_name = {r.name: r for r in rows}
    scan = by_name["scan"]
    assert scan.ours["dot_flops"] == pytest.approx(
        7 * scan.ours_flat["dot_flops"])
    nested = by_name["nested_scan"]
    assert nested.ours["dot_flops"] == pytest.approx(
        15 * nested.ours_flat["dot_flops"])


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 48), st.integers(8, 48), st.integers(8, 48))
def test_live_matmul_dot_flops_match_xla(m, k, n):
    """Property: on a live-lowered matmul, analyze() dot FLOPs equal the
    analytic 2·M·K·N and agree with cost_analysis() within 5%."""
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.dot_flops == 2 * m * k * n
    xla = calibrate.xla_cost_terms(c)["flops"]
    if xla:                                      # some backends omit it
        assert cost.dot_flops == pytest.approx(xla, rel=0.05)
