"""Shared computation behind the DES golden regression fixtures.

One function produces every paper-validated quantity the fixtures pin
(Fig 10/11 seeded DES sweeps, Fig 15 closed-form unlock points), used
by BOTH ``scripts/gen_des_golden.py`` (writes the fixture) and
``tests/test_des_golden.py`` (asserts current outputs still match) —
so the two can never drift apart.

The DES is deterministic given a seed (one ``random.Random`` threaded
through ``ClusterSim``), so tolerances are tight: refactors that
change scheduling order or float summation order are *supposed* to
trip these tests and force a deliberate fixture regeneration
(``make des-golden``).
"""
from __future__ import annotations

from repro.core.broker import BrokerConfig
from repro.core.queueing import max_stable_speedup
from repro.core.simulator import ClusterSim, FaceRecWorkload

REL_TOL = 1e-7      # DES floats: deterministic modulo FP refactors
ABS_TOL = 1e-12

_SIM_KW = dict(scale=0.04, sim_time=20, warmup=5, seed=0)


def compute_goldens() -> dict:
    out: dict = {"sim_kw": dict(_SIM_KW), "fig10_11": {}, "fig15": {}}
    wl, bk = FaceRecWorkload(), BrokerConfig()
    for s in (1, 2, 4, 6, 8):
        r = ClusterSim(wl, bk, speedup=s, **_SIM_KW).run()
        entry = {
            "unstable": r.unstable,
            "diverged": r.diverged,
            "throughput": r.throughput,
            "waiting_mean": r.waiting_mean,
            "broker_write_util": r.broker_write_util,
            "broker_net_util": r.broker_net_util,
            "messages": r.messages,
            "backlog": r.backlog,
            "unwritten": r.unwritten,
        }
        if not r.unstable:      # inf latencies aren't JSON-comparable
            entry.update(mean_latency=r.mean_latency,
                         p50_latency=r.p50_latency,
                         p95_latency=r.p95_latency,
                         p99_latency=r.p99_latency,
                         waiting_share=r.waiting_share)
        out["fig10_11"][f"S{s}"] = entry
    for d in (1, 2, 3, 4):
        out["fig15"][f"drives{d}"] = max_stable_speedup(
            wl, BrokerConfig(drives_per_broker=d))
    for n in (3, 4, 6, 8):
        out["fig15"][f"brokers{n}"] = max_stable_speedup(
            wl, BrokerConfig(n_brokers=n))
    for frac in (1.0, 0.5, 0.25):
        out["fig15"][f"face_x{frac}"] = max_stable_speedup(
            FaceRecWorkload(face_bytes=37_300 * frac), bk)
    return out
