"""Shared computation behind the DES golden regression fixtures.

One function produces every paper-validated quantity the fixtures pin
(Fig 10/11 seeded DES sweeps, Fig 15 closed-form unlock points), used
by BOTH ``scripts/gen_des_golden.py`` (writes the fixture) and
``tests/test_des_golden.py`` (asserts current outputs still match) —
so the two can never drift apart.

The DES is deterministic given a seed (one ``random.Random`` threaded
through ``ClusterSim``), so tolerances are tight: refactors that
change scheduling order or float summation order are *supposed* to
trip these tests and force a deliberate fixture regeneration
(``make des-golden``).
"""
from __future__ import annotations

from repro.core.broker import BrokerConfig
from repro.core.queueing import max_stable_speedup
from repro.core.simulator import ClusterSim, FaceRecWorkload

REL_TOL = 1e-7      # DES floats: deterministic modulo FP refactors
ABS_TOL = 1e-12

_SIM_KW = dict(scale=0.04, sim_time=20, warmup=5, seed=0)


def compute_goldens() -> dict:
    out: dict = {"sim_kw": dict(_SIM_KW), "fig10_11": {}, "fig15": {}}
    wl, bk = FaceRecWorkload(), BrokerConfig()
    out["fault_kill_revive"] = _fault_golden(wl, bk)
    out["scenarios"] = _scenario_goldens()
    for s in (1, 2, 4, 6, 8):
        r = ClusterSim(wl, bk, speedup=s, **_SIM_KW).run()
        entry = {
            "unstable": r.unstable,
            "diverged": r.diverged,
            "throughput": r.throughput,
            "waiting_mean": r.waiting_mean,
            "broker_write_util": r.broker_write_util,
            "broker_net_util": r.broker_net_util,
            "messages": r.messages,
            "backlog": r.backlog,
            "unwritten": r.unwritten,
        }
        if not r.unstable:      # inf latencies aren't JSON-comparable
            entry.update(mean_latency=r.mean_latency,
                         p50_latency=r.p50_latency,
                         p95_latency=r.p95_latency,
                         p99_latency=r.p99_latency,
                         waiting_share=r.waiting_share)
        out["fig10_11"][f"S{s}"] = entry
    for d in (1, 2, 3, 4):
        out["fig15"][f"drives{d}"] = max_stable_speedup(
            wl, BrokerConfig(drives_per_broker=d))
    for n in (3, 4, 6, 8):
        out["fig15"][f"brokers{n}"] = max_stable_speedup(
            wl, BrokerConfig(n_brokers=n))
    for frac in (1.0, 0.5, 0.25):
        out["fig15"][f"face_x{frac}"] = max_stable_speedup(
            FaceRecWorkload(face_bytes=37_300 * frac), bk)
    return out


def _scenario_goldens() -> dict:
    """Pin the DES half of every library scenario's twin summary.

    Traces are deterministic in (name, horizon, seed) and the DES
    replay is deterministic given the trace, so the fixture pins the
    trace identity (hash + event count), the windowed-p99 trajectory,
    the per-window five-way tax split, and the replay knee — the exact
    quantities the twin gate compares against the live cluster. A
    scheduling or accounting refactor that moves any of them must
    regenerate the fixture deliberately.
    """
    from repro.cluster.crossval import des_twin_summary, scenario_knee
    from repro.cluster.scenarios import SCENARIOS, scenario_spec

    out: dict = {}
    for name in SCENARIOS:
        spec = scenario_spec(name)
        trace = spec.resolve_trace()
        s = des_twin_summary(spec)
        out[name] = {
            "trace_hash": trace.trace_hash(),
            "n_events": trace.n_events,
            "horizon_s": s["horizon_s"],
            "heartbeat_s": s["heartbeat_s"],
            "diverged": s["diverged"],
            "n_heartbeats": len(s["heartbeats"]),
            "windows": s["windows"],
            "five_way": s["five_way"],
            "reliability": s["reliability"],
            "replay_knee": scenario_knee(spec, iters=4),
        }
    return out


def _fault_golden(wl: FaceRecWorkload, bk: BrokerConfig) -> dict:
    """The pinned kill-revive scenario (dynamic-membership DES path).

    At S=6 with the Fig-10 sizing, 30 of the 67 consumers die at t=6
    (consumer rho 0.69 -> 1.25) and 30 fresh members join at t=10: the
    fixture pins the requeue count, the recovery-window tail, and the
    backlog drain at the same 1e-7 tolerance as the legacy quantities
    — same-seed fault runs must stay bit-identical.
    """
    from repro.core.metrics import recovery_report
    from repro.cluster.faults import FaultPlan

    plan = FaultPlan.kill_revive(6.0, 10.0, n=30)
    sim = ClusterSim(wl, bk, speedup=6, fault_plan=plan, **_SIM_KW)
    r = sim.run()
    rep = recovery_report(sim.completions, 6.0, 10.0, window_s=1.0,
                          depth_samples=sim.depth_samples)
    return {
        "t_kill": 6.0, "t_revive": 10.0, "n_killed": 30, "speedup": 6,
        "requeues": r.requeues,
        "fault_events": r.fault_events,
        "final_consumers": r.final_consumers,
        "messages": r.messages,
        "throughput": r.throughput,
        "backlog": r.backlog,
        "unwritten": r.unwritten,
        "diverged": r.diverged,
        "baseline_p99": rep.baseline_p99,
        "spike_p99": rep.spike_p99,
        "recovery_s": rep.recovery_s,
        "drain_s": rep.drain_s,
    }
