"""Trace format, replay-pacing and recorder invariants.

The trace is the digital-twin contract: one validated JSONL timeline
drives both engines, so the format must reject anything ambiguous
(out-of-order, truncated, version-skewed) and the pure replay algebra
must hold exactly — replaying at speed s is the SAME schedule as
replaying the rescaled trace at 1x, and the recorder round-trips a
load generator's arrivals bit-for-bit.
"""
import json

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from hypothesis_fallback import given, settings, st

from repro.cluster.trace import (DEFAULT_PAYLOAD_BYTES, TraceError,
                                 TraceEvent, TraceReplayProducer,
                                 WorkloadTrace, record_loadgen)


def _trace(events=None, **kw):
    if events is None:
        events = (TraceEvent(0.5, 0), TraceEvent(1.0, 1, partition_key=3),
                  TraceEvent(1.0, 2), TraceEvent(3.25, 3, payload_bytes=9.0))
    base = dict(name="t", horizon_s=4.0, heartbeat_s=0.5, events=events)
    base.update(kw)
    return WorkloadTrace(**base)


class FakeClock:
    """Deterministic now/sleep pair for the pacing loop."""

    def __init__(self, t0: float = 100.0, tick: float = 1e-4):
        self.t = t0
        self.tick = tick      # time cost of a now() poll
        self.slept: list[float] = []

    def now(self) -> float:
        self.t += self.tick
        return self.t

    def sleep(self, dt: float) -> None:
        self.slept.append(dt)
        self.t += dt


def _replay(trace, speed=1.0, compression=8.0, deadline=1e9):
    """Run the pacing loop on a fake clock, return (producer, publishes)."""
    prod = TraceReplayProducer(trace, speed_factor=speed)
    clk = FakeClock()
    out: list[tuple[int, float]] = []
    n = prod.run_live(clk.now(), deadline, compression,
                      lambda ev, t_rep: out.append((ev.rid, t_rep)),
                      now=clk.now, sleep=clk.sleep)
    assert n == len(out)
    return prod, out


# ---- format validation -----------------------------------------------------

def test_rejects_out_of_order_events():
    with pytest.raises(TraceError, match="out of order"):
        _trace(events=(TraceEvent(1.0, 0), TraceEvent(0.5, 1)))


def test_rejects_duplicate_rids_and_horizon_overrun():
    with pytest.raises(TraceError, match="duplicate rid"):
        _trace(events=(TraceEvent(0.5, 7), TraceEvent(0.6, 7)))
    with pytest.raises(TraceError, match="beyond horizon"):
        _trace(events=(TraceEvent(5.0, 0),))


def test_rejects_version_mismatch_and_bad_fields():
    with pytest.raises(TraceError, match="unsupported trace version"):
        _trace(version=2)
    with pytest.raises(TraceError, match="t must be >= 0"):
        TraceEvent(-0.1, 0)
    with pytest.raises(TraceError, match="payload_bytes"):
        TraceEvent(0.0, 0, payload_bytes=0.0)
    with pytest.raises(TraceError, match="horizon_s"):
        _trace(horizon_s=0.0)


def test_jsonl_round_trip_preserves_trace_and_hash(tmp_path):
    tr = _trace()
    p = tmp_path / "t.jsonl"
    tr.to_jsonl(p)
    back = WorkloadTrace.from_jsonl(p)
    assert back == tr
    assert back.trace_hash() == tr.trace_hash()
    # content hash actually covers content
    other = _trace(events=tr.events[:-1] + (TraceEvent(3.25, 99),))
    assert other.trace_hash() != tr.trace_hash()


@pytest.mark.parametrize("mutate, match", [
    (lambda L: [], "empty trace file"),
    (lambda L: ["not json"] + L[1:], "not valid JSON"),
    (lambda L: [json.dumps({"format": "other"})] + L[1:],
     "missing 'repro-trace' header"),
    (lambda L: [L[0].replace('"version": 1', '"version": 99')] + L[1:],
     "unsupported trace version"),
    (lambda L: [json.dumps({"format": "repro-trace", "version": 1})] + L[1:],
     "missing required field"),
    (lambda L: L[:1] + ["{bad"] + L[2:], "not valid JSON"),
    (lambda L: L[:1] + [json.dumps({"t": 0.5})] + L[2:], "bad event"),
    (lambda L: [L[0], L[2], L[1]] + L[3:], "out-of-order event"),
    (lambda L: L[:-1], "truncated or padded"),
])
def test_from_jsonl_rejects_malformed_files(tmp_path, mutate, match):
    p = tmp_path / "t.jsonl"
    _trace().to_jsonl(p)
    lines = p.read_text().splitlines()
    p.write_text("\n".join(mutate(lines)) + "\n")
    with pytest.raises(TraceError, match=match):
        WorkloadTrace.from_jsonl(p)


# ---- replay algebra --------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.floats(0.25, 8.0))
def test_rescale_equals_speed_factor_replay(s):
    """timeline() at speed s == rescaled-trace timeline at speed 1."""
    tr = _trace()
    fast = TraceReplayProducer(tr, speed_factor=s).timeline()
    flat = TraceReplayProducer(tr.rescale(s), speed_factor=1.0).timeline()
    assert len(fast) == len(flat)
    for (ta, ea), (tb, eb) in zip(fast, flat):
        assert ta == pytest.approx(tb, rel=1e-12)
        assert (ea.rid, ea.partition_key, ea.payload_bytes) == \
            (eb.rid, eb.partition_key, eb.payload_bytes)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.5, 4.0))
def test_rescale_preserves_window_structure(s):
    tr = _trace()
    rs = tr.rescale(s)
    assert rs.n_windows == tr.n_windows
    assert rs.n_events == tr.n_events
    assert rs.offered_rate == pytest.approx(tr.offered_rate * s)
    # window index of every event is invariant under rescale
    for ev, rv in zip(tr.events, rs.events):
        assert int(ev.t / tr.heartbeat_s + 1e-9) == \
            int(rv.t / rs.heartbeat_s + 1e-9)


def test_rescale_identity_and_validation():
    tr = _trace()
    assert tr.rescale(1.0) is tr
    with pytest.raises(TraceError):
        tr.rescale(0.0)
    with pytest.raises(TraceError):
        TraceReplayProducer(tr, speed_factor=-1.0)


def test_live_pacing_publishes_everything_in_order():
    tr = _trace()
    prod, out = _replay(tr)
    assert [rid for rid, _ in out] == [ev.rid for ev in tr.events]
    assert [t for _, t in out] == [ev.t for ev in tr.events]
    # heartbeats cover the whole horizon in order, incl. trailing windows
    assert [w for w, _ in prod.heartbeats] == list(range(1, 9))
    assert prod.heartbeats[-1] == (8, pytest.approx(4.0))


@settings(max_examples=10, deadline=None)
@given(st.floats(0.5, 4.0))
def test_live_pacing_speed_factor_equivalence(s):
    """run_live at speed s publishes the same rid sequence, at replay
    times scaled by 1/s, as the rescaled trace at speed 1."""
    tr = _trace()
    _, fast = _replay(tr, speed=s)
    _, flat = _replay(tr.rescale(s), speed=1.0)
    assert [r for r, _ in fast] == [r for r, _ in flat]
    for (_, ta), (_, tb) in zip(fast, flat):
        assert ta == pytest.approx(tb, rel=1e-9)


def test_live_pacing_respects_wall_deadline():
    tr = _trace()
    clk = FakeClock()
    t0 = clk.now()
    prod = TraceReplayProducer(tr)
    out = []
    # deadline lands between the first event (t=0.5 -> wall t0+0.0625)
    # and the t=1.0 pair
    n = prod.run_live(t0, t0 + 0.1, 8.0,
                      lambda ev, t: out.append(ev.rid),
                      now=clk.now, sleep=clk.sleep)
    assert n == len(out) == 1 and out == [0]


def test_record_loadgen_round_trip():
    from repro.cluster.loadgen import OpenLoopLoadGen

    gen = OpenLoopLoadGen(n_producers=3, period_s=0.2,
                          process="poisson", seed=7)
    tr = record_loadgen(gen, 4.0, name="rt")
    assert tr.name == "rt" and tr.horizon_s == 4.0
    assert tr.heartbeat_s == pytest.approx(0.5)
    # every producer's arrivals present under the live rid convention
    want = sorted((t, p + k * gen.n_producers)
                  for p in range(gen.n_producers)
                  for k, t in enumerate(gen.schedule(p, 4.0)))
    assert [(ev.t, ev.rid) for ev in tr.events] == want
    assert all(ev.payload_bytes == DEFAULT_PAYLOAD_BYTES
               for ev in tr.events)
    # unkeyed recording round-robins across partitions deterministically
    counts = tr.partition_counts(4)
    assert sum(counts.values()) == tr.n_events
    assert max(counts.values()) - min(counts.values()) <= 1
    # replaying the recording reproduces it exactly
    _, out = _replay(tr)
    assert [(rid, t) for rid, t in out] == \
        [(ev.rid, ev.t) for ev in tr.events]


def test_committed_example_trace_loads_and_hashes_stably():
    """The checked-in fixture is the portable-format regression: it was
    written by an earlier revision, so today's parser must still accept
    it and today's hash must still match — hash drift would silently
    invalidate every persisted TwinCache entry."""
    import pathlib

    p = pathlib.Path(__file__).parent / "fixtures" / "trace_smoke.jsonl"
    tr = WorkloadTrace.from_jsonl(p)
    assert tr.name == "smoke" and tr.n_events == 35
    assert tr.trace_hash() == "e9642dcdab94e2ad"
    _, out = _replay(tr)
    assert len(out) == 35


def test_partition_counts_pin_keys_and_round_robin_unkeyed():
    tr = _trace(events=(TraceEvent(0.1, 0, partition_key=5),
                        TraceEvent(0.2, 1),
                        TraceEvent(0.3, 2, partition_key=5),
                        TraceEvent(0.4, 3),
                        TraceEvent(0.5, 4)))
    # keys pin key % n; the round-robin counter advances ONLY on
    # unkeyed events: rids 1, 3, 4 -> partitions 0, 1, 2
    assert tr.partition_counts(3) == {0: 1, 1: 1, 2: 3}
