"""Perf-infrastructure tests: variants registry, flash-traffic accounting,
grad accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.variants import VARIANTS, get_variant
from repro.models.model import build_model
from repro.roofline import hlo_cost
from repro.roofline.analysis import kernel_ideal_bytes
from repro.configs.base import SHAPES
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def test_variant_registry():
    assert "baseline" in VARIANTS
    base = get_variant("baseline")
    assert base.train_rules["attn_q"] is None       # true baseline
    assert get_variant("attn_q").train_rules["attn_q"] == "model"
    with pytest.raises(KeyError):
        get_variant("nope")


def test_flashable_scope_bytes_are_tracked():
    """Tagged attention region bytes land in the flash bucket."""
    from repro.kernels import ops

    def f(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="xla")

    shapes = [jax.ShapeDtypeStruct((2, 128, 4, 32), jnp.float32),
              jax.ShapeDtypeStruct((2, 128, 2, 32), jnp.float32),
              jax.ShapeDtypeStruct((2, 128, 2, 32), jnp.float32)]
    c = jax.jit(f).lower(*shapes).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flash_bytes > 0
    assert cost.flash_bytes <= cost.hbm_bytes


def test_dus_inplace_accounting():
    """A scan that only updates one row per step must NOT charge the whole
    carry buffer per iteration."""
    def f(buf, xs):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(
                b, xs[i][None], i, axis=0), ()
        b, _ = jax.lax.scan(body, buf, jnp.arange(16))
        return b

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((16, 1024), jnp.float32),
                         jax.ShapeDtypeStruct((16, 1024), jnp.float32)
                         ).compile()
    cost = hlo_cost.analyze(c.as_text())
    full_buffer_per_step = 16 * 16 * 1024 * 4
    assert cost.hbm_bytes < full_buffer_per_step, cost.hbm_bytes


def test_kernel_ideal_bytes_sane():
    cfg = get_config("llama3-8b")
    dec = kernel_ideal_bytes(cfg, SHAPES["decode_32k"], 256)
    # decode: ~cache read once per step
    cache = 128 * 32768 * 2 * 8 * 128 * 2 * 32 / 256
    assert 0.5 * cache <= dec <= 2.0 * cache
    tr = kernel_ideal_bytes(cfg, SHAPES["train_4k"], 256)
    assert tr > dec


def test_grad_accum_matches_full_batch():
    cfg = get_config("llama3-8b", smoke=True).replace(
        n_layers=1, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    k = jax.random.PRNGKey(1)
    toks = jax.random.randint(k, (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    hp = AdamWConfig()
    sh = type("S", (), {"mesh": None, "rules": None})()
    s1 = make_train_step(model, hp, sh, grad_accum=1)
    s4 = make_train_step(model, hp, sh, grad_accum=4)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, opt, batch)
    # microbatch losses average to the full-batch loss and params agree
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
