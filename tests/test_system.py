"""End-to-end behaviour tests for the paper's system.

The paper's thesis, exercised on this framework end to end:
 1. an AI application is more than its AI kernels (tax > 0 in a real
    running pipeline);
 2. accelerating only the AI shifts the bottleneck into the substrate
    (DES destabilizes at the paper's acceleration factor);
 3. a substrate designed from the tax analysis fixes it at lower TCO.
Plus the framework glue: train -> checkpoint -> serve with one model, and
the compressed-gradient collective.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.broker import BrokerConfig
from repro.core.pipeline import StreamingPipeline
from repro.core.queueing import max_stable_speedup
from repro.core.simulator import ClusterSim, FaceRecWorkload
from repro.core.tco import paper_comparison
from repro.data.tokens import TokenLoader
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.trainer import Trainer, TrainerConfig


def test_thesis_1_ai_tax_exists_in_live_pipeline():
    r = StreamingPipeline(n_frames=25, seed=3).run()
    tax = r.ai_tax()
    assert tax["tax_fraction"] > 0.05
    assert r.recall > 0.6


def test_thesis_2_acceleration_shifts_bottleneck_to_substrate():
    wl, bk = FaceRecWorkload(), BrokerConfig()
    base = ClusterSim(wl, bk, speedup=1, scale=0.04, sim_time=15,
                      warmup=4).run()
    fast = ClusterSim(wl, bk, speedup=8, scale=0.04, sim_time=15,
                      warmup=4).run()
    assert not base.unstable and fast.unstable
    assert fast.broker_write_util > 4 * base.broker_write_util
    assert fast.broker_net_util < 0.1     # network is NOT the bottleneck


def test_thesis_3_purpose_built_design_fixes_it_cheaper():
    wl = FaceRecWorkload()
    # the purpose-built brokers (4 drives) support the paper's 32x target
    assert max_stable_speedup(wl, BrokerConfig(drives_per_broker=4)) >= 32
    assert paper_comparison().saving_fraction >= 0.15


def test_full_lifecycle_train_checkpoint_serve(tmp_path):
    """One model: train it, checkpoint, restore, serve it."""
    cfg = get_config("llama3-8b", smoke=True).replace(
        n_layers=2, d_model=64, vocab_size=64, dtype="float32")
    model = build_model(cfg)
    hp = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt, gn = adamw_update(grads, opt, params, hp)
        return params, opt, {"loss": loss, "grad_norm": gn,
                             "step": opt.count}

    loader = TokenLoader(cfg.vocab_size, batch=8, seq_len=32)
    tc = TrainerConfig(steps=30, ckpt_every=15, log_every=1000,
                       ckpt_dir=str(tmp_path / "ck"))
    trainer = Trainer(model, jax.jit(step), loader, tc)
    params, _, hist = trainer.run()
    assert hist[-1]["loss"] < hist[0]["loss"]

    # restore in a "fresh process" and serve
    t2 = Trainer(model, jax.jit(step), loader, tc)
    params2, _, start = t2.restore_or_init()
    assert start == 30
    eng = ServingEngine(model, params2, batch_slots=2, cache_len=48)
    src = loader.next_batch()["tokens"][0, :12]
    eng.submit(Request(0, np.asarray(src), max_tokens=5))
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 5


def test_compressed_gradient_collective_preserves_convergence():
    """int8 EF-compressed gradients: quadratic still converges."""
    from repro.distributed.collectives import compress_grads, dequantize_int8
    params = jnp.asarray([2.0, -3.0, 1.5])
    err = None
    lr = 0.2
    for _ in range(120):
        g = {"w": 2 * params}
        q, s, err = compress_grads(g, err)
        deq = jax.tree.map(dequantize_int8, q, s)
        params = params - lr * deq["w"]
    assert float(jnp.sum(params ** 2)) < 1e-2


def test_taxmeter_on_real_step():
    from repro.core.taxmeter import TaxedStep
    from repro.core.events import EventLog
    ts = TaxedStep(EventLog())
    f = jax.jit(lambda x: jnp.tanh(x @ x.T).sum())
    x = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    out = ts.run(0, compute=f, payload=x)
    rep = ts.breakdown()
    assert "step/compute" in rep["per_stage"]
    assert "step/h2d" in rep["per_stage"]
    assert 0.0 < rep["ai_fraction"] <= 1.0
