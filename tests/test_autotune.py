"""Autotuner: candidate validity, cache hit/miss determinism, dispatch."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune, ops, ref
from repro.roofline import hw


@pytest.fixture
def cache(tmp_path):
    return autotune.AutotuneCache(path=tmp_path / "cache.json",
                                  seed_path=None)


def test_matmul_candidates_fit_vmem_and_clamp():
    for M, K, N in [(512, 3072, 256), (16, 128, 64), (2048, 8192, 4096)]:
        cands = autotune.matmul_candidates(M, K, N)
        assert cands
        for c in cands:
            bm, bn, bk = c["blk_m"], c["blk_n"], c["blk_k"]
            assert bm <= autotune._round_up(M, hw.SUBLANE)
            assert bn <= autotune._round_up(N, hw.LANE)
            assert bk <= autotune._round_up(K, hw.LANE)
            vmem = 2 * (bm * bk + bk * bn) * 4 + bm * bn * 8
            assert vmem <= autotune._VMEM_BUDGET


def test_matmul_tiling_miss_then_hit(cache, monkeypatch):
    t1 = autotune.matmul_tiling(512, 3072, 256, cache=cache)
    assert set(t1) == {"blk_m", "blk_n", "blk_k"}
    assert cache.path.is_file()
    # a hit must not re-run the sweep: poison the scorer
    monkeypatch.setattr(autotune, "matmul_cost_us",
                        lambda *a, **k: 1 / 0)
    t2 = autotune.matmul_tiling(512, 3072, 256, cache=cache)
    assert t2 == t1


def test_matmul_tiling_persists_across_cache_objects(cache):
    t1 = autotune.matmul_tiling(512, 3072, 256, cache=cache)
    fresh = autotune.AutotuneCache(path=cache.path, seed_path=None)
    entry = fresh.lookup(autotune.matmul_key(512, 3072, 256, "float32"))
    assert entry is not None and entry["blocks"] == t1


def test_matmul_tiling_deterministic(tmp_path):
    a = autotune.AutotuneCache(path=tmp_path / "a.json", seed_path=None)
    b = autotune.AutotuneCache(path=tmp_path / "b.json", seed_path=None)
    for M, K, N in [(512, 3072, 256), (64, 200, 48), (1, 6912, 256)]:
        assert autotune.matmul_tiling(M, K, N, cache=a) == \
            autotune.matmul_tiling(M, K, N, cache=b)


def test_m_bucketing_shares_keys():
    """Ragged batch rows land in the same pow2 bucket as the padded
    call facerec actually makes, so one tuning serves the whole bucket."""
    assert autotune.matmul_key(5, 3072, 256, "float32") == \
        autotune.matmul_key(8, 3072, 256, "float32")
    assert autotune.matmul_key(8, 3072, 256, "float32") != \
        autotune.matmul_key(16, 3072, 256, "float32")


def test_corrupt_cache_is_empty_cache(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    c = autotune.AutotuneCache(path=p, seed_path=None)
    assert c.lookup("anything") is None
    t = autotune.matmul_tiling(64, 128, 128, cache=c)
    assert set(t) == {"blk_m", "blk_n", "blk_k"}
    assert json.loads(p.read_text())   # rewritten valid


def test_seed_cache_overlay(tmp_path):
    seed = tmp_path / "seed.json"
    key = autotune.matmul_key(512, 3072, 256, "float32")
    seed.write_text(json.dumps(
        {key: {"blocks": {"blk_m": 8, "blk_n": 128, "blk_k": 128},
               "v": autotune.SCHEMA_VERSION}}))
    c = autotune.AutotuneCache(path=tmp_path / "user.json", seed_path=seed)
    assert autotune.matmul_tiling(512, 3072, 256, cache=c) == \
        {"blk_m": 8, "blk_n": 128, "blk_k": 128}
    assert not (tmp_path / "user.json").is_file()   # hit: nothing written


def test_stale_schema_entries_ignored(tmp_path):
    """An overlay written under an older schema can't shadow a refresh:
    its entries are dropped at load and re-tuned under the new stamp."""
    p = tmp_path / "stale.json"
    key = autotune.matmul_key(512, 3072, 256, "float32")
    p.write_text(json.dumps(
        {key: {"blocks": {"blk_m": 7, "blk_n": 100, "blk_k": 100},
               "v": autotune.SCHEMA_VERSION - 1}}))
    c = autotune.AutotuneCache(path=p, seed_path=None)
    assert c.lookup(key) is None
    fresh = autotune.matmul_tiling(512, 3072, 256, cache=c)
    assert fresh != {"blk_m": 7, "blk_n": 100, "blk_k": 100}
    assert json.loads(p.read_text())[key]["v"] == autotune.SCHEMA_VERSION


def test_resize_and_attention_tilings(cache):
    r = autotune.resize_tiling(216, 384, 108, 192, cache=cache)
    assert 1 <= r["blk_oh"] <= 108
    at = autotune.attention_tiling(2048, 2048, 128, cache=cache)
    assert 2048 % at["blk_q"] == 0 and 2048 % at["blk_k"] == 0
    # prime length: candidates clamp to the full sequence, which divides
    at_p = autotune.attention_tiling(127, 127, 64, cache=cache)
    assert 127 % at_p["blk_q"] == 0 and 127 % at_p["blk_k"] == 0


def test_committed_seed_matches_battery():
    """`make autotune` output is committed; this is --check as a test."""
    committed = json.loads(autotune.SEED_PATH.read_text())
    swept = autotune.hot_path_battery()
    assert {k: v["blocks"] for k, v in committed.items()} == \
        {k: v["blocks"] for k, v in swept.items()}


def test_tuned_matmul_matches_ref(cache, monkeypatch):
    monkeypatch.setattr(autotune, "_CACHE", cache)
    a = jnp.asarray(np.random.default_rng(0).normal(size=(13, 200)),
                    jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(200, 37)),
                    jnp.float32)
    out = ops.matmul(a, b, impl="pallas_interpret")   # tuned blocks
    np.testing.assert_allclose(out, ref.matmul(a, b), atol=1e-4, rtol=1e-4)
    key = autotune.matmul_key(13, 200, 37, "float32")
    assert cache.lookup(key) is not None
