"""Golden regression gate for the DES (paper Figs 10/11/15 quantities).

The committed fixture pins seeded ``FaceRecWorkload`` runs and the
closed-form unlock points, so cluster refactors can't silently shift
paper-validated numbers. A legitimate simulator change regenerates the
fixture with ``make des-golden`` — a diff there is a reviewable event.
"""
import json
import math
import pathlib

import pytest

from golden_des import ABS_TOL, REL_TOL, compute_goldens

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "des_golden.json"


@pytest.fixture(scope="module")
def golden():
    return json.loads(FIXTURE.read_text())


@pytest.fixture(scope="module")
def current():
    return compute_goldens()


def _assert_close(path: str, want, got):
    if isinstance(want, float):
        assert math.isclose(got, want, rel_tol=REL_TOL, abs_tol=ABS_TOL), \
            f"{path}: fixture={want!r} current={got!r}"
    elif isinstance(want, (list, tuple)):
        assert len(got) == len(want), f"{path}: length {len(got)} != " \
            f"{len(want)}"
        for i, (w, g) in enumerate(zip(want, got)):
            _assert_close(f"{path}[{i}]", w, g)
    elif isinstance(want, dict):
        assert set(got) == set(want), f"{path}: keys differ"
        for k, w in want.items():
            _assert_close(f"{path}.{k}", w, got[k])
    else:
        assert got == want, f"{path}: fixture={want!r} current={got!r}"


def test_fixture_exists_and_covers_the_sweep(golden):
    assert set(golden["fig10_11"]) == {"S1", "S2", "S4", "S6", "S8"}
    assert len(golden["fig15"]) == 11
    assert "fault_kill_revive" in golden
    assert set(golden["scenarios"]) == {"diurnal", "flash_crowd",
                                        "camera_fleet", "burst_drain"}


def test_fault_kill_revive_matches_fixture(golden, current):
    want, got = golden["fault_kill_revive"], current["fault_kill_revive"]
    assert set(got) == set(want)
    for field, value in want.items():
        _assert_close(f"fault_kill_revive.{field}", value, got[field])


def test_fault_fixture_pins_the_recovery_story(golden):
    """The fault fixture must keep encoding the scenario's semantics:
    work is requeued (never dropped), the outage spikes the windowed
    tail well above baseline, and the cluster recovers inside the run
    (finite recovery/drain, no divergence verdict)."""
    f = golden["fault_kill_revive"]
    assert f["requeues"] > 0
    assert f["fault_events"] == 60
    assert f["final_consumers"] == 67          # all 30 revived
    assert not f["diverged"]
    assert f["spike_p99"] > 3 * f["baseline_p99"]
    assert 0 < f["recovery_s"] < 10.0          # finite, inside the run
    assert 0 < f["drain_s"] < 10.0


def test_fig10_11_des_quantities_match_fixture(golden, current):
    for s_key, want in golden["fig10_11"].items():
        got = current["fig10_11"][s_key]
        assert set(got) == set(want), s_key
        for field, value in want.items():
            _assert_close(f"fig10_11.{s_key}.{field}", value, got[field])


def test_fig15_unlock_points_match_fixture(golden, current):
    for cfg, want in golden["fig15"].items():
        _assert_close(f"fig15.{cfg}", want, current["fig15"][cfg])


def test_scenario_twin_summaries_match_fixture(golden, current):
    for name, want in golden["scenarios"].items():
        got = current["scenarios"][name]
        assert set(got) == set(want), name
        for field, value in want.items():
            _assert_close(f"scenarios.{name}.{field}", value, got[field])


def test_scenario_fixture_pins_the_library_semantics(golden):
    """The scenario fixture must keep encoding what the library
    promises: every trace replays stably at S=1 (knee at or below 1),
    the DES half populates the full heartbeat grid, and each window's
    five-way tax split is a proper partition of unity."""
    for name, f in golden["scenarios"].items():
        assert not f["diverged"], name
        assert f["replay_knee"] <= 1.0, name
        assert f["n_heartbeats"] == round(f["horizon_s"]
                                          / f["heartbeat_s"]), name
        assert len(f["windows"]) >= 6, name
        for k, fw in f["five_way"].items():
            s = sum(fw.values())
            # the final heartbeat fires exactly at the horizon and
            # opens a boundary window holding only zero-duration
            # markers — that one may sum to 0, every other must be a
            # partition of unity
            ok = math.isclose(s, 1.0, rel_tol=1e-9) or (
                s == 0.0 and int(k) * f["heartbeat_s"] >= f["horizon_s"])
            assert ok, f"{name} window {k}: five-way sums to {s}"


def test_fixture_pins_the_paper_claims(golden):
    """The fixture itself must keep encoding the paper's headline
    numbers — a regeneration that drifts away from them is wrong even
    if internally consistent."""
    f = golden["fig10_11"]
    assert not f["S6"]["unstable"] and f["S8"]["unstable"]
    assert 0.07 <= f["S1"]["broker_write_util"] <= 0.13    # paper: ~10%
    assert f["S8"]["broker_net_util"] < 0.10               # Fig 11a
    g = golden["fig15"]
    assert g["drives1"] < 8.0 <= g["drives2"]
    assert g["drives4"] >= 32.0                            # paper: 32x @ 4
