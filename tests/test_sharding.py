"""Sharding-rule unit tests + an 8-device numerical-equivalence check
(sharded train step == single-device train step) run in a subprocess so
the main test process keeps its single CPU device."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import Mesh, PartitionSpec as PS

import numpy as np

from repro.distributed import sharding as shd


def _mesh2d(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    devs = np.array([jax.devices()[0]] * n).reshape(shape)  # spec-only mesh
    return Mesh(devs, axes)


class _FakeMesh:
    """Shape-only mesh stand-in for spec_for tests."""
    def __init__(self, shape):
        self.shape = shape


def test_spec_for_basic():
    mesh = _FakeMesh({"data": 4, "model": 8})
    rules = dict(shd.TRAIN_RULES)
    spec = shd.spec_for(("embed", "mlp"), (64, 128), mesh, rules)
    assert spec == PS("data", "model")


def test_spec_for_drops_indivisible():
    mesh = _FakeMesh({"data": 4, "model": 16})
    rules = dict(shd.TRAIN_RULES)
    # 40 heads don't divide 16 -> axis dropped
    spec = shd.spec_for(("embed", "heads"), (64, 40), mesh, rules)
    assert spec == PS("data")
    # kv_heads=8 on 16-way axis -> dropped
    spec = shd.spec_for((None, "kv_seq", "kv_heads", None),
                        (8, 1024, 8, 128), mesh,
                        dict(shd.SERVE_RULES))
    assert spec == PS(None, "model")


def test_spec_for_no_duplicate_mesh_axes():
    mesh = _FakeMesh({"data": 4, "model": 16})
    rules = dict(shd.TRAIN_RULES)
    # experts and mlp both map to model; only the first may take it
    spec = shd.spec_for(("experts", "embed", "mlp"), (160, 64, 1536),
                        mesh, rules)
    assert spec == PS("model", "data")


def test_spec_for_multi_axis_batch():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = shd.spec_for(("batch", None), (256, 128), mesh,
                        dict(shd.TRAIN_RULES))
    assert spec == PS(("pod", "data"))


def test_shard_outside_context_is_identity():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shd.shard(x, "batch", None) is x


SUBPROCESS_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.distributed import sharding as shd
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import make_train_shardings, make_train_step

    cfg = get_config("llama3-8b", smoke=True).replace(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    toks = jax.random.randint(k, (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    hp = AdamWConfig()
    opt = init_opt_state(params)

    # single-device reference
    step_ref = make_train_step(model, hp, type("S", (), {
        "mesh": None, "rules": None, "params": None})())
    def ref_step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        from repro.train.optimizer import adamw_update
        return adamw_update(grads, opt, params, hp)
    p_ref, o_ref, g_ref = jax.jit(ref_step)(params, opt, batch)

    # sharded on a (2, 4) mesh
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    sh = make_train_shardings(model, mesh, batch_specs={
        kk: jax.ShapeDtypeStruct(v.shape, v.dtype) for kk, v in batch.items()})
    step = make_train_step(model, hp, sh)
    jstep = jax.jit(step, in_shardings=(sh.params, type(o_ref)(
        m=sh.params, v=sh.params, count=NamedSharding(mesh, PS())), sh.batch))
    p_sh, o_sh, metrics = jstep(params, opt, batch)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
    print("EQUIV_OK gradnorm", float(metrics["grad_norm"]))
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_EQUIV],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "EQUIV_OK" in r.stdout, r.stdout + r.stderr
