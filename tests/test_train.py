"""Training loop: optimizer properties, loss decrease, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # deterministic single-example shim
    from hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.data.tokens import TokenLoader
from repro.models.model import build_model
from repro.train.optimizer import (
    AdamWConfig, adamw_update, global_norm, init_opt_state, schedule,
)
from repro.train.trainer import Trainer, TrainerConfig, Watchdog


def test_adamw_minimizes_quadratic():
    hp = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                     total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, hp)
    assert float(loss(params)) < 1e-2


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 10.0))
def test_grad_clipping_property(scale):
    """Post-clip effective grad norm never exceeds clip_norm."""
    hp = AdamWConfig(clip_norm=1.0)
    g = {"a": jnp.ones((4, 4)) * scale}
    gn = global_norm(g)
    clip_scale = min(1.0, 1.0 / float(gn + 1e-9))
    assert float(gn) * clip_scale <= 1.0 + 1e-6


def test_schedule_warmup_and_decay():
    hp = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(schedule(hp, jnp.asarray(5))) < hp.lr
    assert float(schedule(hp, jnp.asarray(10))) == pytest.approx(hp.lr, rel=1e-3)
    assert float(schedule(hp, jnp.asarray(100))) == pytest.approx(
        hp.lr * hp.min_lr_ratio, rel=1e-3)


def test_loss_decreases_on_tiny_lm(tmp_path):
    cfg = get_config("llama3-8b", smoke=True).replace(
        n_layers=2, d_model=64, vocab_size=64, dtype="float32")
    model = build_model(cfg)
    loader = TokenLoader(cfg.vocab_size, batch=8, seq_len=32)
    hp = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt, gn = adamw_update(grads, opt, params, hp)
        return params, opt, {"loss": loss, "grad_norm": gn,
                             "step": opt.count}

    tc = TrainerConfig(steps=40, ckpt_every=100, log_every=100,
                       ckpt_dir=str(tmp_path / "ck"))
    trainer = Trainer(model, jax.jit(step), loader, tc)
    _, _, hist = trainer.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_trainer_restart_resumes_from_checkpoint(tmp_path):
    cfg = get_config("llama3-8b", smoke=True).replace(
        n_layers=1, d_model=32, vocab_size=32, dtype="float32")
    model = build_model(cfg)
    hp = AdamWConfig(lr=1e-3)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt, gn = adamw_update(grads, opt, params, hp)
        return params, opt, {"loss": loss, "grad_norm": gn,
                             "step": opt.count}

    def make(steps):
        return Trainer(model, jax.jit(step),
                       TokenLoader(cfg.vocab_size, batch=4, seq_len=16),
                       TrainerConfig(steps=steps, ckpt_every=5,
                                     log_every=1000,
                                     ckpt_dir=str(tmp_path / "ck")))

    t1 = make(10)
    t1.run()                                    # writes step_10
    t2 = make(14)                               # "restarted" job
    params, opt, hist = t2.run()
    assert hist[0]["step"] == 11                # resumed, not restarted
    assert int(opt.count) == 14


def test_watchdog_detects_hang():
    import time
    dog = Watchdog(timeout=0.2).start()
    time.sleep(0.7)
    dog.stop()
    assert len(dog.hangs) >= 1


def test_loader_is_seekable_and_deterministic():
    l1 = TokenLoader(64, batch=4, seq_len=8)
    batches = [l1.next_batch() for _ in range(3)]
    l2 = TokenLoader(64, batch=4, seq_len=8)
    l2.seek(2)
    b2 = l2.next_batch()
    np.testing.assert_array_equal(batches[2]["tokens"], b2["tokens"])


def test_loader_host_sharding_partitions_batch():
    full = TokenLoader(64, batch=8, seq_len=8).next_batch()
    h0 = TokenLoader(64, batch=8, seq_len=8, host_index=0,
                     host_count=2).next_batch()
    h1 = TokenLoader(64, batch=8, seq_len=8, host_index=1,
                     host_count=2).next_batch()
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])
