"""Shared test fixtures."""
import pytest

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _hermetic_autotune_cache(tmp_path):
    """Point the process-wide autotune cache at a per-test overlay.

    Tests that resolve tilings through ops dispatch (blk_*=None) must
    neither read a developer's ~/.cache overlay (entries there could
    silently change which blocks a test exercises) nor write to $HOME.
    The committed seed stays readable, so hot-path shapes still hit it.
    """
    prev = autotune._CACHE
    autotune.set_cache(autotune.AutotuneCache(path=tmp_path / "tune.json"))
    yield
    autotune.set_cache(prev)
