"""Live streaming pipeline (the paper's app, actually running on CPU)."""
import pytest

from repro.core.pipeline import StreamingPipeline


@pytest.fixture(scope="module")
def result():
    return StreamingPipeline(n_frames=40, fuse_ingest_detect=True,
                             n_identify_workers=2, seed=0).run()


def test_pipeline_detects_faces(result):
    assert result.ground_truth > 5
    assert result.recall >= 0.7, (result.matched, result.ground_truth)


def test_pipeline_identifies_every_detection(result):
    assert len(result.identities) == result.detected


def test_pipeline_tax_breakdown(result):
    tax = result.ai_tax()
    stages = set(tax["per_stage"])
    assert {"ingest", "detect"} <= stages
    assert 0.0 < tax["ai_fraction"] < 1.0
    # the paper's central claim at the live-pipeline level: supporting
    # work (ingest/resize/wait) is a non-trivial share of latency
    assert tax["tax_fraction"] > 0.05


def test_three_stage_deployment_also_works():
    r = StreamingPipeline(n_frames=15, fuse_ingest_detect=False,
                          n_identify_workers=1, seed=1).run()
    assert r.detected == len(r.identities)
    # the extra broker hop shows up as a wait_frames stage (Fig 3a)
    assert "wait_frames" in r.log.breakdown()
