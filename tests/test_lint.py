"""The static-analysis suite: fixture battery + self-lint.

Two contracts:

  * each ``tests/fixtures/lint/*_bad.py`` snippet trips exactly its
    intended rule (and the ``*_good.py`` twin is clean) — the rules
    stay sharp in both directions;
  * ``src/repro`` itself lints clean modulo the committed
    ``lint_baseline.json``, and every waiver everywhere carries a
    non-empty reason.
"""
import ast
import json
import pathlib
import subprocess
import sys

from repro.analysis.loader import SourceModule
from repro.analysis.runner import lint_sources, run_lint

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "lint"
SRC_TREE = ROOT / "src" / "repro"
BASELINE = ROOT / "lint_baseline.json"


def lint_fixture(name):
    path = FIXTURES / name
    text = path.read_text()
    src = SourceModule(path=path, rel=name, name=path.stem,
                      tree=ast.parse(text, filename=str(path)),
                      lines=text.splitlines())
    return lint_sources([src])


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---- bad fixtures trip exactly their rule -----------------------------------

def test_race_bad_trips_only_race_check():
    fs = lint_fixture("race_bad.py")
    assert fs, "race_bad.py produced no findings"
    assert rules_of(fs) == ["race-check"]
    assert any("Worker._loop:self.count" == f.ident for f in fs)


def test_lockorder_bad_trips_only_lock_order_check():
    fs = lint_fixture("lockorder_bad.py")
    assert fs, "lockorder_bad.py produced no findings"
    assert rules_of(fs) == ["lock-order-check"]
    (f,) = fs                       # one cycle, reported once
    assert "Pair._lock_a" in f.ident and "Pair._lock_b" in f.ident


def test_taxstage_bad_trips_only_tax_stage_check():
    fs = lint_fixture("taxstage_bad.py")
    assert fs, "taxstage_bad.py produced no findings"
    assert rules_of(fs) == ["tax-stage-check"]
    assert fs[0].ident == "record:bogus_stage"


def test_jit_bad_trips_only_jit_purity_check():
    fs = lint_fixture("jit_bad.py")
    assert fs, "jit_bad.py produced no findings"
    assert rules_of(fs) == ["jit-purity-check"]
    idents = {f.ident for f in fs}
    # the direct effect and the one two call-hops down
    assert "step:time.sleep" in idents
    assert "deeper:open" in idents


def test_sleep_bad_trips_only_sleep_under_lock():
    fs = lint_fixture("sleepunderlock_bad.py")
    assert fs, "sleepunderlock_bad.py produced no findings"
    assert rules_of(fs) == ["sleep-under-lock"]
    idents = {f.ident for f in fs}
    assert "Poller._loop:time.sleep" in idents
    assert "Poller._wait_locked:threading.Event.wait" in idents
    # the helper with no `with` of its own — caught via the fixpoint
    assert "Poller._nap:time.sleep" in idents


# ---- good twins are clean ---------------------------------------------------

def test_good_fixtures_are_clean():
    for name in ("race_good.py", "lockorder_good.py",
                 "taxstage_good.py", "jit_good.py",
                 "sleepunderlock_good.py"):
        fs = lint_fixture(name)
        assert fs == [], f"{name}: {[f.format() for f in fs]}"


# ---- waiver mechanics -------------------------------------------------------

def test_wellformed_inline_waiver_suppresses():
    assert lint_fixture("waiver_ok.py") == []


def test_reasonless_waiver_waives_nothing_and_is_flagged():
    fs = lint_fixture("waiver_reasonless.py")
    assert rules_of(fs) == ["race-check", "waiver-format"]


# ---- the tree itself --------------------------------------------------------

def test_src_repro_lints_clean_modulo_baseline():
    findings = run_lint(SRC_TREE, package="repro",
                        baseline_path=BASELINE)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_baseline_entries_all_carry_reasons():
    entries = json.loads(BASELINE.read_text()).get("waivers", [])
    assert entries, "baseline exists but is empty — drop the file then"
    for e in entries:
        assert str(e.get("reason", "")).strip(), f"reasonless: {e}"


def test_inline_waivers_in_tree_all_carry_reasons():
    from repro.analysis.waivers import _waiver_on
    bad = []
    for py in SRC_TREE.rglob("*.py"):
        for i, line in enumerate(py.read_text().splitlines(), 1):
            parsed = _waiver_on(line)
            if parsed is not None and not parsed[1]:
                bad.append(f"{py}:{i}")
    assert bad == [], f"reasonless inline waivers: {bad}"


# ---- CLI contract -----------------------------------------------------------

def test_cli_explain_and_exit_codes():
    env_cmd = [sys.executable, str(ROOT / "scripts" / "lint.py")]
    ok = subprocess.run(env_cmd + ["--explain", "race-check"],
                        capture_output=True, text=True)
    assert ok.returncode == 0
    assert "thread-reachable" in ok.stdout
    bad = subprocess.run(env_cmd + ["--explain", "no-such-rule"],
                         capture_output=True, text=True)
    assert bad.returncode == 2


def test_cli_clean_tree_exits_zero_with_json():
    res = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "lint.py"), "--json"],
        capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr
    assert json.loads(res.stdout) == []
