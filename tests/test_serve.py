"""Serving engine: generation, continuous batching, AI-tax reporting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="llama3-8b", slots=2, cache_len=48):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    return ServingEngine(model, params, batch_slots=slots,
                         cache_len=cache_len), cfg


def test_engine_generates_to_completion():
    eng, cfg = _engine()
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 12),
                           max_tokens=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.tokens) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.tokens)


def test_engine_greedy_matches_manual_decode():
    eng, cfg = _engine(slots=1)
    model, params = eng.model, eng.params
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 10)
    eng.submit(Request(0, prompt, max_tokens=4))
    done = eng.run()
    # manual greedy
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                  cache_len=eng.cache_len)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
    assert done[0].tokens == toks


def test_engine_continuous_batching_refills_slots():
    eng, cfg = _engine(slots=2)
    rng = np.random.default_rng(2)
    for rid in range(6):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8),
                           max_tokens=3))
    done = eng.run()
    assert len(done) == 6                # 6 requests through 2 slots


def test_engine_tax_report_structure():
    eng, cfg = _engine()
    rng = np.random.default_rng(3)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8), max_tokens=3))
    eng.run()
    rep = eng.tax_report()
    assert set(rep) >= {"ai_fraction", "tax_fraction", "per_stage"}
    assert "decode" in rep["per_stage"] and "prefill" in rep["per_stage"]
    assert 0.0 <= rep["ai_fraction"] <= 1.0


def test_engine_respects_cache_capacity():
    eng, cfg = _engine(slots=1, cache_len=16)
    rng = np.random.default_rng(4)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 10),
                       max_tokens=100))     # would overflow without eviction
    done = eng.run()
    assert done[0].done
    assert len(done[0].tokens) <= 16


def _prompts(cfg, n, length, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, length) for _ in range(n)]


def _run_engine(scheduler, reqs, slots=2, cache_len=48):
    eng, cfg = _engine(slots=slots, cache_len=cache_len)
    eng = ServingEngine(eng.model, eng.params, batch_slots=slots,
                        cache_len=cache_len, scheduler=scheduler)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    return eng, done


@pytest.mark.parametrize("scheduler", ["continuous", "slot"])
def test_engine_schedulers_agree(scheduler):
    """Both schedulers produce the greedy stream for every request."""
    eng, cfg = _engine(slots=2)
    p = _prompts(cfg, 5, 9)
    _, done1 = _run_engine("slot", [Request(i, p[i], max_tokens=5)
                                    for i in range(5)])
    _, done2 = _run_engine(scheduler, [Request(i, p[i], max_tokens=5)
                                       for i in range(5)])
    tok1 = {r.rid: r.tokens for r in done1}
    tok2 = {r.rid: r.tokens for r in done2}
    assert tok1 == tok2


def test_engine_mid_flight_admit_joins_without_stalling_residents():
    """A request admitted into a freed slot must not perturb the slots
    still decoding: A and B's token streams are identical with and
    without C in the system, and C's decode overlaps A's."""
    eng, cfg = _engine(slots=2)
    pa, pb, pc = _prompts(cfg, 3, 8, seed=11)

    def make():
        return [Request(0, pa, max_tokens=10),   # long-running resident
                Request(1, pb, max_tokens=3),    # frees its slot early
                Request(2, pc, max_tokens=4)]    # joins mid-flight of A

    _, done_ab = _run_engine("continuous", make()[:2])
    eng3, done_abc = _run_engine("continuous", make())
    ab = {r.rid: r.tokens for r in done_ab}
    abc = {r.rid: r.tokens for r in done_abc}
    assert abc[0] == ab[0] and abc[1] == ab[1]
    # C genuinely joined the running batch: its prefill lands before A's
    # last decode tick, and A keeps producing after C's admission
    c_prefill_end = max(e.t_end for e in eng3.log.events
                        if e.request_id == 2 and e.stage == "prefill")
    a_decodes_after = [e for e in eng3.log.events
                       if e.request_id == 0 and e.stage == "decode"
                       and e.t_start >= c_prefill_end]
    assert a_decodes_after, "admitting C stalled resident slot A"


def test_engine_same_seed_runs_bit_identical():
    """Two fresh engines over the same params and workload produce
    bit-identical token streams (lock-step batched decode is still
    deterministic)."""
    eng, cfg = _engine(slots=2)
    p = _prompts(cfg, 4, 10, seed=13)
    _, d1 = _run_engine("continuous", [Request(i, p[i], max_tokens=6)
                                       for i in range(4)])
    _, d2 = _run_engine("continuous", [Request(i, p[i], max_tokens=6)
                                       for i in range(4)])
    assert {r.rid: r.tokens for r in d1} == {r.rid: r.tokens for r in d2}


@pytest.mark.parametrize("scheduler", ["continuous", "slot"])
def test_engine_max_tokens_one_emits_exactly_one_token(scheduler):
    """max_tokens bounds generated tokens INCLUDING the prefill token:
    max_tokens=1 emits one token and never runs a decode step (the
    off-by-one used to emit two)."""
    eng, cfg = _engine(slots=2)
    p = _prompts(cfg, 3, 8, seed=17)
    eng, done = _run_engine(scheduler, [Request(i, p[i], max_tokens=1)
                                        for i in range(3)])
    assert len(done) == 3
    assert all(len(r.tokens) == 1 for r in done)
    assert not [e for e in eng.log.events if e.stage == "decode"]


@pytest.mark.parametrize("scheduler", ["continuous", "slot"])
def test_engine_transfer_ledger_accounts_every_d2h_byte(scheduler):
    """Every physically fetched device->host byte in the fast-path run
    is on the transfer ledger (the per-token ``cur_len`` sync of the
    old engine was invisible to the tax accounting)."""
    eng, cfg = _engine(slots=2)
    p = _prompts(cfg, 4, 8, seed=19)
    eng, done = _run_engine(scheduler, [Request(i, p[i], max_tokens=4)
                                        for i in range(4)])
    assert len(done) == 4
    assert eng.d2h_syncs > 0
    booked = eng.log.transfer_bytes()["d2h"]
    assert booked == eng.d2h_bytes, (
        f"ledger books {booked} d2h bytes, engine fetched {eng.d2h_bytes}")


def test_engine_decode_d2h_roundtrips_collapse_with_batching():
    """At full occupancy the continuous scheduler pays one d2h fetch per
    tick, not per token: decode-phase round-trips drop slots-fold."""
    eng, cfg = _engine()
    p = _prompts(cfg, 4, 8, seed=23)
    mk = lambda: [Request(i, p[i], max_tokens=5) for i in range(4)]
    slot_eng, _ = _run_engine("slot", mk(), slots=2)
    cont_eng, _ = _run_engine("continuous", mk(), slots=2)
    # 4 prefill fetches either way; decode fetches: 16 vs 8 ticks
    slot_decode = slot_eng.d2h_syncs - 4
    cont_decode = cont_eng.d2h_syncs - 4
    assert slot_decode == 2 * cont_decode


def test_engine_cache_len_768_traces():
    """cache_len=768 (not a multiple of the default KV tile) through the
    Pallas decode kernel in interpret mode — the blk_k legalization
    regression at engine level."""
    from repro.kernels import ops
    eng, cfg = _engine(slots=1, cache_len=768)
    rng = np.random.default_rng(29)
    with ops.default_impl("pallas_interpret"):
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8),
                           max_tokens=2))
        done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 2


def test_engine_ttft_samples_cover_all_requests():
    eng, cfg = _engine(slots=2)
    p = _prompts(cfg, 4, 8, seed=31)
    eng, done = _run_engine("continuous", [Request(i, p[i], max_tokens=3)
                                           for i in range(4)])
    ttfts = eng.ttft_samples()
    assert len(ttfts) == 4 and all(t > 0 for t in ttfts)
