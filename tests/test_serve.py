"""Serving engine: generation, continuous batching, AI-tax reporting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="llama3-8b", slots=2, cache_len=48):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    return ServingEngine(model, params, batch_slots=slots,
                         cache_len=cache_len), cfg


def test_engine_generates_to_completion():
    eng, cfg = _engine()
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 12),
                           max_tokens=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.tokens) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.tokens)


def test_engine_greedy_matches_manual_decode():
    eng, cfg = _engine(slots=1)
    model, params = eng.model, eng.params
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 10)
    eng.submit(Request(0, prompt, max_tokens=4))
    done = eng.run()
    # manual greedy
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                  cache_len=eng.cache_len)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
    assert done[0].tokens == toks


def test_engine_continuous_batching_refills_slots():
    eng, cfg = _engine(slots=2)
    rng = np.random.default_rng(2)
    for rid in range(6):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8),
                           max_tokens=3))
    done = eng.run()
    assert len(done) == 6                # 6 requests through 2 slots


def test_engine_tax_report_structure():
    eng, cfg = _engine()
    rng = np.random.default_rng(3)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8), max_tokens=3))
    eng.run()
    rep = eng.tax_report()
    assert set(rep) >= {"ai_fraction", "tax_fraction", "per_stage"}
    assert "decode" in rep["per_stage"] and "prefill" in rep["per_stage"]
    assert 0.0 <= rep["ai_fraction"] <= 1.0


def test_engine_respects_cache_capacity():
    eng, cfg = _engine(slots=1, cache_len=16)
    rng = np.random.default_rng(4)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 10),
                       max_tokens=100))     # would overflow without eviction
    done = eng.run()
    assert done[0].done
    assert len(done[0].tokens) <= 16
