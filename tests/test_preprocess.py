"""Pre/post-processing subsystem: host/device parity (NMS bit-identical,
decode/letterbox numerics), letterbox invariants, five-way tax
attribution, and the normalization-ownership contract."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # deterministic single-example shim
    from hypothesis_fallback import given, settings, st

from repro.core import facerec, taxmeter
from repro.core.events import FIVE_WAY, EventLog, five_way_fractions
from repro.preprocess import NormSpec, PreprocessStage
from repro.preprocess import device as pre_device
from repro.preprocess import host as pre_host


# ---- NMS: host/device parity ----------------------------------------------

def _random_boxes(rng, n):
    cy, cx = rng.uniform(0, 40, n), rng.uniform(0, 40, n)
    h, w = rng.uniform(1, 8, n), rng.uniform(1, 8, n)
    boxes = np.stack([cy - h, cx - w, cy + h, cx + w], 1).astype(np.float32)
    return boxes, rng.uniform(0, 100, n).astype(np.float32)


@pytest.mark.parametrize("n", [1, 2, 7, 31, 40])
def test_nms_host_device_bit_identical(n):
    """Same boxes, same order — the keep DECISIONS must agree bitwise,
    and the gathered boxes to atol 1e-5 (they are exact gathers)."""
    rng = np.random.default_rng(n)
    boxes, scores = _random_boxes(rng, n)
    kw = dict(iou_thresh=0.3, score_thresh=25.0, max_out=10)
    keep_h = pre_host.nms(boxes, scores, **kw)
    keep_d = pre_device.nms(boxes, scores, **kw)
    assert keep_h == keep_d
    np.testing.assert_allclose(boxes[keep_h], boxes[keep_d], atol=1e-5)


def test_nms_edge_cases():
    assert pre_device.nms(np.zeros((0, 4)), np.zeros((0,))) == []
    assert pre_host.nms(np.zeros((0, 4)), np.zeros((0,))) == []
    # exact duplicates: IoU 1 suppresses, stable tie-break keeps the
    # lower index — on both substrates
    boxes = np.array([[0, 0, 4, 4], [0, 0, 4, 4], [10, 10, 14, 14]],
                     np.float32)
    scores = np.array([5.0, 5.0, 1.0], np.float32)
    for impl in (pre_host.nms, pre_device.nms):
        assert impl(boxes, scores, iou_thresh=0.5) == [0, 2]


def test_nms_max_out_and_threshold():
    rng = np.random.default_rng(0)
    boxes, scores = _random_boxes(rng, 25)
    got = pre_host.nms(boxes, scores, iou_thresh=0.9, score_thresh=50.0,
                       max_out=3)
    assert len(got) == 3
    assert all(scores[i] >= 50.0 for i in got)
    # best-first order
    assert list(np.asarray([scores[i] for i in got])) == \
        sorted((scores[i] for i in got), reverse=True)


def test_postprocess_stage_parity_and_contract():
    """Heatmap -> centers: host and device placements agree exactly and
    respect the max_faces cap."""
    rng = np.random.default_rng(3)
    hms = rng.normal(30, 8, (5, 13, 24)).astype(np.float32)
    for b in range(5):
        for _ in range(b):
            y, x = int(rng.integers(1, 12)), int(rng.integers(1, 23))
            hms[b, y, x] += 120.0
    got_h = PreprocessStage("host").postprocess(hms, facerec.DETECT_POOL)
    got_d = PreprocessStage("device").postprocess(hms, facerec.DETECT_POOL)
    assert got_h == got_d
    assert all(len(c) <= PreprocessStage("host").post.max_faces
               for c in got_h)
    assert any(got_h)                      # the spiked frames detect


# ---- decode ----------------------------------------------------------------

def test_yuv_roundtrip_and_parity():
    rng = np.random.default_rng(1)
    rgb = rng.integers(0, 256, (3, 20, 24, 3), np.uint8)
    yuv = pre_host.rgb_to_yuv(rgb)
    assert yuv.shape == (3, 3, 20, 24)
    back = pre_host.yuv_to_rgb(yuv)
    # uint8 quantization through the color transform: within ±2
    assert np.abs(back.astype(int) - rgb.astype(int)).max() <= 2
    dev = PreprocessStage("device").decode(yuv)
    np.testing.assert_array_equal(dev, back)


# ---- letterbox -------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(16, 64), st.integers(16, 64))
def test_letterbox_shape_and_aspect_invariants(out_h, out_w):
    """Output shape is the target; the content window preserves the
    input aspect via the shared scale r = min(out/in); padding carries
    exactly the pad value; the binding dimension is filled."""
    H, W = 24, 40
    rng = np.random.default_rng(0)
    img = rng.uniform(1.0, 255.0, (2, H, W, 3)).astype(np.float32)
    pad = -7.5
    out = pre_host.letterbox_normalize(
        img, out_h, out_w, scale=np.ones(3, np.float32),
        offset=np.zeros(3, np.float32), pad_value=pad)
    assert out.shape == (2, out_h, out_w, 3) and out.dtype == np.float32
    ch, cw, top, left = pre_host.letterbox_geometry(H, W, out_h, out_w)
    r = min(out_h / H, out_w / W)
    assert abs(ch - H * r) <= 0.5 or ch in (1, out_h)
    assert abs(cw - W * r) <= 0.5 or cw in (1, out_w)
    assert ch == out_h or cw == out_w       # content fills one dim
    mask = np.zeros((out_h, out_w), bool)
    mask[top:top + ch, left:left + cw] = True
    assert np.all(out[:, ~mask] == pad)
    assert np.all(out[:, mask] >= 0.0)      # content came from the image


def test_letterbox_identity_roundtrip():
    """Same-size target, identity norm: letterbox IS the identity (the
    interpolation operator at equal sizes is the identity matrix)."""
    rng = np.random.default_rng(2)
    img = rng.uniform(0, 255, (2, 18, 30, 3)).astype(np.float32)
    out = pre_host.letterbox_normalize(
        img, 18, 30, scale=np.ones(3, np.float32),
        offset=np.zeros(3, np.float32))
    np.testing.assert_allclose(out, img, atol=1e-4)


def test_letterbox_host_device_parity():
    rng = np.random.default_rng(4)
    img = rng.uniform(0, 255, (2, 20, 34, 3)).astype(np.float32)
    kw = dict(scale=np.float32([1 / 255] * 3),
              offset=np.float32([-0.5, 0.0, 0.25]), pad_value=0.125)
    got_h = pre_host.letterbox_normalize(img, 28, 28, **kw)
    import jax.numpy as jnp
    got_d = np.asarray(pre_device.letterbox_normalize(
        jnp.asarray(img), 28, 28, **kw))
    np.testing.assert_allclose(got_h, got_d, atol=1e-4)


# ---- five-way attribution --------------------------------------------------

def test_event_log_five_way_sums_to_one():
    log = EventLog()
    log.log(0, "pre_decode", 0.00, 0.02)
    log.log(0, "ingest", 0.02, 0.03)
    log.log(0, "detect", 0.03, 0.10)
    log.log(0, "post_nms", 0.10, 0.12)
    log.log(0, "wait", 0.12, 0.20)
    log.log(0, "identify", 0.20, 0.30)
    log.log_transfer(0, "h2d", 1024, "detect", 0.30, 0.32)
    fr = log.five_way(facerec.stage_category)
    assert set(fr) == set(FIVE_WAY)
    assert sum(fr.values()) == pytest.approx(1.0, abs=1e-12)
    total = 0.32
    assert fr["pre"] == pytest.approx(0.03 / total)
    assert fr["ai"] == pytest.approx(0.17 / total)
    assert fr["post"] == pytest.approx(0.02 / total)
    assert fr["queue"] == pytest.approx(0.08 / total)
    assert fr["transfer"] == pytest.approx(0.02 / total)
    tax = log.ai_tax(ai_stages={"detect", "identify"},
                     category_of=facerec.stage_category)
    assert tax["fractions"] == fr
    assert tax["pre_fraction"] == fr["pre"]
    assert tax["post_fraction"] == fr["post"]
    # the sum aggregation shares the same attribution (incl. the
    # transfer-kind override) and accounts every logged second
    sec = log.five_way_seconds(facerec.stage_category)
    assert sum(sec.values()) == pytest.approx(
        sum(ev.duration for ev in log.events))
    assert sec["transfer"] == pytest.approx(0.02)


def test_five_way_rejects_unknown_bucket():
    with pytest.raises(ValueError):
        five_way_fractions({"x": 1.0}, lambda s: "nonsense")


def test_taxed_step_five_way():
    from repro.core.taxmeter import TaxedStep
    import jax.numpy as jnp
    step = TaxedStep(EventLog(), name="s")
    step.run(0, pre=lambda x: x + 1, compute=lambda x: x * 2,
             post=lambda y: y - 1, payload=np.ones((8, 8), np.float32))
    bd = step.breakdown()
    fr = bd["fractions"]
    assert sum(fr.values()) == pytest.approx(1.0)
    assert fr["pre"] > 0 and fr["ai"] > 0 and fr["post"] > 0
    assert bd["pre_fraction"] == fr["pre"]
    assert bd["post_fraction"] == fr["post"]
    for stage, cat in [("s/pre", "pre"), ("s/compute", "ai"),
                       ("s/h2d", "transfer"), ("s/d2h", "transfer"),
                       ("s/post", "post"), ("wait", "queue")]:
        assert taxmeter.taxed_stage_category(stage) == cat


def test_pipeline_five_way_fractions_sum_to_one():
    from repro.core.pipeline import StreamingPipeline
    r = StreamingPipeline(n_frames=10, seed=2, n_identify_workers=1).run()
    fr = r.ai_tax()["fractions"]
    assert sum(fr.values()) == pytest.approx(1.0)
    assert fr["pre"] > 0 and fr["ai"] > 0 and fr["queue"] > 0
    stages = set(r.log.breakdown())
    assert {"pre_decode", "pre_letterbox", "post_nms", "detect"} <= stages


# ---- placement + normalization contracts -----------------------------------

def test_device_placement_logs_transfer_bytes():
    log = EventLog()
    stage = PreprocessStage("device", log=log)
    rng = np.random.default_rng(5)
    yuv = rng.integers(0, 256, (2, 3, 16, 16), np.uint8)
    stage.ingest(yuv, 8, 8, rids=[7, 8])
    tb = log.transfer_bytes(boundary="pre_decode")
    assert tb["h2d"] == yuv.nbytes
    assert tb["d2h"] == 2 * 16 * 16 * 3          # uint8 RGB back
    assert log.transfer_bytes(boundary="pre_letterbox")["total"] > 0
    # host placement logs spans but no crossings
    log2 = EventLog()
    PreprocessStage("host", log=log2).ingest(yuv, 8, 8, rids=[7, 8])
    assert log2.transfer_bytes()["total"] == 0
    assert {"pre_decode", "pre_letterbox"} <= set(log2.breakdown())


def test_fused_identifier_folds_stage_norm():
    """A non-trivial crop norm (mean/std) must give the same identities
    through the host embedder chain and the fused device fold — the
    stage owns the constants, both consumers derive from it."""
    norm = NormSpec(mean=(0.3, 0.2, 0.1), std=(0.5, 0.6, 0.7),
                    to_unit=True)
    emb = facerec.Embedder(norm=norm)
    rng = np.random.default_rng(6)
    gal_thumbs = rng.uniform(0, 255, (5, facerec.THUMB, facerec.THUMB, 3))
    gal = {f"p{i}": e
           for i, e in enumerate(emb.embed_batch(gal_thumbs
                                                 .astype(np.float32)))}
    clf = facerec.Classifier(gal)
    fused = facerec.FusedIdentifier(emb, clf)
    assert fused.b1 is not None              # offset fold engaged
    crops = rng.integers(0, 256, (3, facerec.CROP_SIZE,
                                  facerec.CROP_SIZE, 3), np.uint8)
    thumbs = facerec.crop_thumbnails_batch(
        [c for c in crops], [[(facerec.CROP_SIZE // 2,
                               facerec.CROP_SIZE // 2)]] * 3)
    flat = np.stack([t for ts in thumbs for t in ts])
    want = clf.identify_batch(emb.embed_batch(flat))
    got = fused.identify_crops(crops)
    for (n1, s1), (n2, s2) in zip(want, got):
        assert n1 == n2
        assert s1 == pytest.approx(s2, abs=1e-3)


def test_build_identify_stack_carries_preprocess():
    stack = facerec.build_identify_stack(seed=0, gallery_size=4,
                                         placement="device")
    assert isinstance(stack.preprocess, PreprocessStage)
    assert stack.preprocess.placement == "device"
    assert stack.embedder.norm == stack.preprocess.crop_norm
    assert stack.fused is not None and stack.fused.b1 is None


def test_pipeline_device_placement_smoke():
    from repro.core.pipeline import StreamingPipeline
    r = StreamingPipeline(n_frames=8, seed=0, n_identify_workers=1,
                          placement="device").run()
    assert len(r.identities) == r.detected
    assert r.recall >= 0.6
    # the offloaded pre/post stages logged their boundary bytes
    assert r.log.transfer_bytes(boundary="pre_letterbox")["total"] > 0
