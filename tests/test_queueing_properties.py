"""Property-based tests for the closed-form queueing model (§5.3-5.4).

Three families of invariants, run under hypothesis when available and
its deterministic single-example fallback otherwise:
  * every resource's rho is monotone non-decreasing in the acceleration
    factor S (accelerating AI never relieves infrastructure pressure);
  * stability is monotone in provisioning — more drives or more brokers
    never lowers the destabilization knee;
  * the closed-form instability point brackets the DES's measured queue
    blow-up (stable comfortably below it, diverging comfortably above).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # deterministic single-example shim
    from hypothesis_fallback import given, settings, st

from repro.core.broker import BrokerConfig
from repro.core.queueing import (
    max_stable_speedup, stability_knee, utilizations,
)
from repro.core.simulator import (
    ClusterSim, FaceRecWorkload, object_detection_workload,
)


@settings(max_examples=30, deadline=None)
@given(st.floats(1.0, 32.0), st.floats(1.25, 1.9), st.booleans())
def test_rho_monotone_nondecreasing_in_speedup(s, factor, objdet):
    """Accelerating AI can only raise (never lower) any resource's rho."""
    wl = object_detection_workload() if objdet else FaceRecWorkload()
    lo = utilizations(wl, BrokerConfig(), s)
    hi = utilizations(wl, BrokerConfig(), s * factor)
    for name in lo:
        assert hi[name].rho >= lo[name].rho - 1e-12, name


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(3, 7))
def test_stability_monotone_in_drives_and_brokers(drives, brokers):
    """More provisioning never destabilizes: the knee is monotone
    non-decreasing in drives per broker and in broker count."""
    wl = FaceRecWorkload()
    k_d = stability_knee(wl, BrokerConfig(drives_per_broker=drives))
    k_d1 = stability_knee(wl, BrokerConfig(drives_per_broker=drives + 1))
    assert k_d1 >= k_d - 1e-9
    k_b = stability_knee(wl, BrokerConfig(n_brokers=brokers))
    k_b1 = stability_knee(wl, BrokerConfig(n_brokers=brokers + 1))
    assert k_b1 >= k_b - 1e-9


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.sampled_from([0.7, 1.3]))
def test_closed_form_knee_brackets_des_blowup(drives, factor):
    """The analytic instability point brackets the DES's measured queue
    blow-up: runs at 0.7x the knee stay stable, runs at 1.3x diverge
    (the same measured-only signal the cluster cross-validation uses)."""
    wl, bk = FaceRecWorkload(), BrokerConfig(drives_per_broker=drives)
    knee = stability_knee(wl, bk)
    r = ClusterSim(wl, bk, speedup=factor * knee, scale=0.015,
                   sim_time=14, warmup=3, seed=1).run()
    assert r.diverged == (factor > 1.0), (factor, knee, r.backlog,
                                          r.unwritten)


def test_stability_knee_matches_single_resource_bisection():
    """With storage as the binding resource, the whole-system knee
    coincides with the storage-only max_stable_speedup."""
    wl, bk = FaceRecWorkload(), BrokerConfig()
    assert stability_knee(wl, bk) == pytest.approx(
        max_stable_speedup(wl, bk, "broker_storage_write"), rel=1e-3)


def test_consumer_capacity_override_prices_replicas():
    """utilizations(n_consumers=R) prices an R-replica deployment: the
    consumer rho scales as 1/R and, for the accelerated FaceRec shape,
    is flat in S (demand and service rate both scale with S)."""
    wl, bk = FaceRecWorkload(), BrokerConfig()
    r8 = utilizations(wl, bk, 4.0, n_consumers=8)["consumers"]
    r16 = utilizations(wl, bk, 4.0, n_consumers=16)["consumers"]
    assert r8.rho == pytest.approx(2 * r16.rho)
    again = utilizations(wl, bk, 9.0, n_consumers=8)["consumers"]
    assert again.rho == pytest.approx(r8.rho)
