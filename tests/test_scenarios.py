"""Scenario library determinism, seeding audit and the twin gate.

Two regression families ride here:

  * seeding — every generator kind draws from its own salted stream
    (``loadgen._rng``), so no two (kind, seed, stream) combinations the
    library can instantiate share an underlying sequence, and every
    scenario builds bit-identically from the same seed;
  * twin — one live run per CI-affordable scenario must agree with the
    DES replay of the same trace on every heartbeat window (the full
    four-scenario sweep is ``make scenarios-smoke``).
"""
import itertools

import pytest

from repro.cluster.loadgen import rng_fingerprint
from repro.cluster.scenarios import SCENARIOS, build_trace, scenario_spec

ALL = sorted(SCENARIOS)


# ---- seeding audit ---------------------------------------------------------

def test_salted_streams_are_pairwise_distinct():
    """No (generator kind, seed, stream) pair may alias another."""
    salts = ["open-loop", "closed-loop", "diurnal-profile",
             *(f"scenario:{n}" for n in ALL)]
    fps = {}
    for salt, seed, stream in itertools.product(salts, (0, 1, 7),
                                                (0, 1, 2, 11)):
        fp = rng_fingerprint(seed, stream, salt)
        assert fp not in fps, \
            f"stream alias: {(salt, seed, stream)} == {fps[fp]}"
        fps[fp] = (salt, seed, stream)


def test_legacy_unsalted_stream_is_not_an_alias_of_salted():
    assert rng_fingerprint(3, 5) != rng_fingerprint(3, 5, "open-loop")


@pytest.mark.parametrize("name", ALL)
def test_same_seed_builds_bit_identical_traces(name):
    a, b = build_trace(name), build_trace(name)
    assert a == b
    assert a.trace_hash() == b.trace_hash()
    assert a.events == b.events          # tuple equality, every field


@pytest.mark.parametrize("name", ALL)
def test_seed_actually_moves_the_trace(name):
    assert build_trace(name, seed=0).trace_hash() != \
        build_trace(name, seed=1).trace_hash()


# ---- library shape ---------------------------------------------------------

def test_unknown_scenario_is_rejected():
    with pytest.raises(KeyError, match="unknown scenario"):
        build_trace("rush_hour")


@pytest.mark.parametrize("name", ALL)
def test_scenario_traces_are_valid_and_sized(name):
    tr = build_trace(name)
    assert tr.n_events > 100             # enough arrivals per window
    assert tr.horizon_s == 6.0 and tr.n_windows == 8
    spec = scenario_spec(name)
    assert spec.resolve_trace().trace_hash() == tr.trace_hash()


def test_camera_fleet_heat_is_keyed_and_skewed():
    tr = build_trace("camera_fleet")
    assert all(ev.partition_key is not None for ev in tr.events)
    counts = tr.partition_counts(8)
    hot = counts[0]
    assert hot == max(counts.values())
    assert hot > 3 * max(v for k, v in counts.items() if k != 0)


def test_flash_crowd_concentrates_in_the_spike_window():
    tr = build_trace("flash_crowd")
    per_win = [0] * tr.n_windows
    for ev in tr.events:
        per_win[min(int(ev.t / tr.heartbeat_s), tr.n_windows - 1)] += 1
    spike = max(per_win)
    base = sorted(per_win)[len(per_win) // 2]
    assert spike > 3 * base, per_win


# ---- twin gate (one CI-priced live run; the sweep is scenarios-smoke) ------

def test_diurnal_twin_gate_live_vs_des():
    from repro.cluster.crossval import TwinCache, twin_compare

    cache = TwinCache()
    rep = twin_compare(scenario_spec("diurnal"), cache)
    assert rep.agree, rep.row()
    assert not rep.cached and cache.misses == 1
    # same (spec hash, trace hash) -> the DES half comes from cache
    rep2 = twin_compare(scenario_spec("diurnal"), cache)
    assert rep2.cached and cache.hits == 1
    assert rep2.agree, rep2.row()
