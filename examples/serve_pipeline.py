"""End-to-end driver: the paper's Face Recognition pipeline, live.

Synthetic video -> ingestion (resize kernel) -> detection -> broker queue
-> identification, with event instrumentation producing the paper's
Fig 6 / Fig 8 style breakdown for THIS machine.

    PYTHONPATH=src python examples/serve_pipeline.py [n_frames]
"""
import sys

from repro.core.pipeline import StreamingPipeline

n = int(sys.argv[1]) if len(sys.argv) > 1 else 60
res = StreamingPipeline(n_frames=n, fuse_ingest_detect=True,
                        n_identify_workers=2, seed=0).run()

print(f"frames={n}  faces_detected={res.detected}  "
      f"ground_truth={res.ground_truth}  recall={res.recall:.2f}")
tax = res.ai_tax()
print(f"\nAI fraction of latency: {tax['ai_fraction']:.1%}   "
      f"AI TAX: {tax['tax_fraction']:.1%}")
print(f"{'stage':<14}{'mean ms':>10}")
for stage, v in sorted(tax["per_stage"].items()):
    print(f"{stage:<14}{v*1e3:>10.2f}")
p99 = res.log.tail(0.99)
print(f"\nmean e2e: {res.log.mean_e2e()*1e3:.1f} ms   p99: {p99*1e3:.1f} ms")
print("\n(paper, full cluster: ingestion 18.8 / detection 74.8 / "
      "broker wait 126.1 / identification 131.5 ms; e2e 351 ms)")
