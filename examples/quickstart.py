"""Quickstart: 60 seconds through the framework's public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config
from repro.core.broker import BrokerConfig
from repro.core.queueing import bottleneck, max_stable_speedup
from repro.core.simulator import ClusterSim, FaceRecWorkload
from repro.core.tco import paper_comparison
from repro.models.model import build_model

print("== 1. architectures ==")
print(" ".join(ARCHS))

print("\n== 2. build + run a model (reduced config, CPU) ==")
cfg = get_config("llama3-8b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
loss = model.loss(params, {"tokens": tokens, "labels": tokens})
print(f"params={model.n_params():,}  loss={float(loss):.3f}")

logits, cache = model.prefill(params, {"tokens": tokens}, cache_len=20)
logits, cache = model.decode_step(params, cache, tokens[:, -1:])
print(f"decode logits: {logits.shape}")

print("\n== 3. the AI tax (paper §4-§5): accelerate and watch the broker ==")
wl, bk = FaceRecWorkload(), BrokerConfig()
for s in (1, 8):
    r = ClusterSim(wl, bk, speedup=s, scale=0.03, sim_time=12, warmup=3).run()
    lat = "inf" if r.unstable else f"{r.mean_latency*1e3:.0f}ms"
    print(f"  {s}x AI acceleration: latency={lat} "
          f"storage_util={r.broker_write_util:.0%} net={r.broker_net_util:.1%}")
print(f"  bottleneck at 8x: {bottleneck(wl, bk, 8).name}")
print(f"  purpose-built brokers (4 drives) support "
      f"{max_stable_speedup(wl, BrokerConfig(drives_per_broker=4)):.0f}x")
print(f"  ...at {paper_comparison().saving_fraction:.1%} lower TCO (paper: >15%)")

print("\n== 4. dry-run one production cell (needs 512 fake devices) ==")
print("  PYTHONPATH=src python -m repro.launch.dryrun "
      "--arch llama3-8b --shape decode_32k --multi-pod")
