"""The paper's §5-§7 story as one script: characterize -> accelerate ->
find the substrate bottleneck -> re-provision -> compare TCO.

    PYTHONPATH=src python examples/accelerate_datacenter.py
"""
from repro.core.broker import BrokerConfig
from repro.core.queueing import bottleneck, max_stable_speedup, utilizations
from repro.core.simulator import ClusterSim, FaceRecWorkload
from repro.core.tco import paper_comparison

wl = FaceRecWorkload()

print("== Step 1: accelerate the AI (paper Fig 10) ==")
for s in (1, 2, 4, 6, 8):
    r = ClusterSim(wl, BrokerConfig(), speedup=s, scale=0.03,
                   sim_time=12, warmup=3).run()
    lat = "DIVERGES" if r.unstable else f"{r.mean_latency*1e3:6.0f} ms"
    print(f"  {s:2d}x: latency {lat}   throughput {r.throughput:6.0f}/s   "
          f"storage {r.broker_write_util:4.0%}   network {r.broker_net_util:4.1%}")

print("\n== Step 2: the bottleneck is storage, not network (Fig 11) ==")
for name, u in utilizations(wl, BrokerConfig(), 8.0).items():
    flag = " <-- UNSTABLE" if not u.stable else ""
    print(f"  {name:<22} rho = {u.rho:5.2f}{flag}")

print("\n== Step 3: three mitigations (Fig 15) ==")
for d in (1, 2, 3, 4):
    s = max_stable_speedup(wl, BrokerConfig(drives_per_broker=d))
    print(f"  {d} drive(s)/broker  -> max stable {s:5.1f}x")
for n in (3, 8):
    s = max_stable_speedup(wl, BrokerConfig(n_brokers=n))
    print(f"  {n} brokers         -> max stable {s:5.1f}x")

print("\n== Step 4: purpose-built data center (Tables 3/4) ==")
c = paper_comparison()
s = c.summary()
print(f"  homogeneous (+4 drives for 32x): "
      f"${s['homogeneous']['equipment']/1e6:.1f}M equip, "
      f"${s['homogeneous']['yearly_tco']/1e6:.1f}M/yr")
print(f"  purpose-built:                   "
      f"${s['purpose_built']['equipment']/1e6:.1f}M equip, "
      f"${s['purpose_built']['yearly_tco']/1e6:.1f}M/yr")
print(f"  TCO saving: {c.saving_fraction:.1%}  (paper: 'in excess of 15%')")
