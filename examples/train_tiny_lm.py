"""End-to-end training driver: tiny LM, a few hundred steps on CPU, with
async checkpointing, restart-resume and the fault-tolerance loop.

    PYTHONPATH=src python examples/train_tiny_lm.py [steps] [arch]
"""
import sys

import jax

from repro.configs import get_config
from repro.data.tokens import TokenLoader
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig, adamw_update
from repro.train.trainer import Trainer, TrainerConfig

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
arch = sys.argv[2] if len(sys.argv) > 2 else "llama3-8b"

cfg = get_config(arch, smoke=True).replace(dtype="float32")
model = build_model(cfg)
print(f"training {cfg.name}: {model.n_params():,} params, {steps} steps")

hp = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps)


def step(params, opt, batch):
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    params, opt, gn = adamw_update(grads, opt, params, hp)
    return params, opt, {"loss": loss, "grad_norm": gn, "step": opt.count}


loader = TokenLoader(cfg.vocab_size, batch=8, seq_len=64)
tc = TrainerConfig(steps=steps, ckpt_every=50, log_every=20,
                   ckpt_dir="/tmp/repro_example_ckpt")
trainer = Trainer(model, jax.jit(step), loader, tc)
params, opt, hist = trainer.run()
print(f"\nfirst-10 mean loss: "
      f"{sum(h['loss'] for h in hist[:10]) / max(len(hist[:10]),1):.4f}")
print(f"last-10 mean loss:  "
      f"{sum(h['loss'] for h in hist[-10:]) / max(len(hist[-10:]),1):.4f}")
print(f"checkpoints: {trainer.ckpt.all_steps()} in {tc.ckpt_dir}")
print("re-run this script: it resumes from the latest checkpoint.")
