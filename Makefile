# Tier-1 verification and fast smoke targets.
#   make test        - full suite (the former HLO-cost deselects are
#                      green since the structured-parser recalibration).
#                      The raw tier-1 command stays
#                      `PYTHONPATH=src python -m pytest -x -q`.
#   make bench-smoke - fast benchmark subset, proves the harness runs
#   make calibrate   - cost model vs XLA cost_analysis() on the fixture
#                      battery (gates dot-FLOP agreement at 5%)
#   make docs-lint   - docs exist and the figure map covers every bench
.PHONY: test bench-smoke calibrate docs-lint check

PY := PYTHONPATH=src python

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) -m benchmarks.run --only fig09
	$(PY) -m benchmarks.run --only batching

calibrate:
	$(PY) scripts/calibrate_cost.py

docs-lint:
	$(PY) scripts/docs_lint.py

check: test bench-smoke docs-lint
