# Tier-1 verification and fast smoke targets.
#   make test        - full suite (the former HLO-cost deselects are
#                      green since the structured-parser recalibration).
#                      The raw tier-1 command stays
#                      `PYTHONPATH=src python -m pytest -x -q`.
#   make bench-smoke - fast benchmark subset, proves the harness runs
#   make calibrate   - cost model vs XLA cost_analysis() on the fixture
#                      battery (gates dot-FLOP agreement at 5%)
#   make docs-lint   - docs exist and the figure map covers every bench
#   make autotune    - refresh the committed Pallas tiling cache
#                      (src/repro/kernels/tilings.json) from the
#                      hot-path shape battery
#   make autotune-check - assert the committed cache is in sync with
#                      what the sweep produces (CI runs this)
.PHONY: test bench-smoke calibrate docs-lint autotune autotune-check check

PY := PYTHONPATH=src python

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) -m benchmarks.run --only fig09
	$(PY) -m benchmarks.run --only batching

calibrate:
	$(PY) scripts/calibrate_cost.py

docs-lint:
	$(PY) scripts/docs_lint.py

autotune:
	$(PY) scripts/autotune.py

autotune-check:
	$(PY) scripts/autotune.py --check

check: test bench-smoke docs-lint autotune-check
