# Tier-1 verification and fast smoke targets.
#   make test        - full suite minus the known pre-existing failures
#                      (ROADMAP.md Open items: HLO-cost parser vs this
#                      container's jax) so green == nothing new broke.
#                      The raw tier-1 command stays
#                      `PYTHONPATH=src python -m pytest -x -q`.
#   make bench-smoke - fast benchmark subset, proves the harness runs
#   make docs-lint   - docs exist and the figure map covers every bench
.PHONY: test bench-smoke docs-lint check

PY := PYTHONPATH=src python

KNOWN_FAIL := \
  --deselect tests/test_hlo_cost.py::test_plain_matmul_flops \
  --deselect tests/test_hlo_cost.py::test_scan_trip_count_multiplication \
  --deselect tests/test_hlo_cost.py::test_nested_scan \
  --deselect tests/test_perf_infra.py::test_dus_inplace_accounting

test:
	$(PY) -m pytest -q $(KNOWN_FAIL)

bench-smoke:
	$(PY) -m benchmarks.run --only fig09
	$(PY) -m benchmarks.run --only batching

docs-lint:
	$(PY) scripts/docs_lint.py

check: test bench-smoke docs-lint
