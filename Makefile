# Tier-1 verification and fast smoke targets.
#   make test        - full suite (the former HLO-cost deselects are
#                      green since the structured-parser recalibration).
#                      The raw tier-1 command stays
#                      `PYTHONPATH=src python -m pytest -x -q`.
#   make coverage    - full suite under pytest-cov with the fail-under
#                      gate (CI's test step); degrades to a skip notice
#                      where pytest-cov isn't installed (the container
#                      bans new deps — requirements-dev.txt has it)
#   make bench-smoke - fast benchmark subset, proves the harness runs
#   make cluster-smoke - CI-sized measured-vs-modeled cluster overlay
#   make faults-smoke - CI-sized fault-injection battery: kill-revive /
#                      drive-drop recovery, degraded-knee cross-check,
#                      autoscaler rescue (RuntimeError on gate failure)
#   make reliability-smoke - CI-sized reliability-tax battery: naive
#                      retry storm must collapse, breaker+backoff must
#                      recover goodput, degradation must buy p99 at a
#                      booked accuracy cost, live-vs-DES agreement
#                      within DES_TOL (RuntimeError on gate failure)
#   make scenarios-smoke - digital-twin battery over the scenario
#                      library: every trace must keep its stress
#                      signature in the DES, live-vs-DES windowed
#                      tail/tax must agree per heartbeat window, and
#                      the second twin pass must hit the DES cache
#                      (RuntimeError on gate failure)
#   make bench-diff  - compare working-tree BENCH_*.json against HEAD's
#                      committed baseline (direction-aware tolerances;
#                      exits 1 on a gated regression)
#   make calibrate   - cost model vs XLA cost_analysis() on the fixture
#                      battery (gates dot-FLOP agreement at 5%)
#   make docs-check  - docs lint + figure-registry sync: required docs
#                      exist, intra-repo links resolve, figure map AND
#                      benchmarks/run.py MODULES cover every benchmark,
#                      public src/repro modules carry docstrings
#                      (docs-lint is an alias)
#   make decode-smoke - CI-sized continuous-batching battery: batched
#                      decode must beat the per-slot baseline >=2x on
#                      decode tokens/sec with p99 TTFT no worse and the
#                      per-token d2h round-trips collapsed slots-fold,
#                      transfer ledger balanced against the engine's
#                      physical fetch counters (RuntimeError on gate
#                      failure)
#   make preprocess-smoke - acceleration x placement sweep over the
#                      preprocess subsystem with its three assertions
#                      (host fraction grows, device >=2x cheaper at the
#                      top, host/device NMS bit-identical)
#   make des-golden  - regenerate tests/fixtures/des_golden.json (ONLY
#                      after a deliberate simulator change; the fixture
#                      exists so refactors can't shift Fig 10/11/15
#                      numbers silently)
#   make autotune    - refresh the committed Pallas tiling cache
#                      (src/repro/kernels/tilings.json) from the
#                      hot-path shape battery
#   make autotune-check - assert the committed cache is in sync with
#                      what the sweep produces (CI runs this)
#   make lint        - AST static analysis over src/repro (race-check,
#                      lock-order-check, tax-stage-check,
#                      jit-purity-check, sleep-under-lock) against
#                      lint_baseline.json; exit 0 clean / 1 findings /
#                      2 internal error (see docs/static_analysis.md)
.PHONY: test coverage bench-smoke cluster-smoke faults-smoke \
	reliability-smoke scenarios-smoke preprocess-smoke decode-smoke \
	bench-diff calibrate docs-lint docs-check des-golden autotune \
	autotune-check lint check

PY := PYTHONPATH=src python

# coverage floor: measured statement coverage is ~88% (full suite,
# stdlib settrace approximation); the floor sits under it with margin
# for tooling differences — ratchet upward, never down
COV_MIN := 80

test:
	$(PY) -m pytest -q

coverage:
	@if $(PY) -c "import pytest_cov" 2>/dev/null; then \
		$(PY) -m pytest -q --cov=repro --cov-report=term \
			--cov-fail-under=$(COV_MIN); \
	else \
		echo "pytest-cov not installed; running plain suite" \
			"(CI installs requirements-dev.txt and enforces the gate)"; \
		$(PY) -m pytest -q; \
	fi

bench-smoke:
	$(PY) -m benchmarks.run --only fig09
	$(PY) -m benchmarks.run --only batching_sweep

cluster-smoke:
	$(PY) -m benchmarks.fig_cluster_scaling --smoke

faults-smoke:
	$(PY) -m benchmarks.fig_fault_recovery --smoke

reliability-smoke:
	$(PY) -m benchmarks.fig_reliability --smoke

scenarios-smoke:
	$(PY) -m benchmarks.fig_scenarios --smoke

bench-diff:
	$(PY) scripts/bench_diff.py

preprocess-smoke:
	$(PY) -m benchmarks.fig_preprocess_offload --smoke

decode-smoke:
	$(PY) -m benchmarks.fig_decode_batching --smoke

des-golden:
	$(PY) scripts/gen_des_golden.py

calibrate:
	$(PY) scripts/calibrate_cost.py

docs-check:
	$(PY) scripts/docs_lint.py

docs-lint: docs-check

autotune:
	$(PY) scripts/autotune.py

autotune-check:
	$(PY) scripts/autotune.py --check

lint:
	$(PY) scripts/lint.py

check: test bench-smoke faults-smoke reliability-smoke scenarios-smoke \
	preprocess-smoke decode-smoke docs-check autotune-check lint
