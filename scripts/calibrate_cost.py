"""CLI for the cost-model calibration harness.

Lowers the fixture battery (matmul, scan, nested scan, DUS carry,
attention), compares ``hlo_cost.analyze()`` against XLA's
``compiled.cost_analysis()`` term by term, and exits non-zero if any
gated fixture's FLOP delta exceeds the tolerance. Run:

    PYTHONPATH=src python scripts/calibrate_cost.py [--tolerance 0.05]
"""
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="max |relative flops delta| on gated fixtures")
    args = ap.parse_args()

    from repro.roofline import calibrate

    rows = calibrate.calibrate()
    for line in calibrate.report(rows, tolerance=args.tolerance):
        print(line)
    bad = [r.name for r in rows if not r.ok(args.tolerance)]
    if bad:
        print(f"calibrate: FAIL — flops delta > {args.tolerance:.0%} on: "
              + ", ".join(bad), file=sys.stderr)
        return 1
    gated = sum(1 for r in rows if r.gate)
    print(f"calibrate: OK ({gated}/{len(rows)} fixtures gated at "
          f"{args.tolerance:.0%}, all within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
