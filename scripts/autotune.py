"""Refresh or verify the committed Pallas tiling cache.

``make autotune`` runs the analytic candidate sweep over the repo's
hot-path shape battery and rewrites ``src/repro/kernels/tilings.json``;
``make autotune-check`` (``--check``) re-runs the sweep in memory and
exits non-zero if the committed file has drifted — so CI catches a
kernel/candidate-space change that forgot to refresh the cache.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.kernels import autotune  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="verify the committed cache matches the sweep "
                         "instead of rewriting it")
    ap.add_argument("--out", default=str(autotune.SEED_PATH),
                    help="cache file to write (default: committed seed)")
    args = ap.parse_args()

    entries = autotune.hot_path_battery()
    text = json.dumps(entries, indent=1, sort_keys=True) + "\n"
    out = pathlib.Path(args.out)

    if args.check:
        if not out.is_file():
            print(f"autotune --check: {out} missing (run `make autotune`)",
                  file=sys.stderr)
            return 1
        committed = json.loads(out.read_text())
        stale = {k for k in entries
                 if committed.get(k, {}).get("blocks") != entries[k]["blocks"]}
        gone = set(committed) - set(entries)
        if stale or gone:
            for k in sorted(stale):
                print(f"autotune --check: stale entry {k}: committed="
                      f"{committed.get(k, {}).get('blocks')} "
                      f"swept={entries[k]['blocks']}", file=sys.stderr)
            for k in sorted(gone):
                print(f"autotune --check: orphan entry {k} "
                      "(not in the battery)", file=sys.stderr)
            return 1
        print(f"autotune --check: OK ({len(entries)} entries in sync)")
        return 0

    out.write_text(text)
    print(f"autotune: wrote {len(entries)} entries to {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
