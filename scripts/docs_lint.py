"""Docs lint: the prose must cover the code, and stay navigable.

Checks (exit non-zero on any failure):
  * README.md and the docs/ pages exist and are non-trivial;
  * every intra-repo markdown link in README.md / docs/*.md resolves
    to a real file (anchors stripped; external/anchor-only links
    skipped); wiki-style ``[[...]]`` links are rejected outright
    (nothing renders them here), as are relative links that escape
    the repository root;
  * every ``benchmarks/*.py`` module (minus shared plumbing) is
    mentioned in docs/figures.md;
  * figure-registry sync, both directions: every module registered in
    ``benchmarks/run.py`` MODULES has a file, and every non-plumbing
    benchmark file is registered (an unregistered benchmark never runs
    in the sweep — silent coverage loss);
  * every public module under ``src/repro/`` (no ``_``-prefixed path
    component) carries a module docstring.
Run via ``make docs-check`` (``make docs-lint`` is an alias).
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
PLUMBING = {"common.py", "run.py", "__init__.py"}
REQUIRED_DOCS = ["README.md", "docs/architecture.md", "docs/figures.md",
                 "docs/ai_tax_accounting.md", "docs/static_analysis.md"]
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_WIKI_LINK = re.compile(r"\[\[[^\]]+\]\]")


def _check_links(md: pathlib.Path, errors: list[str]) -> None:
    text = md.read_text()
    for i, line in enumerate(text.splitlines(), 1):
        if _WIKI_LINK.search(line):
            errors.append(f"{md.relative_to(ROOT)}:{i}: wiki-style "
                          "[[...]] link — use [text](path), nothing "
                          "here renders wiki links")
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
        elif ROOT not in resolved.parents and resolved != ROOT:
            errors.append(f"{md.relative_to(ROOT)}: link escapes the "
                          f"repository -> {target}")


def _check_docstrings(errors: list[str]) -> None:
    for py in sorted((ROOT / "src" / "repro").rglob("*.py")):
        rel = py.relative_to(ROOT)
        if any(part.startswith("_") and part != "__init__.py"
               for part in rel.parts):
            continue
        try:
            tree = ast.parse(py.read_text())
        except SyntaxError as e:
            errors.append(f"{rel}: syntax error ({e})")
            continue
        if not ast.get_docstring(tree):
            errors.append(f"{rel}: public module missing a docstring")


def main() -> int:
    errors: list[str] = []
    for rel in REQUIRED_DOCS:
        p = ROOT / rel
        if not p.is_file():
            errors.append(f"missing doc: {rel}")
        elif len(p.read_text().split()) < 50:
            errors.append(f"doc too thin (<50 words): {rel}")

    for md in [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]:
        if md.is_file():
            _check_links(md, errors)

    figmap = ROOT / "docs" / "figures.md"
    figtext = figmap.read_text() if figmap.is_file() else ""
    runpy = (ROOT / "benchmarks" / "run.py").read_text()
    registered = set(re.findall(r'"benchmarks\.(\w+)"', runpy))
    for bench in sorted((ROOT / "benchmarks").glob("*.py")):
        if bench.name in PLUMBING:
            continue
        if bench.name not in figtext:
            errors.append(f"benchmarks/{bench.name} not in docs/figures.md")
        if bench.stem not in registered:
            errors.append(f"benchmarks/{bench.name} not registered in "
                          "benchmarks/run.py MODULES")
    for mod in registered:
        if not (ROOT / "benchmarks" / f"{mod}.py").is_file():
            errors.append(f"run.py registers benchmarks.{mod} but no file")

    _check_docstrings(errors)

    for e in errors:
        print(f"docs-lint: {e}", file=sys.stderr)
    if not errors:
        print(f"docs-lint: OK ({len(REQUIRED_DOCS)} docs, links resolve, "
              "figure map + run.py registry cover all benchmarks, "
              "public modules documented)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
