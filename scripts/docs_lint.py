"""Docs lint: the figure map must cover every benchmark module.

Checks (exit non-zero on any failure):
  * README.md and the docs/ pages exist and are non-trivial;
  * every ``benchmarks/*.py`` module (minus shared plumbing) is
    mentioned in docs/figures.md;
  * every module registered in benchmarks/run.py MODULES has a file.
Run via ``make docs-lint``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
PLUMBING = {"common.py", "run.py", "__init__.py"}
REQUIRED_DOCS = ["README.md", "docs/figures.md", "docs/ai_tax_accounting.md"]


def main() -> int:
    errors = []
    for rel in REQUIRED_DOCS:
        p = ROOT / rel
        if not p.is_file():
            errors.append(f"missing doc: {rel}")
        elif len(p.read_text().split()) < 50:
            errors.append(f"doc too thin (<50 words): {rel}")

    figmap = ROOT / "docs" / "figures.md"
    figtext = figmap.read_text() if figmap.is_file() else ""
    for bench in sorted((ROOT / "benchmarks").glob("*.py")):
        if bench.name in PLUMBING:
            continue
        if bench.name not in figtext:
            errors.append(f"benchmarks/{bench.name} not in docs/figures.md")

    runpy = (ROOT / "benchmarks" / "run.py").read_text()
    for mod in re.findall(r'"benchmarks\.(\w+)"', runpy):
        if not (ROOT / "benchmarks" / f"{mod}.py").is_file():
            errors.append(f"run.py registers benchmarks.{mod} but no file")

    for e in errors:
        print(f"docs-lint: {e}", file=sys.stderr)
    if not errors:
        print(f"docs-lint: OK ({len(REQUIRED_DOCS)} docs, figure map "
              "covers all benchmarks)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
