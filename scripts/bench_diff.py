"""Regression gate over the committed BENCH_*.json baselines.

Compares every ``BENCH_*.json`` in the working tree against the copy
committed at HEAD (``git show HEAD:<name>``). Each metric carries its
own policy (written by ``benchmarks.common.BenchRecorder``):

  * ``better: "higher"|"lower"`` + ``tol`` — fail when the new value
    drifts past ``tol`` relative in the bad direction;
  * ``gate: false`` or ``better: null`` — report the drift, never fail
    (live-cluster numbers on a shared box, counters);

Sections are only compared when their recorded ``mode`` (smoke/full)
matches — a local full run is never graded against CI's smoke
baseline. A file absent at HEAD passes with a notice (first commit of
a new baseline). Exit 1 iff any gated metric regressed.

Usage: PYTHONPATH=src python scripts/bench_diff.py [--ref HEAD]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _head_version(name: str, ref: str) -> dict | None:
    proc = subprocess.run(["git", "show", f"{ref}:{name}"],
                          cwd=ROOT, capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def _check_metric(key: str, old: dict, new: dict) -> tuple[bool, str]:
    """Returns (regressed, human line)."""
    ov, nv = old["value"], new["value"]
    better, tol = new.get("better"), new.get("tol", 0.25)
    gated = new.get("gate", False) and better is not None
    if ov == 0:
        drift = 0.0 if nv == 0 else float("inf")
    else:
        drift = (nv - ov) / abs(ov)
    bad = (better == "higher" and drift < -tol) \
        or (better == "lower" and drift > tol)
    tag = "REGRESSED" if (bad and gated) else \
        ("drift" if bad else "ok")
    line = (f"  {key}: {ov:g} -> {nv:g} ({drift:+.1%})"
            f" [{tag}{'' if gated else ', ungated'}]")
    return bad and gated, line


def diff_file(path: pathlib.Path, ref: str) -> tuple[int, list[str]]:
    lines = [f"{path.name}:"]
    base = _head_version(path.name, ref)
    if base is None:
        lines.append(f"  (absent at {ref} — new baseline, nothing to"
                     " compare)")
        return 0, lines
    cur = json.loads(path.read_text())
    regressions = 0
    for section, body in sorted(cur.items()):
        old_body = base.get(section)
        if old_body is None:
            lines.append(f"  [{section}] new section")
            continue
        if old_body.get("mode") != body.get("mode"):
            lines.append(f"  [{section}] mode {old_body.get('mode')} !="
                         f" {body.get('mode')} — skipped")
            continue
        for key, new in sorted(body["metrics"].items()):
            old = old_body["metrics"].get(key)
            if old is None:
                lines.append(f"  {section}.{key}: new metric")
                continue
            bad, line = _check_metric(f"{section}.{key}", old, new)
            regressions += bad
            lines.append(line)
    return regressions, lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the baseline (default HEAD)")
    args = ap.parse_args()
    files = sorted(ROOT.glob("BENCH_*.json"))
    if not files:
        print("bench-diff: no BENCH_*.json in the working tree; nothing"
              " to check")
        return
    total = 0
    for path in files:
        n, lines = diff_file(path, args.ref)
        total += n
        print("\n".join(lines))
    if total:
        print(f"bench-diff: {total} gated regression(s)")
        sys.exit(1)
    print("bench-diff: no gated regressions")


if __name__ == "__main__":
    main()
