"""CLI for the repro static-analysis suite (``make lint``).

Runs the four checkers (race-check, lock-order-check, tax-stage-check,
jit-purity-check) over ``src/repro``, filtered through inline waivers
and the committed ``lint_baseline.json``.

Usage: PYTHONPATH=src python scripts/lint.py [options]

  --explain RULE   print what a rule checks and how to waive it
  --rule RULE      run only the named rule(s) (repeatable)
  --root PATH      lint a different tree (fixtures; implies bare names)
  --baseline       regenerate lint_baseline.json from current findings,
                   preserving reasons already recorded (new entries get
                   an empty reason, which the linter itself then flags
                   until a human writes one)
  --json           machine-readable findings on stdout

Exit codes: 0 clean, 1 findings, 2 internal error (unparseable file,
checker crash) — the same contract as the other scripts/ gates.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_TREE = ROOT / "src" / "repro"
BASELINE = ROOT / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="AST lint: concurrency + tax-accounting invariants")
    ap.add_argument("--explain", metavar="RULE",
                    help="print a rule's documentation and exit")
    ap.add_argument("--rule", action="append", metavar="RULE",
                    help="run only this rule (repeatable)")
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="lint this tree instead of src/repro")
    ap.add_argument("--baseline", action="store_true",
                    help="regenerate lint_baseline.json")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="JSON findings on stdout")
    args = ap.parse_args(argv)

    from repro.analysis import runner
    from repro.analysis import waivers as wv
    from repro.analysis.checkers import META_RULES, RULES

    if args.explain:
        texts = {r: doc for r, (_f, doc) in RULES.items()}
        texts.update(META_RULES)
        if args.explain not in texts:
            print(f"unknown rule {args.explain!r}; rules: "
                  f"{', '.join(sorted(texts))}", file=sys.stderr)
            return 2
        print(texts[args.explain].strip())
        return 0

    bad = [r for r in (args.rule or []) if r not in RULES]
    if bad:
        print(f"unknown rule(s) {', '.join(bad)}; rules: "
              f"{', '.join(sorted(RULES))}", file=sys.stderr)
        return 2

    custom_root = args.root is not None
    tree = args.root or DEFAULT_TREE
    package = None if custom_root else "repro"
    baseline = None if custom_root else BASELINE

    try:
        if args.baseline:
            sources = runner.load_tree(tree, package=package)
            raw = runner.lint_sources(sources, rules=args.rule)
            prev = wv.load_baseline(BASELINE)
            n = wv.write_baseline(BASELINE, raw, prev)
            print(f"lint: wrote {n} baseline entries to "
                  f"{BASELINE.name}")
            return 0
        findings = runner.run_lint(tree, package=package,
                                   baseline_path=baseline,
                                   rules=args.rule)
    except SyntaxError as e:
        print(f"lint: internal error: {e}", file=sys.stderr)
        return 2
    except Exception as e:                      # checker crash = exit 2
        import traceback
        traceback.print_exc()
        print(f"lint: internal error: {e}", file=sys.stderr)
        return 2

    if args.as_json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        n = len(findings)
        print(f"lint: {n} finding{'s' if n != 1 else ''} "
              f"({'FAIL' if n else 'OK'})")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
