"""Regenerate the DES golden regression fixture.

Writes ``tests/fixtures/des_golden.json`` from the seeded runs defined
in ``tests/golden_des.py``. Run (``make des-golden``) ONLY when a
deliberate simulator change is supposed to shift the paper-validated
numbers — the whole point of the fixture is that cluster/infrastructure
refactors cannot move them silently.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tests"))

from golden_des import compute_goldens  # noqa: E402


def main() -> None:
    path = ROOT / "tests" / "fixtures" / "des_golden.json"
    path.write_text(json.dumps(compute_goldens(), indent=2,
                               sort_keys=True) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
