"""Source loader: walk a tree, parse every module once, keep the text.

The whole suite works on one pass of ``ast.parse`` per file — the
analyzed code is never imported, so the linter can run on broken
branches, on fixture snippets that reference modules that don't exist,
and in CI without the JAX runtime warming up. Source lines are kept
alongside the AST because waivers are plain comments (``# lint: waive
...``), which the AST does not carry.
"""
from __future__ import annotations

import ast
import pathlib
from dataclasses import dataclass, field


@dataclass
class SourceModule:
    """One parsed file: path, dotted name, AST, and raw lines."""
    path: pathlib.Path                  # absolute
    rel: str                            # root-relative posix path
    name: str                           # dotted module name
    tree: ast.Module
    lines: list[str] = field(default_factory=list)

    def line(self, lineno: int) -> str:
        """1-based source line (empty string out of range)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def module_name(root: pathlib.Path, py: pathlib.Path,
                package: str | None) -> str:
    """Dotted name for ``py`` under ``root`` (prefix ``package``)."""
    rel = py.relative_to(root).with_suffix("")
    parts = list(rel.parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    if package:
        parts = [package] + parts
    return ".".join(parts) if parts else (package or "")


def load_tree(root: pathlib.Path,
              package: str | None = None) -> list[SourceModule]:
    """Parse every ``*.py`` under ``root`` into :class:`SourceModule`.

    ``package`` is the dotted prefix the tree's modules import under
    (``"repro"`` for ``src/repro``); fixture trees pass ``None`` and
    get bare stem names. Unparseable files raise — a syntax error in
    the analyzed tree is an internal-error condition (CLI exit 2), not
    a finding.
    """
    root = pathlib.Path(root).resolve()
    out: list[SourceModule] = []
    for py in sorted(root.rglob("*.py")):
        text = py.read_text()
        out.append(SourceModule(
            path=py,
            rel=py.relative_to(root).as_posix(),
            name=module_name(root, py, package),
            tree=ast.parse(text, filename=str(py)),
            lines=text.splitlines()))
    return out
