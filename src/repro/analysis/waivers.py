"""Waivers: inline comments and the committed baseline file.

Two ways to accept a finding, both REQUIRING a non-empty reason:

  * inline, on the finding line or the line directly above::

        self.busy = until   # lint: waive race-check -- single owning
                            # writer thread; read only after join()

    syntax: ``# lint: waive <rule>[,<rule>...] -- <reason>``. ``all``
    waives every rule at that site. A waiver with no reason (or no
    ``--`` separator) is itself reported as a ``waiver-format``
    finding — silent suppressions are exactly what this suite exists
    to prevent;

  * the committed ``lint_baseline.json``::

        {"waivers": [{"rule": ..., "path": ..., "ident": ...,
                      "reason": ...}]}

    matched on the stable line-free ``(rule, path, ident)`` key.
    Entries that no longer match anything are reported as
    ``baseline-stale`` findings so the file can only shrink as code
    gets fixed.
"""
from __future__ import annotations

import json
import pathlib
import re

from repro.analysis.findings import Finding
from repro.analysis.loader import SourceModule

_WAIVE_RE = re.compile(
    r"#\s*lint:\s*waive\s+(?P<rules>[A-Za-z0-9_,\- ]+?)"
    r"\s*(?:--\s*(?P<reason>.*))?$")


def _waiver_on(line: str):
    """Parse a waiver comment on one source line -> (rules, reason) or
    None; reason is "" when missing/empty (malformed)."""
    m = _WAIVE_RE.search(line)
    if m is None:
        return None
    rules = tuple(r.strip() for r in m.group("rules").split(",")
                  if r.strip())
    reason = (m.group("reason") or "").strip()
    return rules, reason


def apply_inline_waivers(findings: list[Finding],
                         sources: list[SourceModule]) -> list[Finding]:
    """Drop findings waived inline; emit waiver-format findings for
    malformed (reason-less) waiver comments that matched a finding."""
    by_rel = {s.rel: s for s in sources}
    kept: list[Finding] = []
    malformed: list[Finding] = []
    for f in findings:
        src = by_rel.get(f.path)
        waiver = None
        wline = f.line
        if src is not None:
            waiver = _waiver_on(src.line(f.line))
            if waiver is None and f.line > 1:
                prev = src.line(f.line - 1).strip()
                if prev.startswith("#"):
                    waiver = _waiver_on(prev)
                    wline = f.line - 1
        if waiver is None:
            kept.append(f)
            continue
        rules, reason = waiver
        if f.rule not in rules and "all" not in rules:
            kept.append(f)
            continue
        if not reason:
            malformed.append(Finding(
                rule="waiver-format", path=f.path, line=wline,
                ident=f"{f.ident}:waiver",
                message=(f"waiver for [{f.rule}] at {f.ident} has no "
                         "reason — write '# lint: waive <rule> -- "
                         "<why this is safe>'")))
            kept.append(f)          # a malformed waiver waives nothing
    return kept + malformed


def load_baseline(path: pathlib.Path) -> list[dict]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("waivers", []))


def apply_baseline(findings: list[Finding],
                   entries: list[dict]) -> list[Finding]:
    """Drop baseline-waived findings; report empty-reason and stale
    entries as findings themselves."""
    index = {(e.get("rule"), e.get("path"), e.get("ident")): e
             for e in entries}
    used: set[tuple] = set()
    kept: list[Finding] = []
    for f in findings:
        e = index.get(f.key)
        if e is None:
            kept.append(f)
            continue
        used.add(f.key)
        if not str(e.get("reason", "")).strip():
            kept.append(Finding(
                rule="waiver-format", path=f.path, line=f.line,
                ident=f"{f.ident}:baseline",
                message=(f"baseline entry for [{f.rule}] {f.ident} has "
                         "an empty reason")))
    for key, e in index.items():
        if key not in used:
            kept.append(Finding(
                rule="baseline-stale", path=str(e.get("path", "?")),
                line=0, ident=str(e.get("ident", "?")),
                message=(f"baseline entry [{e.get('rule')}] "
                         f"{e.get('ident')} no longer matches any "
                         "finding — remove it")))
    return kept


def write_baseline(path: pathlib.Path, findings: list[Finding],
                   previous: list[dict]) -> int:
    """Regenerate the baseline from current findings, preserving
    reasons already recorded; new entries get a FILL-ME reason that
    waiver-format will flag until a human writes one."""
    prev = {(e.get("rule"), e.get("path"), e.get("ident")):
            str(e.get("reason", "")) for e in previous}
    entries = []
    for f in sorted(findings, key=lambda f: f.key):
        entries.append({"rule": f.rule, "path": f.path, "ident": f.ident,
                        "reason": prev.get(f.key, "")})
    path.write_text(json.dumps({"waivers": entries}, indent=2) + "\n")
    return len(entries)
