"""Orchestration: load -> model -> graph -> checkers -> waivers.

:func:`run_lint` is the single library entry point; ``scripts/lint.py``
is a thin CLI over it. Findings come back already filtered through the
inline waivers and the baseline, with the waiver machinery's own
meta-findings (``waiver-format``, ``baseline-stale``) merged in — an
empty list means the tree is clean.
"""
from __future__ import annotations

import pathlib

from repro.analysis import waivers as _waivers
from repro.analysis.checkers import RULES
from repro.analysis.findings import Finding
from repro.analysis.loader import SourceModule, load_tree
from repro.analysis.model import build_program
from repro.analysis.threads import build_graph


def lint_sources(sources: list[SourceModule],
                 rules: list[str] | None = None) -> list[Finding]:
    """Run the (selected) checkers over pre-loaded sources; raw
    findings, inline waivers applied, no baseline."""
    program = build_program(sources)
    graph = build_graph(program)
    findings: list[Finding] = []
    for rule, (fn, _explain) in RULES.items():
        if rules is not None and rule not in rules:
            continue
        findings.extend(fn(program, graph, sources))
    findings = _waivers.apply_inline_waivers(findings, sources)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.ident))
    return findings


def run_lint(root: pathlib.Path, package: str | None = "repro",
             baseline_path: pathlib.Path | None = None,
             rules: list[str] | None = None) -> list[Finding]:
    """Lint the tree under ``root``; apply ``baseline_path`` if given.

    Raises ``SyntaxError`` when a file under ``root`` does not parse —
    the CLI maps that to exit code 2 (internal error), distinct from
    exit 1 (findings).
    """
    sources = load_tree(pathlib.Path(root), package=package)
    findings = lint_sources(sources, rules=rules)
    if baseline_path is not None:
        entries = _waivers.load_baseline(pathlib.Path(baseline_path))
        findings = _waivers.apply_baseline(findings, entries)
        findings.sort(key=lambda f: (f.path, f.line, f.rule, f.ident))
    return findings
