"""tax-stage-check: every logged stage name resolves through the
canonical table.

The five-way attribution ({pre, ai, post, transfer, queue}) is only
trustworthy if every stage string handed to the EventLog sinks —
``log``, ``log_batch_span``, ``log_transfer(stage=...)``, ``Timer`` —
resolves through ``repro.core.events.STAGE_CATEGORIES`` (or its
prefix/suffix conventions). A stage that does not resolve would
silently land in the residual "pre" bucket and skew every figure built
on the breakdown. This checker validates, statically:

  * string literals in a sink's stage slot:
    ``categorize(name, default=None)`` must not be None;
  * f-strings: a constant tail matching a ``/phase`` suffix or a
    constant head matching ``pre_``/``post_`` passes; otherwise the
    site is skipped (dynamic — the runtime guards cover it);
  * wrappers: a function whose parameter flows verbatim into a sink's
    stage slot becomes a sink itself (``PreprocessStage._log_span``),
    so its call sites are checked the same way.

Receivers that resolve to external modules (``math.log``, ``jnp.log``)
are excluded by import-table resolution, not by name.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.model import FunctionModel, chain_of
from repro.analysis.threads import resolve_chain
from repro.core.events import (STAGE_PREFIXES, STAGE_SUFFIXES,
                               categorize)

EXPLAIN = __doc__

# method name -> index of the stage argument among positional args
# (None = keyword-only); receiver resolution filters out externals.
_SINKS = {"log": 1, "log_batch_span": 1, "log_transfer": None,
          "Timer": 2}
_TIMER_HOME = "repro.core.events"


def _stage_arg(node: ast.Call, pos: int | None) -> ast.AST | None:
    for kw in node.keywords:
        if kw.arg == "stage":
            return kw.value
    if pos is not None and len(node.args) > pos:
        return node.args[pos]
    return None


def _check_value(val: ast.AST) -> str | None:
    """Return the offending stage string, or None when the value is
    valid or undecidable (dynamic)."""
    if isinstance(val, ast.Constant) and isinstance(val.value, str):
        return val.value if categorize(val.value, default=None) is None \
            else None
    if isinstance(val, ast.JoinedStr) and val.values:
        last = val.values[-1]
        if isinstance(last, ast.Constant) and isinstance(last.value, str):
            tail = last.value
            if any(tail.endswith(s) for s in STAGE_SUFFIXES):
                return None
            if "wait" in tail:
                return None
        first = val.values[0]
        if isinstance(first, ast.Constant) and isinstance(first.value, str):
            head = first.value
            if any(head.startswith(p) for p in STAGE_PREFIXES):
                return None
            if "wait" in head:
                return None
        if isinstance(last, ast.Constant) and isinstance(last.value, str) \
                and "/" in last.value:
            # a constant /suffix that matched nothing canonical
            return f"...{last.value}"
        return None               # fully dynamic — runtime guards own it
    return None


def _is_sink_call(program, fn: FunctionModel, site) -> int | None:
    """Stage-arg position if this call site is an EventLog-family sink."""
    name = site.chain[-1]
    if name not in _SINKS:
        return None
    res = resolve_chain(program, fn, site.chain)
    if res is not None and res[0] == "external" \
            and not res[1].startswith("repro."):
        return None               # math.log / jnp.log / np.log
    if name == "Timer":
        # only the events.Timer; any other Timer class is not a sink
        if res is None or res[0] != "fn" \
                or not res[1].startswith(_TIMER_HOME):
            return None
    return _SINKS[name]


def _wrapper_sinks(program, graph) -> dict[str, int]:
    """fn qualname -> positional index of its stage-forwarding param."""
    out: dict[str, int] = {}
    for fn in program.functions.values():
        for site in fn.calls:
            pos = _is_sink_call(program, fn, site)
            if pos is None:
                continue
            val = _stage_arg(site.node, pos)
            if isinstance(val, ast.Name) and val.id in fn.params:
                out[fn.qualname] = fn.params.index(val.id)
    return out


def check(program, graph, sources) -> list[Finding]:
    wrappers = _wrapper_sinks(program, graph)
    out: list[Finding] = []
    seen: set[tuple] = set()
    for fn in program.functions.values():
        short = fn.qualname[len(fn.module) + 1:] if fn.module \
            else fn.qualname
        for site in fn.calls:
            pos = _is_sink_call(program, fn, site)
            if pos is None:
                res = resolve_chain(program, fn, site.chain)
                if res is None or res[0] != "fn" \
                        or res[1] not in wrappers:
                    continue
                pos = wrappers[res[1]]
            bad = _check_value(_stage_arg(site.node, pos))
            if bad is None:
                continue
            key = (fn.rel, short, bad)
            if key in seen:
                continue
            seen.add(key)
            out.append(Finding(
                rule="tax-stage-check", path=fn.rel, line=site.lineno,
                ident=f"{short}:{bad}",
                message=(f"stage {bad!r} logged in '{short}' does not "
                         "resolve through repro.core.events."
                         "STAGE_CATEGORIES — it would silently land in "
                         "the residual 'pre' bucket"),
                detail={"stage": bad}))
    return out
