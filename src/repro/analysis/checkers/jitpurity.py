"""jit-purity-check: no host side effects reachable from compiled code.

``jax.jit`` / ``pl.pallas_call`` trace their function once and replay
the compiled program; host effects inside — clocks, RNG from
``random``/``np.random``, thread primitives, EventLog appends, file
I/O — either burn in a single traced value (a timestamp frozen at
trace time), silently stop happening on cache hits, or tear the
tracing machinery. The checker seeds from:

  * ``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` decorators;
  * ``jax.jit(f)`` / ``pl.pallas_call(kernel, ...)`` call sites, with
    ``functools.partial(f, ...)`` unwrapped one level and lambdas
    followed;

closes over repo-resolvable call/ref edges, and flags any reached
function that touches: ``time.*``, ``random.*`` / ``numpy.random.*``,
``threading.*``, builtin ``open``/``print``, ``Path.read_text`` /
``write_text``, or an EventLog method. Host-side work that merely
*builds* a compiled program (autotune cache lookups at trace time) is
the intended waiver case — the baseline carries those with reasons.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.model import chain_of
from repro.analysis.threads import resolve_chain

EXPLAIN = __doc__

_JIT_CTORS = {"jax.jit"}
_PALLAS_CTORS = {"jax.experimental.pallas.pallas_call"}
_PARTIAL = {"functools.partial"}
_EFFECT_PREFIXES = ("time.", "random.", "threading.", "numpy.random.")
_EFFECT_METHODS = {"read_text", "write_text", "open", "print"}
_EVENTLOG_METHODS = {"log", "log_transfer", "log_batch_span",
                     "log_batch_transfers"}


def _resolve_callable_arg(program, fn, node: ast.AST) -> str | None:
    """A callable expression -> function qualname (Name/Attribute,
    lambda, or functools.partial(F, ...) unwrapped one level)."""
    if isinstance(node, ast.Lambda):
        return fn.local_funcs.get(f"<lambda:{node.lineno}>")
    if isinstance(node, ast.Call):
        pchain = chain_of(node.func)
        if pchain:
            pres = resolve_chain(program, fn, pchain)
            if pres and pres[0] == "external" and pres[1] in _PARTIAL \
                    and node.args:
                return _resolve_callable_arg(program, fn, node.args[0])
        return None
    chain = chain_of(node)
    if chain is None:
        return None
    res = resolve_chain(program, fn, chain)
    return res[1] if res and res[0] == "fn" else None


def _seeds(program) -> set[str]:
    seeds: set[str] = set()
    for fn in program.functions.values():
        # decorators: @jax.jit and @functools.partial(jax.jit, ...)
        for dec in fn.decorators:
            target = dec.func if isinstance(dec, ast.Call) else dec
            chain = chain_of(target)
            res = resolve_chain(program, fn, chain) if chain else None
            dotted = res[1] if res and res[0] == "external" else None
            if dotted in _JIT_CTORS:
                seeds.add(fn.qualname)
            elif dotted in _PARTIAL and isinstance(dec, ast.Call) \
                    and dec.args:
                inner = chain_of(dec.args[0])
                ires = resolve_chain(program, fn, inner) if inner else None
                if ires and ires[0] == "external" \
                        and ires[1] in _JIT_CTORS:
                    seeds.add(fn.qualname)
        # call sites: jax.jit(f) / pl.pallas_call(kernel, ...)
        for site in fn.calls:
            res = resolve_chain(program, fn, site.chain)
            if res is None or res[0] != "external":
                continue
            if res[1] in _JIT_CTORS | _PALLAS_CTORS:
                args = list(site.node.args) \
                    + [kw.value for kw in site.node.keywords
                       if kw.arg in (None, "fun", "kernel", "f")]
                if args:
                    tgt = _resolve_callable_arg(program, fn, args[0])
                    if tgt:
                        seeds.add(tgt)
    return seeds


def _effects_in(program, fn) -> list[tuple[str, int]]:
    """(sink description, lineno) for every host effect in ``fn``."""
    out = []
    for site in fn.calls:
        chain = site.chain
        res = resolve_chain(program, fn, chain)
        if res and res[0] == "external":
            dotted = res[1]
            if dotted.startswith(_EFFECT_PREFIXES):
                out.append((dotted, site.lineno))
                continue
        if res and res[0] == "fn" \
                and ".EventLog." in res[1]:
            out.append((res[1], site.lineno))
            continue
        name = chain[-1]
        if len(chain) == 1 and name in ("open", "print"):
            mod = program.modules.get(fn.module)
            if res is None and name not in fn.local_funcs \
                    and (mod is None or name not in mod.functions):
                out.append((name, site.lineno))
            continue
        if res is None and name in _EFFECT_METHODS:
            out.append((f"*.{name}", site.lineno))
            continue
        if res is None and name in _EVENTLOG_METHODS and len(chain) >= 2:
            out.append((f"*.{name}", site.lineno))
    return out


def check(program, graph, sources) -> list[Finding]:
    seeds = _seeds(program)
    reached: set[str] = set(seeds)
    work = list(seeds)
    while work:
        cur = work.pop()
        for e in graph.edges.get(cur, []):
            if e.callee not in reached:
                reached.add(e.callee)
                work.append(e.callee)

    out: list[Finding] = []
    seen: set[tuple] = set()
    for qual in sorted(reached):
        fn = program.functions.get(qual)
        if fn is None:
            continue
        short = qual[len(fn.module) + 1:] if fn.module else qual
        for sink, line in _effects_in(program, fn):
            key = (qual, sink)
            if key in seen:
                continue
            seen.add(key)
            via = " (jit/pallas seed)" if qual in seeds else \
                " (reachable from a jit/pallas seed)"
            out.append(Finding(
                rule="jit-purity-check", path=fn.rel, line=line,
                ident=f"{short}:{sink}",
                message=(f"'{short}'{via} reaches host side effect "
                         f"'{sink}' — traced programs must be pure; "
                         "hoist it out or waive with a reason"),
                detail={"sink": sink}))
    return out
