"""lock-order-check: the cross-class lock acquisition graph is acyclic.

Whenever a function acquires lock B while holding lock A — lexically
(nested ``with``), or by calling, directly or transitively, a function
that acquires B — the graph gains an edge A -> B. A cycle in that
graph is a potential deadlock: two threads entering the cycle from
different points can each hold the lock the other needs. The locks in
play are the cluster's ``ServingCluster._lock``, the topic's
``ConsumerGroup._lock`` / ``LivePartition._rr_lock`` and the
pipeline's ``_ident_lock`` / ``_stats_lock``; lock identity is
``(ClassName, attr)``, so every instance of a class shares one node —
conservative, which is the right direction for deadlock detection.

Call edges propagate through the resolved call graph's acquire
closure: a call made under lock A to a function whose closure acquires
B contributes A -> B even when the ``with B`` is three frames down.
"""
from __future__ import annotations

from repro.analysis.findings import Finding

EXPLAIN = __doc__


def _acquire_closure(graph) -> dict[str, set[str]]:
    """fn qualname -> every lock token its call closure can acquire."""
    clo = {q: {tok for tok, _held, _ln in evs}
           for q, evs in graph.acquires.items()}
    changed = True
    while changed:
        changed = False
        for q, edges in graph.edges.items():
            cur = clo.setdefault(q, set())
            for e in edges:
                extra = clo.get(e.callee)
                if extra and not extra <= cur:
                    cur |= extra
                    changed = True
    return clo


def check(program, graph, sources) -> list[Finding]:
    clo = _acquire_closure(graph)

    # lock-token digraph with one evidence site per edge
    succ: dict[str, set[str]] = {}
    evidence: dict[tuple[str, str], tuple[str, int]] = {}

    def add(a: str, b: str, rel: str, line: int) -> None:
        if a == b:
            return               # re-entry on one lock is RLock's job
        succ.setdefault(a, set()).add(b)
        evidence.setdefault((a, b), (rel, line))

    for q, evs in graph.acquires.items():
        fn = program.functions[q]
        for tok, held, line in evs:
            for h in held:
                add(h, tok, fn.rel, line)
    for q, edges in graph.edges.items():
        fn = program.functions[q]
        ctx = graph.ctx_locks.get(q, frozenset())
        for e in edges:
            if e.kind != "call":
                continue
            held = set(e.held) | ctx
            if not held:
                continue
            for tok in clo.get(e.callee, ()):
                for h in held:
                    add(h, tok, fn.rel, e.lineno)

    # cycle detection (iterative DFS, colored); each cycle reported
    # once under its lexicographically-smallest rotation
    out: list[Finding] = []
    seen_cycles: set[tuple] = set()
    color: dict[str, int] = {}       # 1 = on stack, 2 = done

    def dfs(start: str) -> None:
        stack = [(start, iter(sorted(succ.get(start, ()))))]
        path = [start]
        color[start] = 1
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                color[node] = 2
                stack.pop()
                path.pop()
                continue
            c = color.get(nxt, 0)
            if c == 0:
                color[nxt] = 1
                stack.append((nxt, iter(sorted(succ.get(nxt, ())))))
                path.append(nxt)
            elif c == 1:
                cyc = tuple(path[path.index(nxt):])
                k = min(range(len(cyc)), key=lambda i: cyc[i])
                norm = cyc[k:] + cyc[:k]
                if norm in seen_cycles:
                    continue
                seen_cycles.add(norm)
                rel, line = evidence[(norm[-1], norm[0])]
                order = " -> ".join(norm + (norm[0],))
                out.append(Finding(
                    rule="lock-order-check", path=rel, line=line,
                    ident=f"cycle:{'->'.join(norm)}",
                    message=(f"lock acquisition cycle {order} — "
                             "threads entering at different points "
                             "can deadlock"),
                    detail={"cycle": list(norm)}))

    for node in sorted(succ):
        if color.get(node, 0) == 0:
            dfs(node)
    return out
