"""sleep-under-lock: blocking waits while holding a lock.

A thread that sleeps or waits while holding a lock stalls every other
thread that needs that lock for the full wait — the classic convoy
that turns a 2ms pacing sleep into a cluster-wide head-of-line block.
In every thread-reachable function this checker flags calls to

  * ``time.sleep``;
  * ``threading.Event.wait`` / ``threading.Barrier.wait`` /
    ``threading.Thread.join``;
  * ``threading.Condition.wait`` / ``wait_for`` — but ONLY when a
    lock OTHER than the condition's own is also held: waiting on a
    condition with its own lock held is the sanctioned pattern (wait
    atomically releases that lock), while waiting with a second lock
    held blocks that second lock for the whole wait.

"Lock held" counts both the lexical ``with`` context at the call and
the interprocedural lock-context fixpoint — a helper only ever called
by lock holders is treated as running under the lock even with no
``with`` of its own. Fix by moving the wait outside the critical
section, switching to a Condition owned by the same lock, or waiving
with a reason.
"""
from __future__ import annotations

from repro.analysis.findings import Finding
from repro.analysis.threads import _lock_token, resolve_chain

EXPLAIN = __doc__

# dotted external targets that block the calling thread outright
_BLOCKING = {
    "time.sleep",
    "threading.Event.wait",
    "threading.Barrier.wait",
    "threading.Thread.join",
}
# condition waits: blocking too, but exempt on the condition's own lock
_CONDITION_WAITS = {
    "threading.Condition.wait",
    "threading.Condition.wait_for",
}


def check(program, graph, sources) -> list[Finding]:
    out: list[Finding] = []
    for qual in sorted(graph.thread_reachable):
        fn = program.functions.get(qual)
        if fn is None:
            continue
        short = qual[len(fn.module) + 1:] if fn.module else qual
        for site in fn.calls:
            res = resolve_chain(program, fn, site.chain)
            if res is None or res[0] != "external":
                continue
            dotted = res[1]
            if dotted not in _BLOCKING and dotted not in _CONDITION_WAITS:
                continue
            held = graph.held_at(fn, site.held)
            if dotted in _CONDITION_WAITS:
                # subtract the condition's own lock: cv.wait() under
                # `with cv:` releases exactly that lock while waiting
                own = _lock_token(program, fn, site.chain[:-1])
                held = held - {own} if own else held
            if not held:
                continue
            locks = ", ".join(sorted(held))
            out.append(Finding(
                rule="sleep-under-lock", path=fn.rel, line=site.lineno,
                ident=f"{short}:{dotted}",
                message=(f"'{dotted}' called in thread-reachable "
                         f"'{short}' while holding {locks} — every "
                         "other holder stalls for the full wait; move "
                         "the wait outside the lock or waive with a "
                         "reason"),
                detail={"held": sorted(held)}))
    return out
