"""Checker registry: rule name -> (check function, explanation).

Each checker is ``check(program, graph, sources) -> list[Finding]``.
The runner executes every registered rule; ``scripts/lint.py
--explain RULE`` prints the explanation text verbatim.
"""
from repro.analysis.checkers import (jitpurity, lockorder, race,
                                     sleepunderlock, taxstage)

# rule -> (checker callable, --explain text)
RULES = {
    "race-check": (race.check, race.EXPLAIN),
    "lock-order-check": (lockorder.check, lockorder.EXPLAIN),
    "tax-stage-check": (taxstage.check, taxstage.EXPLAIN),
    "jit-purity-check": (jitpurity.check, jitpurity.EXPLAIN),
    "sleep-under-lock": (sleepunderlock.check, sleepunderlock.EXPLAIN),
}

# meta-rules emitted by the waiver machinery, documented for --explain
META_RULES = {
    "waiver-format": (
        "Every waiver needs a non-empty reason.\n\n"
        "Inline form:   # lint: waive <rule>[,<rule>] -- <reason>\n"
        "Baseline form: {\"rule\", \"path\", \"ident\", \"reason\"} in\n"
        "lint_baseline.json. A waiver without a reason suppresses\n"
        "nothing and is itself reported — silent suppressions are what\n"
        "this suite exists to prevent."),
    "baseline-stale": (
        "A lint_baseline.json entry no longer matches any finding.\n"
        "Remove it: the baseline may only shrink as code gets fixed,\n"
        "never accumulate dead weight that could mask a future\n"
        "regression at the same identifier."),
}

__all__ = ["RULES", "META_RULES"]
