"""race-check: unguarded shared-state writes in thread-reachable code.

The serving cluster and the streaming pipeline mutate shared counters
from worker threads; the repo's convention is that every such write is
either (a) under a ``with self._lock`` whose lock the class owns,
(b) a write to a threading primitive (events/queues synchronize
themselves), or (c) explicitly waived with a reason. This checker
flags, in every function reachable from a thread entry point:

  * ``self.X = / += / self.X[k] =`` writes with no lock held — unless
    ``X`` is a threading primitive attribute of the class;
  * augmented writes through ANY receiver (``part.consumed += 1``,
    ``st.served += 1``): read-modify-write on a shared object is racy
    no matter whose attribute it is.

"Lock held" counts both the lexical ``with`` context at the write and
the interprocedural lock-context fixpoint (a method called only by
holders of ``_lock`` is guarded even with no ``with`` of its own).
``__init__`` is exempt: construction happens-before any thread start.
"""
from __future__ import annotations

from repro.analysis.findings import Finding

EXPLAIN = __doc__

# plain (non-aug) assigns to non-self receivers are single atomic
# stores into objects the caller hands over (message fields, fresh
# stats objects) — not flagged; aug-assign read-modify-writes are.
_SELF_KINDS = ("assign", "aug", "subscript")


def check(program, graph, sources) -> list[Finding]:
    out: list[Finding] = []
    for qual in sorted(graph.thread_reachable):
        fn = program.functions.get(qual)
        if fn is None or fn.name == "__init__":
            continue
        cm = program.classes.get(f"{fn.module}.{fn.cls}") if fn.cls \
            else None
        short = qual[len(fn.module) + 1:] if fn.module else qual
        for w in fn.writes:
            if w.receiver == "self":
                if cm is not None and w.attr in cm.primitive_attrs:
                    continue
                if w.kind not in _SELF_KINDS:
                    continue
            elif w.kind != "aug":
                continue
            if graph.held_at(fn, w.held):
                continue
            tgt = f"{w.receiver}.{w.attr}"
            out.append(Finding(
                rule="race-check", path=fn.rel, line=w.lineno,
                ident=f"{short}:{tgt}",
                message=(f"'{tgt}' written without a lock in "
                         f"thread-reachable '{short}' — guard it, make "
                         "it a threading primitive, or waive with a "
                         "reason"),
                detail={"kind": w.kind}))
    return out
