"""Per-module / per-class / per-function models over the raw ASTs.

One visitor pass per module extracts everything the four checkers
need, resolved no further than names allow *locally*:

  * import tables (``import x as y`` aliases, ``from m import n``);
  * per class: methods, base-class chains, and the attribute model —
    which ``self.X`` attributes are locks (``threading.Lock/RLock/
    Condition``), which are other threading primitives (events,
    queues), and which hold instances of repo classes
    (``self.group = ConsumerGroup(...)`` gives ``group`` the type
    ``ConsumerGroup``);
  * per function/method (plus nested defs and lambdas): attribute
    writes with the lexically-held ``with``-lock context, every call
    site with its receiver chain, bare function references (callbacks
    like ``Thread(target=self._replica)``), and local-variable types
    from ``x = ClassName(...)`` assignments.

Cross-module resolution (receiver chain -> concrete method) happens in
:mod:`repro.analysis.threads`, which sees the whole
:class:`Program` at once.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.loader import SourceModule

# constructor chains that make an attribute a lock / a threading
# primitive (dotted form, after alias resolution)
LOCK_CTORS = {"threading.Lock", "threading.RLock", "threading.Condition"}
PRIMITIVE_CTORS = LOCK_CTORS | {
    "threading.Event", "threading.Semaphore", "threading.BoundedSemaphore",
    "threading.Barrier", "queue.Queue", "queue.SimpleQueue",
    "queue.LifoQueue", "queue.PriorityQueue", "collections.deque",
}


def chain_of(node: ast.AST) -> tuple[str, ...] | None:
    """``a.b.c`` -> ("a", "b", "c"); None when the root isn't a Name.

    ``self.topic.publish`` becomes ("self", "topic", "publish");
    anything rooted in a call/subscript result is unresolvable and
    returns None (the checkers then skip or fall back by method name).
    """
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


@dataclass
class CallSite:
    chain: tuple[str, ...]
    lineno: int
    held: tuple                      # receiver chains of held with-locks
    node: ast.Call


@dataclass
class RefSite:
    """A bare reference to a callable (callback / iteration target)."""
    chain: tuple[str, ...]
    lineno: int


@dataclass
class AttrWrite:
    receiver: str                    # "self" or a local/param name
    attr: str
    kind: str                        # assign | aug | subscript
    lineno: int
    held: tuple                      # receiver chains of held with-locks


@dataclass
class FunctionModel:
    module: str
    rel: str
    cls: str | None                  # owning class name, None for functions
    name: str
    qualname: str                    # module.Class.name / module.name
    node: ast.AST
    params: list[str] = field(default_factory=list)   # excludes self
    writes: list[AttrWrite] = field(default_factory=list)
    calls: list[CallSite] = field(default_factory=list)
    refs: list[RefSite] = field(default_factory=list)
    # with-enter events: (lock chain, held-before snapshot, lineno)
    acquired: list[tuple] = field(default_factory=list)
    local_types: dict[str, str] = field(default_factory=dict)
    local_funcs: dict[str, str] = field(default_factory=dict)
    decorators: list = field(default_factory=list)    # raw decorator nodes


@dataclass
class ClassModel:
    module: str
    rel: str
    name: str
    qualname: str
    bases: list[tuple[str, ...]] = field(default_factory=list)
    methods: dict[str, FunctionModel] = field(default_factory=dict)
    lock_attrs: set[str] = field(default_factory=set)
    primitive_attrs: set[str] = field(default_factory=set)
    attr_types: dict[str, str] = field(default_factory=dict)


@dataclass
class ModuleModel:
    src: SourceModule
    import_alias: dict[str, str] = field(default_factory=dict)
    from_names: dict[str, tuple[str, str]] = field(default_factory=dict)
    functions: dict[str, FunctionModel] = field(default_factory=dict)
    classes: dict[str, ClassModel] = field(default_factory=dict)
    global_types: dict[str, str] = field(default_factory=dict)


@dataclass
class Program:
    """The whole analyzed tree, cross-indexed for the checkers."""
    modules: dict[str, ModuleModel] = field(default_factory=dict)
    functions: dict[str, FunctionModel] = field(default_factory=dict)
    classes: dict[str, ClassModel] = field(default_factory=dict)
    # method name -> qualnames of every method with that name (the
    # exactly-one fallback for unresolvable receivers)
    method_index: dict[str, list[str]] = field(default_factory=dict)
    class_by_name: dict[str, list[str]] = field(default_factory=dict)

    def class_of(self, qualname: str) -> ClassModel | None:
        return self.classes.get(qualname)


class _FunctionVisitor(ast.NodeVisitor):
    """Fills one FunctionModel; maintains the lexical with-lock stack."""

    def __init__(self, fn: FunctionModel, collector: "_ModuleCollector"):
        self.fn = fn
        self.col = collector
        self.held: list[tuple[str, ...]] = []

    # ---- helpers -----------------------------------------------------------

    def _snapshot(self) -> tuple:
        return tuple(self.held)

    def _record_write(self, target: ast.AST, kind: str) -> None:
        if isinstance(target, ast.Attribute):
            chain = chain_of(target)
            if chain and len(chain) == 2:
                self.fn.writes.append(AttrWrite(
                    chain[0], chain[1], kind, target.lineno,
                    self._snapshot()))
        elif isinstance(target, ast.Subscript):
            chain = chain_of(target.value)
            if chain and len(chain) == 2 and chain[0] == "self":
                self.fn.writes.append(AttrWrite(
                    chain[0], chain[1], "subscript", target.lineno,
                    self._snapshot()))
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._record_write(el, kind)

    def _record_local_type(self, targets: list, value: ast.AST) -> None:
        if not isinstance(value, ast.Call):
            return
        ctor = chain_of(value.func)
        if ctor is None:
            return
        resolved = self.col.resolve_ctor(ctor)
        if resolved is None:
            return
        for t in targets:
            if isinstance(t, ast.Name):
                self.fn.local_types[t.id] = resolved

    # ---- statements --------------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_write(t, "assign")
        self._record_local_type(node.targets, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_write(node.target, "assign")
            self._record_local_type([node.target], node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_write(node.target, "aug")
        self.generic_visit(node)

    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            chain = chain_of(item.context_expr)
            if chain is not None and len(chain) >= 2:
                self.fn.acquired.append(
                    (chain, self._snapshot(), item.context_expr.lineno))
                self.held.append(chain)
                pushed += 1
            else:
                self.visit(item.context_expr)
        for stmt in node.body:
            self.visit(stmt)
        if pushed:
            del self.held[-pushed:]

    def visit_Call(self, node: ast.Call) -> None:
        chain = chain_of(node.func)
        if chain is not None:
            self.fn.calls.append(CallSite(chain, node.lineno,
                                          self._snapshot(), node))
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            c = chain_of(arg)
            if c is not None and len(c) >= 1:
                self.fn.refs.append(RefSite(c, node.lineno))
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        c = chain_of(node.iter)
        if c is not None:
            # iterating an object invokes its __iter__ (Batcher loops)
            self.fn.calls.append(CallSite(c + ("__iter__",), node.lineno,
                                          self._snapshot(),
                                          ast.Call(func=node.iter, args=[],
                                                   keywords=[])))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.col.add_function(node, cls=self.fn.cls, parent=self.fn)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self.col.add_function(node, cls=self.fn.cls, parent=self.fn)


class _ModuleCollector:
    """Builds the ModuleModel (and registers into the Program)."""

    def __init__(self, src: SourceModule, program: Program):
        self.src = src
        self.program = program
        self.mod = ModuleModel(src=src)
        program.modules[src.name] = self.mod

    # ---- resolution helpers ------------------------------------------------

    def dotted(self, chain: tuple[str, ...]) -> str | None:
        """Resolve a chain's root through the import tables -> dotted
        external/stdlib path ("threading.Lock"), or None."""
        root = chain[0]
        if root in self.mod.import_alias:
            return ".".join((self.mod.import_alias[root],) + chain[1:])
        if root in self.mod.from_names:
            m, orig = self.mod.from_names[root]
            return ".".join((m, orig) + chain[1:])
        return None

    def resolve_ctor(self, ctor: tuple[str, ...]) -> str | None:
        """Constructor chain -> class identity: a repo class qualname,
        or a dotted external name ("threading.Thread")."""
        if len(ctor) == 1:
            name = ctor[0]
            if name in self.mod.classes:
                return self.mod.classes[name].qualname
            if name in self.mod.from_names:
                m, orig = self.mod.from_names[name]
                return f"{m}.{orig}"
            return None
        return self.dotted(ctor)

    # ---- collection --------------------------------------------------------

    def add_function(self, node, cls: str | None = None,
                     parent: FunctionModel | None = None) -> FunctionModel:
        if isinstance(node, ast.Lambda):
            name = f"<lambda:{node.lineno}>"
            params = [a.arg for a in node.args.args]
            decorators: list = []
        else:
            name = node.name
            params = [a.arg for a in node.args.args if a.arg != "self"]
            decorators = list(node.decorator_list)
        scope = (f"{cls}." if cls and parent is None else "")
        if parent is not None:
            # nest under the parent's module-relative qualname
            prefix = parent.qualname[len(self.src.name) + 1:] \
                if self.src.name else parent.qualname
            scope = f"{prefix}."
        qualname = f"{self.src.name}.{scope}{name}" if self.src.name \
            else f"{scope}{name}"
        fn = FunctionModel(module=self.src.name, rel=self.src.rel,
                           cls=cls if parent is None else None,
                           name=name, qualname=qualname, node=node,
                           params=params, decorators=decorators)
        self.program.functions[qualname] = fn
        if parent is not None:
            parent.local_funcs[name] = qualname
        v = _FunctionVisitor(fn, self)
        body = node.body if isinstance(node.body, list) else [node.body]
        for stmt in body:
            v.visit(stmt)
        return fn

    def add_class(self, node: ast.ClassDef) -> None:
        qualname = f"{self.src.name}.{node.name}"
        cm = ClassModel(module=self.src.name, rel=self.src.rel,
                        name=node.name, qualname=qualname)
        for base in node.bases:
            c = chain_of(base)
            if c is not None:
                cm.bases.append(c)
        self.mod.classes[node.name] = cm
        self.program.classes[qualname] = cm
        self.program.class_by_name.setdefault(node.name, []).append(qualname)
        # pass 1: the attribute model, over every method's self.X = ctor
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_attr_types(cm, stmt)
        # pass 2: full function models
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = self.add_function(stmt, cls=node.name)
                cm.methods[stmt.name] = fn
                self.program.method_index.setdefault(
                    stmt.name, []).append(fn.qualname)

    def _scan_attr_types(self, cm: ClassModel, method: ast.AST) -> None:
        for sub in ast.walk(method):
            if not isinstance(sub, ast.Assign):
                continue
            if not isinstance(sub.value, ast.Call):
                continue
            ctor = chain_of(sub.value.func)
            resolved = self.resolve_ctor(ctor) if ctor else None
            if resolved is None:
                continue
            for t in sub.targets:
                c = chain_of(t) if isinstance(t, ast.Attribute) else None
                if c and len(c) == 2 and c[0] == "self":
                    cm.attr_types[c[1]] = resolved
                    if resolved in LOCK_CTORS:
                        cm.lock_attrs.add(c[1])
                    if resolved in PRIMITIVE_CTORS:
                        cm.primitive_attrs.add(c[1])

    def collect(self) -> None:
        for stmt in self.src.tree.body:
            if isinstance(stmt, ast.Import):
                for a in stmt.names:
                    self.mod.import_alias[a.asname or
                                          a.name.split(".")[0]] = a.name
            elif isinstance(stmt, ast.ImportFrom):
                if stmt.module is None:
                    continue
                for a in stmt.names:
                    self.mod.from_names[a.asname or a.name] = (stmt.module,
                                                               a.name)
        # function-local imports also feed resolution (ops.matmul's
        # lazy "from repro.kernels import autotune" pattern)
        for sub in ast.walk(self.src.tree):
            if isinstance(sub, ast.ImportFrom) and sub.module:
                for a in sub.names:
                    self.mod.from_names.setdefault(
                        a.asname or a.name, (sub.module, a.name))
            elif isinstance(sub, ast.Import):
                for a in sub.names:
                    self.mod.import_alias.setdefault(
                        a.asname or a.name.split(".")[0], a.name)
        for stmt in self.src.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = self.add_function(stmt)
                self.mod.functions[stmt.name] = fn
            elif isinstance(stmt, ast.ClassDef):
                self.add_class(stmt)
            elif isinstance(stmt, ast.Assign):
                if isinstance(stmt.value, ast.Call):
                    ctor = chain_of(stmt.value.func)
                    resolved = self.resolve_ctor(ctor) if ctor else None
                    if resolved:
                        for t in stmt.targets:
                            if isinstance(t, ast.Name):
                                self.mod.global_types[t.id] = resolved


def build_program(sources: list[SourceModule]) -> Program:
    """Model every module; returns the cross-indexed Program."""
    program = Program()
    for src in sources:
        _ModuleCollector(src, program).collect()
    return program
