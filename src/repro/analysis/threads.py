"""Cross-module call graph, thread reachability, lock-context fixpoint.

This is where receiver chains become concrete methods. Resolution is
deliberately type-light — just what one AST pass can know:

  * ``self.m()``           -> the same class's method;
  * ``self.group.join()``  -> through the class attribute model
    (``self.group = ConsumerGroup(...)`` types ``group``);
  * ``x = ClassName(...); x.m()`` -> through function local types;
  * module-level singletons (``_CACHE = TilingCache(...)``) through
    module global types; imported names through the import tables;
  * a receiver we cannot type falls back by method *name*, but only
    when exactly ONE repo class defines that name — ambiguous names
    produce no edge rather than a flood of false paths;
  * a receiver typed as an *external* class (``threading.Thread``,
    ``queue.Queue``) suppresses both the edge and the fallback, so
    ``t.start()`` on a Thread never reaches a repo class's ``start``.

Thread-entry seeds are ``threading.Thread(target=...)`` call sites
(the target resolved like any callable reference) plus the ``run``
method of any ``threading.Thread`` subclass. Reachability closes over
call edges *and* reference edges (callbacks such as
``iter(self.next_batch, None)`` and ``Thread(target=self._replica)``).

The lock-context fixpoint answers "which locks are *always* held when
F runs": ctx(F) = intersection over F's call sites of (locks held at
the site + ctx(caller)). That is what lets ``ConsumerGroup._rebalance``
count as guarded — every caller (`join`/`leave`) holds ``_lock``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.analysis.model import (FunctionModel, Program, chain_of)

# external receiver types whose methods never resolve into the repo
_EXTERNAL_PREFIXES = ("threading.", "queue.", "collections.", "jax.",
                      "numpy.", "np.", "concurrent.", "subprocess.",
                      "multiprocessing.")


@dataclass
class Edge:
    """One resolved call edge (caller is the dict key in Graph.edges)."""
    callee: str                      # callee qualname
    lineno: int
    held: tuple = ()                 # lock tokens held at the call site
    kind: str = "call"               # call | ref


@dataclass
class Graph:
    """The resolved program graph the checkers consume."""
    program: Program
    edges: dict[str, list[Edge]] = field(default_factory=dict)
    thread_seeds: set[str] = field(default_factory=set)
    thread_reachable: set[str] = field(default_factory=set)
    # qualname -> locks always held when the function runs (fixpoint)
    ctx_locks: dict[str, frozenset] = field(default_factory=dict)
    # qualname -> (lock token, held-before tokens, lineno) acquire events
    acquires: dict[str, list[tuple]] = field(default_factory=dict)

    def held_at(self, fn: FunctionModel, site_held: tuple) -> frozenset:
        """Effective lock set at a site: lexical holds + caller context."""
        toks = {t for ch in site_held
                for t in [_lock_token(self.program, fn, ch)] if t}
        return frozenset(toks) | self.ctx_locks.get(fn.qualname,
                                                    frozenset())


# ---- receiver-type resolution ---------------------------------------------

def _is_external(resolved: str) -> bool:
    return resolved.startswith(_EXTERNAL_PREFIXES)


def _receiver_type(program: Program, fn: FunctionModel,
                   root: str) -> str | None:
    """Type of a chain's root name inside ``fn`` (class qualname or
    dotted external), or None when untypeable."""
    if root == "self" and fn.cls:
        return f"{fn.module}.{fn.cls}"
    if root in fn.local_types:
        return fn.local_types[root]
    mod = program.modules.get(fn.module)
    if mod and root in mod.global_types:
        return mod.global_types[root]
    return None


def _class_method(program: Program, cls_qual: str,
                  name: str) -> str | None:
    cm = program.classes.get(cls_qual)
    if cm and name in cm.methods:
        return cm.methods[name].qualname
    return None


# builtin-collection method names: an untyped receiver with one of
# these is a list/dict/set/deque, not a repo object — never fall back
# (repo classes happening to share the name, e.g. the DES Partition's
# ``append``, must not inherit every stray ``xs.append(...)`` site)
_BUILTIN_METHODS = {
    "append", "extend", "insert", "remove", "pop", "popleft",
    "appendleft", "clear", "sort", "reverse", "add", "discard",
    "update", "get", "setdefault", "items", "keys", "values", "put",
    "join", "split", "strip", "format", "copy", "index", "count",
}


def _fallback(program: Program, name: str) -> str | None:
    """Unique-name fallback: resolve only when exactly one repo class
    defines a method with this name (else no edge — ambiguity must not
    flood the graph), and the name isn't a builtin-collection method."""
    if name in _BUILTIN_METHODS:
        return None
    cands = program.method_index.get(name, [])
    return cands[0] if len(cands) == 1 else None


def resolve_chain(program: Program, fn: FunctionModel,
                  chain: tuple[str, ...]) -> tuple[str, str] | None:
    """Resolve a call/ref chain to ("fn", qualname) for a repo function
    or ("external", dotted) for an import-rooted external; None when
    nothing can be said (the caller may then try the name fallback)."""
    mod = program.modules.get(fn.module)
    root = chain[0]

    if len(chain) == 1:
        if root in fn.local_funcs:
            return ("fn", fn.local_funcs[root])
        if mod and root in mod.functions:
            return ("fn", mod.functions[root].qualname)
        if mod and root in mod.classes:
            init = _class_method(program, mod.classes[root].qualname,
                                 "__init__")
            return ("fn", init) if init else None
        if mod and root in mod.from_names:
            m, orig = mod.from_names[root]
            tgt = program.modules.get(m)
            if tgt and orig in tgt.functions:
                return ("fn", tgt.functions[orig].qualname)
            if tgt and orig in tgt.classes:
                init = _class_method(program, tgt.classes[orig].qualname,
                                     "__init__")
                if init:
                    return ("fn", init)
            return ("external", f"{m}.{orig}")
        if mod and root in mod.import_alias:
            return ("external", mod.import_alias[root])
        return None

    # dotted receiver: type the root, then walk attribute types
    rtype = _receiver_type(program, fn, root)
    if rtype is not None:
        # walk intermediate attributes through the class attr models
        for attr in chain[1:-1]:
            if _is_external(rtype):
                return ("external", f"{rtype}.{attr}")
            cm = program.classes.get(rtype)
            nxt = cm.attr_types.get(attr) if cm else None
            if nxt is None:
                return None          # untyped hop -> caller may fall back
            rtype = nxt
        if _is_external(rtype):
            return ("external", f"{rtype}.{chain[-1]}")
        meth = _class_method(program, rtype, chain[-1])
        if meth:
            return ("fn", meth)
        cm = program.classes.get(rtype)
        if cm is not None:
            # receiver IS a known repo class but has no such method —
            # a dataclass field tweak, not a call into the repo graph
            return ("external", f"{rtype}.{chain[-1]}")
        return None

    # root is an imported module / name
    if mod and root in mod.import_alias:
        dotted = mod.import_alias[root]
        target = program.modules.get(dotted)
        if target is not None:
            if chain[1] in target.functions and len(chain) == 2:
                return ("fn", target.functions[chain[1]].qualname)
            if chain[1] in target.classes:
                cls_qual = target.classes[chain[1]].qualname
                want = chain[2] if len(chain) >= 3 else "__init__"
                meth = _class_method(program, cls_qual, want)
                if meth:
                    return ("fn", meth)
        return ("external", ".".join((dotted,) + chain[1:]))
    if mod and root in mod.from_names:
        m, orig = mod.from_names[root]
        dotted = f"{m}.{orig}"
        target = program.modules.get(dotted)     # from pkg import module
        if target is not None:
            if chain[1] in target.functions and len(chain) == 2:
                return ("fn", target.functions[chain[1]].qualname)
        holder = program.modules.get(m)          # from module import Class
        if holder and orig in holder.classes:
            meth = _class_method(program, holder.classes[orig].qualname,
                                 chain[1])
            if meth and len(chain) == 2:
                return ("fn", meth)
        return ("external", ".".join((dotted,) + chain[1:]))
    return None


# ---- lock tokens -----------------------------------------------------------

def _lock_token(program: Program, fn: FunctionModel,
                chain: tuple[str, ...]) -> str | None:
    """A held-with chain -> "Class.attr" lock token, or None when the
    chain doesn't end on a known lock attribute."""
    if len(chain) < 2:
        return None
    root, attr = chain[0], chain[-1]
    rtype = _receiver_type(program, fn, root)
    if rtype is None or _is_external(rtype):
        return None
    for hop in chain[1:-1]:
        cm = program.classes.get(rtype)
        nxt = cm.attr_types.get(hop) if cm else None
        if nxt is None or _is_external(nxt):
            return None
        rtype = nxt
    cm = program.classes.get(rtype)
    if cm and attr in cm.lock_attrs:
        return f"{cm.name}.{attr}"
    return None


# ---- graph construction ----------------------------------------------------

def _thread_target_seed(program: Program, fn: FunctionModel,
                        node) -> str | None:
    """``threading.Thread(target=X)`` -> X's qualname (if resolvable)."""
    for kw in node.keywords:
        if kw.arg != "target":
            continue
        chain = chain_of(kw.value)
        if chain is None:
            return None
        res = resolve_chain(program, fn, chain)
        if res and res[0] == "fn":
            return res[1]
        if res is None and len(chain) >= 2:
            return _fallback(program, chain[-1])
    return None


def _base_is_thread(program: Program, fn_module: str,
                    base: tuple[str, ...]) -> bool:
    mod = program.modules.get(fn_module)
    if mod is None:
        return False
    if len(base) == 1 and base[0] in mod.from_names:
        m, orig = mod.from_names[base[0]]
        return f"{m}.{orig}" == "threading.Thread"
    if len(base) >= 2 and base[0] in mod.import_alias:
        dotted = ".".join((mod.import_alias[base[0]],) + base[1:])
        return dotted == "threading.Thread"
    return False


def build_graph(program: Program) -> Graph:
    """Resolve every call/ref site, seed threads, run both fixpoints."""
    g = Graph(program=program)

    for fn in program.functions.values():
        out: list[Edge] = []
        for site in fn.calls:
            res = resolve_chain(program, fn, site.chain)
            if res is None and len(site.chain) >= 2 \
                    and site.chain[0] != "self":
                fb = _fallback(program, site.chain[-1])
                res = ("fn", fb) if fb else None
            if res and res[0] == "fn":
                out.append(Edge(res[1], site.lineno,
                                held=tuple(sorted(
                                    g.held_at(fn, site.held))),
                                kind="call"))
            # Thread(target=...) seeds, wherever the ctor resolved to
            if res and res[0] == "external" \
                    and res[1] == "threading.Thread":
                tgt = _thread_target_seed(program, fn, site.node)
                if tgt:
                    g.thread_seeds.add(tgt)
        for ref in fn.refs:
            res = resolve_chain(program, fn, ref.chain)
            if res and res[0] == "fn":
                out.append(Edge(res[1], ref.lineno, kind="ref"))
        g.edges[fn.qualname] = out

    # Thread subclasses: their run() is a thread entry
    for cm in program.classes.values():
        for base in cm.bases:
            if _base_is_thread(program, cm.module, base) \
                    and "run" in cm.methods:
                g.thread_seeds.add(cm.methods["run"].qualname)

    # reachability closure over call + ref edges
    work = list(g.thread_seeds)
    g.thread_reachable = set(work)
    while work:
        cur = work.pop()
        for e in g.edges.get(cur, []):
            if e.callee not in g.thread_reachable:
                g.thread_reachable.add(e.callee)
                work.append(e.callee)

    _lock_context_fixpoint(g)

    # acquire events with tokens resolved (for the lock-order checker)
    for fn in program.functions.values():
        evs = []
        for chain, held_before, lineno in fn.acquired:
            tok = _lock_token(program, fn, chain)
            if tok:
                evs.append((tok, g.held_at(fn, held_before), lineno))
        if evs:
            g.acquires[fn.qualname] = evs
    return g


def _lock_context_fixpoint(g: Graph) -> None:
    """ctx(F) = ∩ over call sites of (site-held-locks ∪ ctx(caller)).

    Functions with no incoming call edges (public entry points, thread
    seeds) get the empty context. Iterates to a fixpoint; the lattice
    is finite (subsets of the lock-token universe) and the transfer is
    monotone, so this terminates quickly on trees this size.
    """
    program = g.program
    # incoming: callee -> list of (caller fn, site-held lock tokens)
    incoming: dict[str, list[tuple[str, frozenset]]] = {}
    for caller, edges in g.edges.items():
        for e in edges:
            if e.kind != "call":
                continue
            incoming.setdefault(e.callee, []).append(
                (caller, frozenset(e.held)))

    all_toks: set[str] = set()
    for sites in incoming.values():
        for _, toks in sites:
            all_toks |= toks
    top = frozenset(all_toks)

    ctx = {q: (top if q in incoming and q not in g.thread_seeds
               else frozenset())
           for q in program.functions}
    changed = True
    while changed:
        changed = False
        for q, sites in incoming.items():
            if q in g.thread_seeds:
                continue
            new = None
            for caller, toks in sites:
                site_set = toks | ctx.get(caller, frozenset())
                new = site_set if new is None else (new & site_set)
            new = new if new is not None else frozenset()
            if new != ctx.get(q):
                ctx[q] = new
                changed = True
    g.ctx_locks = ctx
