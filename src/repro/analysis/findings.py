"""Finding records: what the checkers emit, how results serialize.

A finding's ``ident`` is a *stable, line-free* identifier (qualified
symbol plus the offending detail) so waivers in the committed baseline
keep matching across unrelated edits; the line number is carried for
human navigation only and never participates in matching.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Finding:
    """One rule violation at one site."""
    rule: str                 # e.g. "race-check"
    path: str                 # root-relative posix path of the module
    line: int                 # 1-based line (navigation only)
    ident: str                # stable id, e.g. "BrokerWriter.run:self.busy"
    message: str              # human sentence
    detail: dict = field(default_factory=dict)   # rule-specific extras

    @property
    def key(self) -> tuple[str, str, str]:
        """The (rule, path, ident) triple waivers match on."""
        return (self.rule, self.path, self.ident)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "ident": self.ident, "message": self.message,
                **({"detail": self.detail} if self.detail else {})}

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"
