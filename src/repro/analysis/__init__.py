"""Static analysis suite: machine-checked concurrency + tax invariants.

The repo's correctness conventions — lock-guarded shared counters in
the threaded cluster/pipeline, a single canonical stage->bucket table
behind the five-way tax attribution, side-effect-free jitted programs
— were enforced only by reviewer vigilance. This package turns them
into lint rules over the stdlib-``ast`` representation of
``src/repro`` (no imports of the analyzed code, no runtime cost):

  * ``race-check``       — instance attributes written from
    thread-reachable methods must be lock-guarded, a threading
    primitive, or carry a waiver with a reason;
  * ``lock-order-check`` — the cross-class lock acquisition graph must
    be acyclic (cycles are potential deadlocks);
  * ``tax-stage-check``  — every literal stage name passed to
    ``EventLog.log``-family sinks must resolve through the canonical
    ``STAGE_CATEGORIES`` table in ``repro.core.events``;
  * ``jit-purity-check`` — functions reachable from ``jax.jit`` /
    ``pallas_call`` sites must not reach host side effects (``time``,
    ``random``, ``threading``, EventLog methods, file I/O).

Entry points: :func:`repro.analysis.runner.run_lint` (library) and
``scripts/lint.py`` (CLI, wired into ``make lint`` / ``make check``).
Intentional exceptions live inline (``# lint: waive <rule> -- reason``)
or in the committed ``lint_baseline.json``; both REQUIRE a non-empty
reason. See docs/static_analysis.md.
"""
from repro.analysis.findings import Finding
from repro.analysis.runner import run_lint

__all__ = ["Finding", "run_lint"]
