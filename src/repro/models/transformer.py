"""Decoder-only LM: block-pattern scan-over-layers, train/prefill/decode.

``cfg.block_pattern`` is the repeating unit (dense: 1 layer; gemma3: 5
local + 1 global; jamba: 7 mamba + 1 attn with alternating MoE). Parameters
and caches for each pattern position are stacked over ``n_repeats`` and the
stack is consumed by one ``lax.scan`` — one trace regardless of depth, with
per-block rematerialization in training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    P, apply_norm, cast_params, embed_meta, embed_tokens, mlp_apply,
    mlp_meta, norm_meta, stack_meta, unembed,
)


# --------------------------------------------------------------------------
# metadata
# --------------------------------------------------------------------------

def _mixer_meta(cfg, spec):
    if spec.kind == "attn":
        return attn.attn_meta(cfg)
    if spec.kind == "mamba":
        return ssm.mamba_meta(cfg)
    return ssm.rwkv_meta(cfg)


def _mlp_meta(cfg, spec):
    if spec.moe:
        return moe_mod.moe_meta(cfg)
    if cfg.mlp_kind == "rwkv":
        return ssm.rwkv_cm_meta(cfg)
    return mlp_meta(cfg)


def block_meta(cfg) -> dict:
    out = {}
    for i, spec in enumerate(cfg.block_pattern):
        out[f"l{i}"] = {
            "ln1": norm_meta(cfg),
            "mix": _mixer_meta(cfg, spec),
            "ln2": norm_meta(cfg),
            "mlp": _mlp_meta(cfg, spec),
        }
    return out


def lm_meta(cfg) -> dict:
    return {
        "embed": embed_meta(cfg),
        "blocks": stack_meta(block_meta(cfg), cfg.n_repeats),
        "ln_f": norm_meta(cfg),
    }


def lm_cache_meta(cfg, batch: int, cache_len: int) -> dict:
    blocks = {}
    for i, spec in enumerate(cfg.block_pattern):
        if spec.kind == "attn":
            c = attn.attn_cache_meta(cfg, spec, batch, cache_len)
        elif spec.kind == "mamba":
            c = ssm.mamba_cache_meta(cfg, batch)
        else:
            c = ssm.rwkv_cache_meta(cfg, batch)
            c["x_cm"] = P((batch, cfg.d_model), ("batch", "embed"), "zeros")
        blocks[f"l{i}"] = c
    return {"blocks": stack_meta(blocks, cfg.n_repeats)}


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def _apply_layer_train(cfg, spec, lp, x, positions, aux):
    h = apply_norm(cfg, lp["ln1"], x)
    if spec.kind == "attn":
        mix = attn.attn_apply(cfg, spec, lp["mix"], h, positions)
    elif spec.kind == "mamba":
        mix = ssm.mamba_apply(cfg, lp["mix"], h)
    else:
        mix = ssm.rwkv_apply(cfg, lp["mix"], h)
    x = shard(x + mix, "batch", "seq", None)
    h = apply_norm(cfg, lp["ln2"], x)
    if spec.moe:
        out, a = moe_mod.moe_apply(cfg, lp["mlp"], h)
        aux = aux + a
    elif cfg.mlp_kind == "rwkv":
        out = ssm.rwkv_cm_apply(cfg, lp["mlp"], h)
    else:
        out = mlp_apply(cfg, lp["mlp"], h)
    x = shard(x + out, "batch", "seq", None)
    return x, aux


def _apply_layer_prefill(cfg, spec, lp, x, positions, cache_len, aux):
    h = apply_norm(cfg, lp["ln1"], x)
    if spec.kind == "attn":
        mix, cache = attn.attn_prefill(cfg, spec, lp["mix"], h, positions,
                                       cache_len)
    elif spec.kind == "mamba":
        mix, cache = ssm.mamba_apply(cfg, lp["mix"], h, return_cache=True)
    else:
        mix, cache = ssm.rwkv_apply(cfg, lp["mix"], h, return_cache=True)
    x = x + mix
    h = apply_norm(cfg, lp["ln2"], x)
    if spec.moe:
        out, a = moe_mod.moe_apply(cfg, lp["mlp"], h)
        aux = aux + a
    elif cfg.mlp_kind == "rwkv":
        out = ssm.rwkv_cm_apply(cfg, lp["mlp"], h)
        cache["x_cm"] = h[:, -1]
    else:
        out = mlp_apply(cfg, lp["mlp"], h)
    x = x + out
    return x, cache, aux


def _apply_layer_decode(cfg, spec, lp, x, cache, cur_len):
    h = apply_norm(cfg, lp["ln1"], x)
    if spec.kind == "attn":
        mix, cache = attn.attn_decode(cfg, spec, lp["mix"], h, cache, cur_len)
    elif spec.kind == "mamba":
        mix, cache = ssm.mamba_decode(cfg, lp["mix"], h, cache)
    else:
        mix, new = ssm.rwkv_decode(cfg, lp["mix"], h, {k: cache[k] for k in
                                                       ("x_tm", "h")})
        cache = {**cache, **new}
    x = x + mix
    h = apply_norm(cfg, lp["ln2"], x)
    if spec.moe:
        out, _ = moe_mod.moe_apply(cfg, lp["mlp"], h)
    elif cfg.mlp_kind == "rwkv":
        out = ssm.rwkv_cm_decode(cfg, lp["mlp"], h, cache["x_cm"])
        cache = {**cache, "x_cm": h[:, 0]}
    else:
        out = mlp_apply(cfg, lp["mlp"], h)
    x = x + out
    return x, cache


# --------------------------------------------------------------------------
# full model passes
# --------------------------------------------------------------------------

def lm_forward(cfg, params, tokens, *, remat: bool = True):
    """Train-mode forward. Returns (hidden (B,S,d), aux_loss)."""
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    B, S = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens, dtype)
    x = shard(x, "batch", "seq", None)
    positions = jnp.arange(S)

    def block_fn(carry, bp):
        x, aux = carry
        for i, spec in enumerate(cfg.block_pattern):
            x, aux = _apply_layer_train(cfg, spec, bp[f"l{i}"], x,
                                        positions, aux)
        # sequence-parallel layer boundary: the saved-for-backward residual
        # stream is sharded over the model axis (Megatron SP); recovered by
        # an all-gather inside the (remat'd) block.
        x = shard(x, "batch", "seq_block", None)
        return (x, aux), None

    fn = jax.checkpoint(block_fn, prevent_cse=False) if remat else block_fn
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = apply_norm(cfg, params["ln_f"], x)
    return x, aux


def lm_logits(cfg, params, hidden):
    params = cast_params(params, jnp.dtype(cfg.dtype))
    return unembed(cfg, params["embed"], hidden)


def lm_loss(cfg, params, tokens, labels, *, chunk: int = 512,
            remat: bool = True):
    """Chunked softmax cross-entropy (never materializes (B,S,V) at once)."""
    hidden, aux = lm_forward(cfg, params, tokens, remat=remat)
    dtype = jnp.dtype(cfg.dtype)
    emb = cast_params(params["embed"], dtype)
    B, S, d = hidden.shape
    C = min(chunk, S)
    n = S // C if S % C == 0 else -(-S // C)
    pad = n * C - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hs = hidden.reshape(B, n, C, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, C).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        h, lab = inp
        logits = unembed(cfg, emb, h).astype(jnp.float32)
        logits = shard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab_c = jnp.clip(lab, 0)
        ll = jnp.take_along_axis(logits, lab_c[..., None], axis=-1)[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        carry = (carry[0] + jnp.sum((lse - ll) * valid), carry[1] + valid.sum())
        return carry, None

    fn = jax.checkpoint(chunk_loss, prevent_cse=False) if remat else chunk_loss
    (tot, cnt), _ = jax.lax.scan(fn, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0) + aux


def lm_prefill(cfg, params, tokens, *, cache_len: int | None = None):
    """Returns (last-position logits (B,V), cache)."""
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    B, S = tokens.shape
    cache_len = cache_len or S
    x = embed_tokens(cfg, params["embed"], tokens, dtype)
    positions = jnp.arange(S)

    def block_fn(carry, bp):
        x, aux = carry
        caches = {}
        for i, spec in enumerate(cfg.block_pattern):
            x, c, aux = _apply_layer_prefill(cfg, spec, bp[f"l{i}"], x,
                                             positions, cache_len, aux)
            caches[f"l{i}"] = c
        return (x, aux), caches

    (x, _), caches = jax.lax.scan(block_fn, (x, jnp.zeros((), jnp.float32)),
                                  params["blocks"])
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    return logits, {"blocks": caches, "cur_len": jnp.asarray(S, jnp.int32)}


def _lm_decode_blocks(cfg, params, blocks, tokens, cur_len):
    """Shared decode body: one token per row against the block caches.

    ``cur_len`` is scalar (lock-step) or ``(B,)`` (ragged slots); the
    attention layers handle either form (see ``attn_decode``).
    """
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    x = embed_tokens(cfg, params["embed"], tokens, dtype)

    def block_fn(x, bp_cache):
        bp, bc = bp_cache
        new = {}
        for i, spec in enumerate(cfg.block_pattern):
            x, nc = _apply_layer_decode(cfg, spec, bp[f"l{i}"], x,
                                        bc[f"l{i}"], cur_len)
            new[f"l{i}"] = nc
        return x, new

    x, new_caches = jax.lax.scan(block_fn, x, (params["blocks"], blocks))
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    return logits, new_caches


def lm_decode_step(cfg, params, cache, tokens):
    """tokens: (B, 1). Returns (logits (B,V), new cache)."""
    cur_len = cache["cur_len"]
    logits, new_caches = _lm_decode_blocks(cfg, params, cache["blocks"],
                                           tokens, cur_len)
    return logits, {"blocks": new_caches, "cur_len": cur_len + 1}


def lm_decode_step_ragged(cfg, params, blocks, tokens, kv_len):
    """Continuous-batching decode: every slot at its own cache length.

    ``blocks`` is the batched block-cache tree (no ``cur_len`` — the
    scheduler owns per-slot occupancy host-side), ``tokens`` (B, 1),
    ``kv_len`` (B,) int32 tokens-so-far per slot. Returns
    (logits (B, V), new blocks); the caller advances its own lengths.
    """
    return _lm_decode_blocks(cfg, params, blocks, tokens,
                             kv_len.astype(jnp.int32))
