"""Unified model interface: build once, use for train/prefill/decode/dry-run."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ed
from repro.models import transformer as tf
from repro.models.layers import (
    abstract_params, init_params, meta_axes,
)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters ----
    def param_meta(self):
        return ed.encdec_meta(self.cfg) if self.cfg.encdec else tf.lm_meta(self.cfg)

    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(self.param_meta(), key, dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.param_meta(), dtype)

    def param_axes(self):
        return meta_axes(self.param_meta())

    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree.leaves(self.abstract_params()))

    # ---- caches ----
    def cache_meta(self, batch: int, cache_len: int):
        if self.cfg.encdec:
            return ed.encdec_cache_meta(self.cfg, batch, cache_len)
        return tf.lm_cache_meta(self.cfg, batch, cache_len)

    def abstract_cache(self, batch: int, cache_len: int):
        c = abstract_params(self.cache_meta(batch, cache_len),
                            jnp.dtype(self.cfg.dtype))
        return {**c, "cur_len": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_axes(self):
        """Logical axes for the cache tree (cur_len replicated)."""
        axes = meta_axes(self.cache_meta(2, 8))
        return {**axes, "cur_len": ()}

    def init_cache(self, batch: int, cache_len: int):
        c = init_params(self.cache_meta(batch, cache_len),
                        jax.random.PRNGKey(0), jnp.dtype(self.cfg.dtype))
        return {**c, "cur_len": jnp.asarray(0, jnp.int32)}

    # ---- entry points ----
    def forward(self, params, batch):
        """Train-mode hidden states. batch: {tokens[, frames]}."""
        if self.cfg.encdec:
            return ed.encdec_forward(self.cfg, params, batch["frames"],
                                     batch["tokens"])
        return tf.lm_forward(self.cfg, params, batch["tokens"])

    def loss(self, params, batch):
        if self.cfg.encdec:
            hidden, aux = ed.encdec_forward(self.cfg, params, batch["frames"],
                                            batch["tokens"])
            return _hidden_loss(self.cfg, params, hidden, batch["labels"]) + aux
        return tf.lm_loss(self.cfg, params, batch["tokens"], batch["labels"])

    def prefill(self, params, batch, *, cache_len: int | None = None):
        if self.cfg.encdec:
            return ed.encdec_prefill(self.cfg, params, batch["frames"],
                                     batch["tokens"],
                                     cache_len=cache_len or batch["tokens"].shape[1])
        return tf.lm_prefill(self.cfg, params, batch["tokens"],
                             cache_len=cache_len)

    def decode_step(self, params, cache, tokens):
        if self.cfg.encdec:
            return ed.encdec_decode_step(self.cfg, params, cache, tokens)
        return tf.lm_decode_step(self.cfg, params, cache, tokens)

    def decode_step_ragged(self, params, blocks, tokens, kv_len):
        """Continuous-batching decode over a batched block cache.

        ``blocks`` is the ``"blocks"`` subtree of a batched cache (one
        slot per batch row), ``kv_len`` the (B,) per-slot tokens-so-far
        vector; slot occupancy lives with the caller, not the cache.
        Decoder-only models only (the encoder-decoder cache keeps its
        lock-step scalar).
        """
        if self.cfg.encdec:
            raise NotImplementedError(
                "ragged decode requires a decoder-only cache layout")
        return tf.lm_decode_step_ragged(self.cfg, params, blocks, tokens,
                                        kv_len)

    def insert_prefill(self, blocks, one_blocks, slot):
        """Write a single-request prefill cache into ``slot`` of a
        batched block cache (continuous batching's prefill-on-admit).

        ``blocks`` leaves are (n_repeats, slots, ...), ``one_blocks``
        leaves (n_repeats, 1, ...) from a batch-1 ``prefill`` at the
        same ``cache_len``; ``slot`` may be a traced int32, so one jit
        of this serves every slot.
        """
        return jax.tree.map(
            lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot, axis=1),
            blocks, one_blocks)

    # ---- dry-run stand-ins ----
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        emb = lambda *s: jax.ShapeDtypeStruct(s, jnp.dtype(cfg.dtype))
        if shape.kind == "train":
            if cfg.encdec:
                Sd = max(S // cfg.dec_ratio, 8)
                return {"frames": emb(B, S, cfg.d_model),
                        "tokens": tok(B, Sd), "labels": tok(B, Sd)}
            return {"tokens": tok(B, S), "labels": tok(B, S)}
        if shape.kind == "prefill":
            if cfg.encdec:
                Sd = max(S // cfg.dec_ratio, 8)
                return {"frames": emb(B, S, cfg.d_model), "tokens": tok(B, Sd)}
            return {"tokens": tok(B, S)}
        # decode: one new token against a cache of S
        return {"tokens": tok(B, 1)}


def _hidden_loss(cfg, params, hidden, labels):
    logits = tf.lm_logits(cfg, params, hidden).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.clip(labels, 0)[..., None], -1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * valid) / jnp.maximum(valid.sum(), 1.0)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
