"""State-space mixers: Mamba (Jamba's SSM layers) and RWKV6 time/channel mix.

Both reduce to first-order diagonal recurrences executed by
``repro.kernels.ops.{mamba_scan, rwkv_scan}`` (chunked associative scans on
the XLA path, Pallas kernels on TPU). Decode is a single recurrence step —
state caches are O(1) in sequence length, which is what makes these archs
eligible for the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.models.layers import P, groupnorm_heads


# --------------------------------------------------------------------------
# Mamba
# --------------------------------------------------------------------------

def _mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    dtr = cfg.ssm_dt_rank or max(cfg.d_model // 16, 1)
    return di, dtr, cfg.ssm_state, cfg.ssm_conv


def mamba_meta(cfg) -> dict:
    d = cfg.d_model
    di, dtr, N, K = _mamba_dims(cfg)
    return {
        "in_proj": P((d, 2 * di), ("embed", "inner")),
        "conv_w": P((K, di), (None, "inner"), scale=K**-0.5),
        "conv_b": P((di,), ("inner",), "zeros"),
        "x_proj": P((di, dtr + 2 * N), ("inner", None)),
        "dt_w": P((dtr, di), (None, "inner")),
        "dt_bias": P((di,), ("inner",), "ones", dtype="float32"),
        "A_log": P((di, N), ("inner", None), "zeros", dtype="float32"),
        "D": P((di,), ("inner",), "ones", dtype="float32"),
        "out_proj": P((di, d), ("inner", "embed")),
    }


def mamba_cache_meta(cfg, batch: int) -> dict:
    di, dtr, N, K = _mamba_dims(cfg)
    return {"conv": P((batch, K - 1, di), ("batch", None, "inner"), "zeros"),
            "h": P((batch, di, N), ("batch", "inner", None), "zeros",
                   dtype="float32")}


def _mamba_pre(cfg, p, xz, conv_tail):
    """Shared projection path. xz: (B, S, 2*di); returns delta, Bt, Ct, xc, z."""
    di, dtr, N, K = _mamba_dims(cfg)
    x_in, z = xz[..., :di], xz[..., di:]
    xw = jnp.concatenate([conv_tail, x_in], axis=1)      # causal depthwise conv
    # (B, S+K-1, di) -> windows: sum_k conv_w[k] * x[t+k]
    xc = sum(xw[:, k:k + x_in.shape[1]] * p["conv_w"][k].astype(xw.dtype)
             for k in range(K))
    xc = jax.nn.silu(xc + p["conv_b"].astype(xc.dtype))
    xdb = xc @ p["x_proj"]
    delta = jax.nn.softplus(xdb[..., :dtr] @ p["dt_w"]
                            + p["dt_bias"].astype(xdb.dtype))
    Bt, Ct = xdb[..., dtr:dtr + N], xdb[..., dtr + N:]
    return delta, Bt, Ct, xc, z, x_in


def mamba_apply(cfg, p, x, h0=None, conv_tail=None, return_cache=False):
    """x: (B, S, d). Returns y or (y, cache)."""
    B, S, _ = x.shape
    di, dtr, N, K = _mamba_dims(cfg)
    xz = x @ p["in_proj"]
    xz = shard(xz, "batch", "seq", "inner")
    if conv_tail is None:
        conv_tail = jnp.zeros((B, K - 1, di), xz.dtype)
    delta, Bt, Ct, xc, z, x_in = _mamba_pre(cfg, p, xz, conv_tail)
    A = -jnp.exp(p["A_log"])
    y, h = ops.mamba_scan(delta, A, Bt, Ct, xc, h0)
    y = y + xc * p["D"].astype(y.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if not return_cache:
        return out
    tail = jnp.concatenate([conv_tail, x_in], axis=1)[:, -(K - 1):]
    return out, {"conv": tail, "h": h}


def mamba_decode(cfg, p, x, cache):
    """x: (B, 1, d); cache: {conv (B,K-1,di), h (B,di,N)}."""
    di, dtr, N, K = _mamba_dims(cfg)
    xz = x @ p["in_proj"]
    delta, Bt, Ct, xc, z, x_in = _mamba_pre(cfg, p, xz, cache["conv"])
    A = -jnp.exp(p["A_log"])
    y, h = ops.mamba_decode_step(delta[:, 0], A, Bt[:, 0], Ct[:, 0],
                                 xc[:, 0], cache["h"])
    y = y[:, None] + xc * p["D"].astype(y.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    tail = jnp.concatenate([cache["conv"], x_in], axis=1)[:, 1:]
    return out, {"conv": tail, "h": h}


# --------------------------------------------------------------------------
# RWKV6 (Finch) — time-mix with data-dependent decay + channel-mix FFN
# --------------------------------------------------------------------------

def _rwkv_dims(cfg):
    K = cfg.rwkv_head_dim
    H = cfg.d_model // K
    return H, K


def rwkv_meta(cfg) -> dict:
    d = cfg.d_model
    H, K = _rwkv_dims(cfg)
    da = H * K
    lora = 64
    return {
        "mu": P((5, d), (None, "embed"), "zeros"),    # r,w,k,v,g token-shift mixes
        "wr": P((d, da), ("embed", "inner")),
        "wk": P((d, da), ("embed", "inner")),
        "wv": P((d, da), ("embed", "inner")),
        "wg": P((d, da), ("embed", "inner")),
        "w0": P((da,), ("inner",), "zeros", dtype="float32"),
        "w1": P((d, lora), ("embed", None)),
        "w2": P((lora, da), (None, "inner"), scale=0.01),
        "u": P((H, K), (None, None), "zeros", dtype="float32"),
        "gn_w": P((da,), ("inner",), "ones", dtype="float32"),
        "gn_b": P((da,), ("inner",), "zeros", dtype="float32"),
        "wo": P((da, d), ("inner", "embed")),
    }


def rwkv_cm_meta(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {"mu": P((2, d), (None, "embed"), "zeros"),   # k, r mixes
            "wk": P((d, f), ("embed", "mlp")),
            "wv": P((f, d), ("mlp", "embed")),
            "wr": P((d, d), ("embed", None))}


def rwkv_cache_meta(cfg, batch: int) -> dict:
    H, K = _rwkv_dims(cfg)
    d = cfg.d_model
    return {"x_tm": P((batch, d), ("batch", "embed"), "zeros"),
            "x_cm": P((batch, d), ("batch", "embed"), "zeros"),
            "h": P((batch, H, K, K), ("batch", None, None, None), "zeros",
                   dtype="float32")}


def _shift(x, x_prev):
    """Previous-token tensor: (B,S,d) shifted right, first slot = x_prev."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _lerp(x, xp, mu):
    return x + (xp - x) * mu.astype(x.dtype)


def _rwkv_project(cfg, p, x, xp):
    B, S, d = x.shape
    H, K = _rwkv_dims(cfg)
    r = _lerp(x, xp, p["mu"][0]) @ p["wr"]
    xw = _lerp(x, xp, p["mu"][1])
    k = _lerp(x, xp, p["mu"][2]) @ p["wk"]
    v = _lerp(x, xp, p["mu"][3]) @ p["wv"]
    g = jax.nn.silu(_lerp(x, xp, p["mu"][4]) @ p["wg"])
    w = jnp.exp(-jnp.exp(
        p["w0"] + (jnp.tanh(xw @ p["w1"]) @ p["w2"]).astype(jnp.float32)))
    shp = (B, S, H, K)
    return (r.reshape(shp), w.reshape(shp), k.reshape(shp),
            v.reshape(shp), g)


def rwkv_apply(cfg, p, x, h0=None, x_prev=None, return_cache=False):
    B, S, d = x.shape
    H, K = _rwkv_dims(cfg)
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    r, w, k, v, g = _rwkv_project(cfg, p, x, _shift(x, x_prev))
    o, h = ops.rwkv_scan(r, w, k, v, p["u"], h0)
    o = groupnorm_heads(o, p["gn_w"].reshape(H, K), p["gn_b"].reshape(H, K))
    out = (o.reshape(B, S, H * K) * g) @ p["wo"]
    if not return_cache:
        return out
    return out, {"x_tm": x[:, -1], "h": h}


def rwkv_decode(cfg, p, x, cache):
    """x: (B, 1, d)."""
    B, _, d = x.shape
    H, K = _rwkv_dims(cfg)
    r, w, k, v, g = _rwkv_project(cfg, p, x, cache["x_tm"][:, None])
    o, h = ops.rwkv_decode_step(r[:, 0], w[:, 0], k[:, 0], v[:, 0],
                                p["u"], cache["h"])
    o = groupnorm_heads(o, p["gn_w"].reshape(H, K), p["gn_b"].reshape(H, K))
    out = (o.reshape(B, 1, H * K) * g) @ p["wo"]
    return out, {"x_tm": x[:, 0], "h": h}


def rwkv_cm_apply(cfg, p, x, x_prev=None):
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    xp = _shift(x, x_prev)
    k = jnp.square(jax.nn.relu(_lerp(x, xp, p["mu"][0]) @ p["wk"]))
    return jax.nn.sigmoid(_lerp(x, xp, p["mu"][1]) @ p["wr"]) * (k @ p["wv"])


def rwkv_cm_decode(cfg, p, x, x_prev):
    k = jnp.square(jax.nn.relu(_lerp(x, x_prev[:, None], p["mu"][0]) @ p["wk"]))
    return jax.nn.sigmoid(_lerp(x, x_prev[:, None], p["mu"][1]) @ p["wr"]) * (k @ p["wv"])
