"""Whisper-style encoder-decoder backbone.

The audio frontend (mel + conv downsampling) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, d) and the
encoder consumes them directly (plus a learned-equivalent sinusoidal
position). The decoder is a causal transformer with per-layer cross
attention; decode shapes use a self-attention cache of ``seq_len`` plus a
static cross-attention cache over the stub encoder states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.models import attention as attn
from repro.models.layers import (
    P, apply_norm, cast_params, embed_meta, embed_tokens, mlp_apply,
    mlp_meta, norm_meta, sincos_positions, stack_meta, unembed,
)


def _xattn_meta(cfg) -> dict:
    d, H, D = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {"wq": P((d, H * D), ("embed", "heads")),
            "wk": P((d, H * D), ("embed", "heads")),
            "wv": P((d, H * D), ("embed", "heads")),
            "wo": P((H * D, d), ("heads", "embed"))}


def encdec_meta(cfg) -> dict:
    enc_layer = {"ln1": norm_meta(cfg), "attn": attn.attn_meta(cfg),
                 "ln2": norm_meta(cfg), "mlp": mlp_meta(cfg)}
    dec_layer = {"ln1": norm_meta(cfg), "attn": attn.attn_meta(cfg),
                 "lnx": norm_meta(cfg), "xattn": _xattn_meta(cfg),
                 "ln2": norm_meta(cfg), "mlp": mlp_meta(cfg)}
    return {
        "embed": embed_meta(cfg),
        "enc_in": P((cfg.d_model, cfg.d_model), ("embed", None)),  # frontend stub proj
        "enc": stack_meta(enc_layer, cfg.n_enc_layers),
        "ln_enc": norm_meta(cfg),
        "dec": stack_meta(dec_layer, cfg.n_layers),
        "ln_f": norm_meta(cfg),
    }


def encdec_cache_meta(cfg, batch: int, cache_len: int) -> dict:
    H, D = cfg.n_heads, cfg.head_dim
    S_x = cfg.cross_seq
    layer = {
        "k": P((batch, cache_len, H, D), ("batch", "kv_seq", "heads", None), "zeros"),
        "v": P((batch, cache_len, H, D), ("batch", "kv_seq", "heads", None), "zeros"),
        "xk": P((batch, S_x, H, D), ("batch", None, "heads", None), "zeros"),
        "xv": P((batch, S_x, H, D), ("batch", None, "heads", None), "zeros"),
    }
    return {"dec": stack_meta(layer, cfg.n_layers)}


def encode(cfg, params, frames):
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    B, S, d = frames.shape
    x = frames.astype(dtype) @ params["enc_in"]
    x = x + sincos_positions(S, d).astype(dtype)[None]
    x = shard(x, "batch", "seq", None)

    def block(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = attn._project_qkv(cfg, lp["attn"], h, jnp.arange(S))
        o = ops.attention(q, k, v, causal=False)
        x = x + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        h = apply_norm(cfg, lp["ln2"], x)
        return shard(x + mlp_apply(cfg, lp["mlp"], h),
                     "batch", "seq_block", None), None

    x, _ = jax.lax.scan(jax.checkpoint(block, prevent_cse=False), x,
                        params["enc"])
    return apply_norm(cfg, params["ln_enc"], x)


def _cross_kv(cfg, lp, enc):
    B, Sx, _ = enc.shape
    H, D = cfg.n_heads, cfg.head_dim
    k = (enc @ lp["xattn"]["wk"]).reshape(B, Sx, H, D)
    v = (enc @ lp["xattn"]["wv"]).reshape(B, Sx, H, D)
    return k, v


def _dec_layer(cfg, lp, x, enc_kv, positions, self_cache=None, cur_len=None):
    """One decoder layer; full-seq if self_cache is None, else one-token."""
    B = x.shape[0]
    H, D = cfg.n_heads, cfg.head_dim
    h = apply_norm(cfg, lp["ln1"], x)
    new_cache = None
    if self_cache is None:
        q, k, v = attn._project_qkv(cfg, lp["attn"], h, positions)
        o = ops.attention(q, k, v, causal=True)
        x = x + o.reshape(*x.shape[:2], -1) @ lp["attn"]["wo"]
    else:
        pos = jnp.full((B, 1), cur_len, jnp.int32)
        q, k, v = attn._project_qkv(cfg, lp["attn"], h, pos)
        ck = jax.lax.dynamic_update_slice_in_dim(self_cache["k"], k, cur_len, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(self_cache["v"], v, cur_len, 1)
        ck = shard(ck, "batch", "kv_seq", "heads", None)
        cv = shard(cv, "batch", "kv_seq", "heads", None)
        kv_len = jnp.full((B,), cur_len + 1, jnp.int32)
        o = ops.decode_attention(q, ck, cv, kv_len=kv_len)
        x = x + o.reshape(B, 1, -1) @ lp["attn"]["wo"]
        new_cache = {"k": ck, "v": cv}
    h = apply_norm(cfg, lp["lnx"], x)
    q = (h @ lp["xattn"]["wq"]).reshape(*x.shape[:2], H, D)
    xk, xv = enc_kv
    o = ops.attention(q, xk, xv, causal=False)
    x = x + o.reshape(*x.shape[:2], -1) @ lp["xattn"]["wo"]
    h = apply_norm(cfg, lp["ln2"], x)
    x = x + mlp_apply(cfg, lp["mlp"], h)
    return shard(x, "batch", "seq", None), new_cache


def encdec_forward(cfg, params, frames, tokens, *, remat: bool = True):
    """Returns (decoder hidden (B, S_dec, d), aux=0)."""
    dtype = jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, frames)
    params = cast_params(params, dtype)
    B, S = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens, dtype)
    x = x + sincos_positions(S, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(S)

    def block(x, lp):
        kv = _cross_kv(cfg, lp, enc)
        x, _ = _dec_layer(cfg, lp, x, kv, positions)
        return shard(x, "batch", "seq_block", None), None

    fn = jax.checkpoint(block, prevent_cse=False) if remat else block
    x, _ = jax.lax.scan(fn, x, params["dec"])
    return apply_norm(cfg, params["ln_f"], x), jnp.zeros((), jnp.float32)


def encdec_prefill(cfg, params, frames, tokens, *, cache_len: int):
    """Encode + decoder prefill. Returns (last logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, frames)
    params = cast_params(params, dtype)
    B, S = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens, dtype)
    x = x + sincos_positions(S, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(S)

    def block(x, lp):
        xk, xv = _cross_kv(cfg, lp, enc)
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = attn._project_qkv(cfg, lp["attn"], h, positions)
        o = ops.attention(q, k, v, causal=True)
        x = x + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        h = apply_norm(cfg, lp["lnx"], x)
        qx = (h @ lp["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        o = ops.attention(qx, xk, xv, causal=False)
        x = x + o.reshape(B, S, -1) @ lp["xattn"]["wo"]
        h = apply_norm(cfg, lp["ln2"], x)
        x = x + mlp_apply(cfg, lp["mlp"], h)
        cache = {"k": attn._fit(k, cache_len), "v": attn._fit(v, cache_len),
                 "xk": xk, "xv": xv}
        return x, cache

    x, caches = jax.lax.scan(block, x, params["dec"])
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    return logits, {"dec": caches, "cur_len": jnp.asarray(S, jnp.int32)}


def encdec_decode_step(cfg, params, cache, tokens):
    dtype = jnp.dtype(cfg.dtype)
    params = cast_params(params, dtype)
    cur_len = cache["cur_len"]
    B = tokens.shape[0]
    x = embed_tokens(cfg, params["embed"], tokens, dtype)
    x = x + sincos_positions(1, cfg.d_model, offset=cur_len).astype(dtype)[None]

    def block(x, lp_cache):
        lp, c = lp_cache
        x, new = _dec_layer(cfg, lp, x, (c["xk"], c["xv"]), None,
                            self_cache={"k": c["k"], "v": c["v"]},
                            cur_len=cur_len)
        return x, {**new, "xk": c["xk"], "xv": c["xv"]}

    x, new_caches = jax.lax.scan(block, x, (params["dec"], cache["dec"]))
    x = apply_norm(cfg, params["ln_f"], x)
    logits = unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    return logits, {"dec": new_caches, "cur_len": cur_len + 1}
