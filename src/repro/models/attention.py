"""Attention mixers: GQA/MHA, sliding-window, MLA (DeepSeek-V2).

Three entry modes per layer:
  * train:   full forward, no cache.
  * prefill: full forward, returns the layer's decode cache.
  * decode:  one new token against the cache, returns updated cache.

Caches are sequence-sharded under the serve rules ("kv_seq" -> model axis);
the decode softmax then reduces over a sharded axis, which GSPMD lowers to
local partial reductions + small all-reduces (distributed-LSE) instead of
gathering the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.models.layers import P, norm_meta, apply_norm, rope


# --------------------------------------------------------------------------
# parameter metadata
# --------------------------------------------------------------------------

def attn_meta(cfg) -> dict:
    d, H, KV, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        meta = {
            "wq_a": P((d, m.q_lora), ("embed", "lora")),
            "q_norm": norm_meta(cfg, m.q_lora),
            "wq_b": P((m.q_lora, H * (m.qk_nope + m.qk_rope)), ("lora", "heads")),
            "wkv_a": P((d, m.kv_lora + m.qk_rope), ("embed", None)),
            "kv_norm": norm_meta(cfg, m.kv_lora),
            "wkv_b": P((m.kv_lora, H * (m.qk_nope + m.v_head)), ("lora", "heads")),
            "wo": P((H * m.v_head, d), ("heads", "embed")),
        }
        return meta
    meta = {
        "wq": P((d, H * D), ("embed", "heads")),
        "wk": P((d, KV * D), ("embed", "kv_heads")),
        "wv": P((d, KV * D), ("embed", "kv_heads")),
        "wo": P((H * D, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        meta["bq"] = P((H * D,), ("heads",), "zeros")
        meta["bk"] = P((KV * D,), ("kv_heads",), "zeros")
        meta["bv"] = P((KV * D,), ("kv_heads",), "zeros")
    if cfg.qk_norm:
        meta["qn"] = norm_meta(cfg, D)
        meta["kn"] = norm_meta(cfg, D)
    return meta


def attn_cache_meta(cfg, spec, batch: int, cache_len: int) -> dict:
    """Decode-cache metadata for one attention layer (as P entries)."""
    if cfg.mla is not None:
        m = cfg.mla
        return {"ckv": P((batch, cache_len, m.kv_lora),
                         ("batch", "kv_seq", None), "zeros"),
                "kr": P((batch, cache_len, m.qk_rope),
                        ("batch", "kv_seq", None), "zeros")}
    KV, D = cfg.n_kv_heads, cfg.head_dim
    L = min(spec.window, cache_len) if spec.window else cache_len
    return {"k": P((batch, L, KV, D), ("batch", "kv_seq", "kv_heads", None), "zeros"),
            "v": P((batch, L, KV, D), ("batch", "kv_seq", "kv_heads", None), "zeros")}


# --------------------------------------------------------------------------
# GQA forward
# --------------------------------------------------------------------------

def _project_qkv(cfg, p, x, positions):
    B, S, d = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, KV, D)
    v = v.reshape(B, S, KV, D)
    if cfg.qk_norm:
        q = apply_norm(cfg, p["qn"], q)
        k = apply_norm(cfg, p["kn"], k)
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(cfg, spec, p, x, positions):
    """Full-sequence (train) attention."""
    if cfg.mla is not None:
        return _mla_apply(cfg, p, x, positions)[0]
    q, k, v = _project_qkv(cfg, p, x, positions)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    o = ops.attention(q, k, v, causal=True, window=spec.window)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


def attn_prefill(cfg, spec, p, x, positions, cache_len: int):
    """Forward + build this layer's decode cache (length ``cache_len``)."""
    if cfg.mla is not None:
        y, (ckv, kr) = _mla_apply(cfg, p, x, positions)
        return y, {"ckv": _fit(ckv, cache_len), "kr": _fit(kr, cache_len)}
    q, k, v = _project_qkv(cfg, p, x, positions)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    o = ops.attention(q, k, v, causal=True, window=spec.window)
    B, S = x.shape[:2]
    y = o.reshape(B, S, -1) @ p["wo"]
    if spec.window and cache_len >= spec.window:
        cache = {"k": _roll_window(k, spec.window),
                 "v": _roll_window(v, spec.window)}
    else:
        cache = {"k": _fit(k, cache_len), "v": _fit(v, cache_len)}
    cache = {n: shard(c, "batch", "kv_seq", "kv_heads", None)
             if c.ndim == 4 else shard(c, "batch", "kv_seq", None)
             for n, c in cache.items()}
    return y, cache


def _fit(t, L):
    """Pad/trim a (B, S, ...) tensor to cache length L along axis 1."""
    S = t.shape[1]
    if S == L:
        return t
    if S > L:
        return t[:, -L:]
    pad = [(0, 0)] * t.ndim
    pad[1] = (0, L - S)
    return jnp.pad(t, pad)


def _roll_window(t, W):
    """Last W entries arranged so slot = position % W (rolling cache)."""
    S = t.shape[1]
    tail = t[:, S - W:]
    slots = jnp.arange(S - W, S) % W
    out = jnp.zeros_like(tail)
    return out.at[:, slots].set(tail)


def attn_decode(cfg, spec, p, x, cache, cur_len):
    """One-token decode. x: (B, 1, d).

    ``cur_len`` is the tokens-so-far count — a scalar (the classic
    lock-step cache where every row is at the same position) or a
    ``(B,)`` vector for continuous batching, where each slot of the
    batched cache sits at its own length: positions, the cache insert,
    and the validity mask are then all per-row, and the ragged
    ``kv_len`` flows straight into :func:`ops.decode_attention` (the
    Pallas ragged decode kernel's contract).
    """
    if cfg.mla is not None:
        return _mla_decode(cfg, p, x, cache, cur_len)
    B = x.shape[0]
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ragged = jnp.ndim(cur_len) == 1
    if ragged:
        pos = cur_len.astype(jnp.int32)[:, None]
    else:
        pos = jnp.full((B, 1), cur_len, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, pos)
    L = cache["k"].shape[1]
    slot = cur_len % L if spec.window else cur_len
    if ragged:
        # per-row insert: row b writes its token at its own slot[b]
        ck = cache["k"].at[jnp.arange(B), slot].set(k[:, 0])
        cv = cache["v"].at[jnp.arange(B), slot].set(v[:, 0])
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
    cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
    if spec.window:
        # rolling cache: slot s holds position s + L*floor((t-s)/L), t = cur_len
        s_idx = jnp.arange(L)
        if ragged:
            pos_of_slot = s_idx[None] + L * ((cur_len[:, None] - s_idx[None])
                                             // L)
            valid = pos_of_slot >= 0
        else:
            pos_of_slot = s_idx + L * ((cur_len - s_idx) // L)
            valid = (pos_of_slot >= 0)[None].repeat(B, 0)
        o = _masked_decode(cfg, q, ck, cv, valid)
    else:
        kv_len = (cur_len.astype(jnp.int32) + 1 if ragged
                  else jnp.full((B,), cur_len + 1, jnp.int32))
        o = ops.decode_attention(q, ck, cv, kv_len=kv_len)
    y = o.reshape(B, 1, H * D) @ p["wo"]
    return y, {"k": ck, "v": cv}


def _masked_decode(cfg, q, k, v, valid):
    """Decode attention with an explicit (B, L) validity mask."""
    B, _, H, D = q.shape
    L, KV = k.shape[1], k.shape[2]
    G = H // KV
    s = jnp.einsum("bkgd,bskd->bkgs",
                   (q[:, 0].astype(jnp.float32) * D**-0.5).reshape(B, KV, G, D),
                   k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None], s, ops.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", pr, v.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV cache, absorbed decode
# --------------------------------------------------------------------------

def _mla_project(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = apply_norm(cfg, p["q_norm"], x @ p["wq_a"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]
    ckv = apply_norm(cfg, p["kv_norm"], kv[..., :m.kv_lora])
    kr = rope(kv[..., m.kv_lora:][:, :, None], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, kr


def _mla_apply(cfg, p, x, positions):
    """Training/prefill MLA: expand k/v from the compressed latent."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, ckv, kr = _mla_project(cfg, p, x, positions)
    kvb = (ckv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope + m.v_head)
    k_nope, v = kvb[..., :m.qk_nope], kvb[..., m.qk_nope:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None],
                                                  (B, S, H, m.qk_rope))], axis=-1)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "heads", None)
    scale = (m.qk_nope + m.qk_rope) ** -0.5
    o = ops.attention(q, k, v, causal=True, scale=scale)
    y = o.reshape(B, S, H * m.v_head) @ p["wo"]
    return y, (ckv, kr)


def _mla_decode(cfg, p, x, cache, cur_len):
    """Absorbed-matrix decode: attend in the 512-d latent space.

    ``cur_len`` scalar (lock-step) or ``(B,)`` (ragged slots), as in
    :func:`attn_decode`.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    ragged = jnp.ndim(cur_len) == 1
    if ragged:
        pos = cur_len.astype(jnp.int32)[:, None]
    else:
        pos = jnp.full((B, 1), cur_len, jnp.int32)
    q_nope, q_rope, ckv_t, kr_t = _mla_project(cfg, p, x, pos)
    if ragged:
        ckv = cache["ckv"].at[jnp.arange(B), cur_len].set(ckv_t[:, 0])
        kr = cache["kr"].at[jnp.arange(B), cur_len].set(kr_t[:, 0])
    else:
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t,
                                                  cur_len, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_t,
                                                 cur_len, axis=1)
    ckv = shard(ckv, "batch", "kv_seq", None)
    kr = shard(kr, "batch", "kv_seq", None)
    wkv_b = p["wkv_b"].reshape(m.kv_lora, H, m.qk_nope + m.v_head)
    wk = wkv_b[..., :m.qk_nope]            # (lora, H, nope)
    wv = wkv_b[..., m.qk_nope:]            # (lora, H, v)
    # absorb wk into q: (B,1,H,nope) x (lora,H,nope) -> (B,H,lora)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], wk)
    scale = (m.qk_nope + m.qk_rope) ** -0.5
    s = (jnp.einsum("bhl,bsl->bhs", q_lat.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    k_pos = jnp.arange(ckv.shape[1])
    bound = cur_len[:, None, None] if ragged else cur_len
    s = jnp.where(k_pos[None, None, :] <= bound, s, ops.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", pr, ckv.astype(jnp.float32))   # (B,H,lora)
    o = jnp.einsum("bhl,lhv->bhv", o_lat.astype(x.dtype), wv)
    y = o.reshape(B, 1, H * m.v_head) @ p["wo"]
    return y, {"ckv": ckv, "kr": kr}
