"""Mixture-of-Experts MLP with capacity-based, sort-free gather dispatch.

Dispatch is per-example (vmapped over batch): per-expert capacity
C = ceil(S * top_k / E * capacity_factor). Tokens beyond capacity are
dropped (standard Switch/GShard semantics). Expert weights carry the
"experts" logical axis; on meshes where E divides the model axis this is
expert parallelism (GSPMD inserts the token all-to-all), otherwise the
d_expert axis shards instead (tensor-parallel experts — e.g. granite's
E=40 on a 16-way axis).

Returns (y, aux_loss); aux is the Switch load-balance loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import P, act_fn


def moe_meta(cfg) -> dict:
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    meta = {
        "router": P((d, e), ("embed", None), scale=d**-0.5),
        "wg": P((e, d, f), ("experts", "embed", "mlp")),
        "wi": P((e, d, f), ("experts", "embed", "mlp")),
        "wo": P((e, f, d), ("experts", "mlp", "embed")),
    }
    if m.n_shared:
        fs = m.d_expert * m.n_shared
        meta["shared"] = {"wg": P((d, fs), ("embed", "mlp")),
                          "wi": P((d, fs), ("embed", "mlp")),
                          "wo": P((fs, d), ("mlp", "embed"))}
    return meta


def _capacity(cfg, S: int) -> int:
    m = cfg.moe
    c = int(S * m.top_k / m.n_experts * m.capacity_factor)
    return max(4, -(-c // 4) * 4)


def moe_apply(cfg, p, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B, S, d), aux scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    C = _capacity(cfg, S)
    act = act_fn(cfg.act)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                             # (B,S,K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e fraction_routed_e * mean_prob_e
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1))) * m.router_aux_weight

    def dispatch_one(xe, idx_e, gate_e):
        """Per example: xe (S,d), idx (S,K), gate (S,K).

        Within-expert positions come from a stable sort of the expert
        assignments — O(S*K log) time and O(S*K) memory, versus the
        O(S*K*E) one-hot-cumsum form (which at 32k tokens x 40 experts
        materializes tens of GB of bookkeeping per example)."""
        flat_e = idx_e.reshape(-1)                 # (S*K,)
        flat_t = jnp.repeat(jnp.arange(S), K)      # token of each slot
        flat_g = gate_e.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        start = jnp.searchsorted(sorted_e, jnp.arange(E))        # (E,)
        pos_sorted = jnp.arange(S * K) - start[sorted_e]
        pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
        keep = pos < C
        dest = jnp.where(keep, flat_e * C + pos, E * C)          # overflow slot
        buf = jnp.zeros((E * C + 1, d), xe.dtype).at[dest].add(
            xe[flat_t] * keep[:, None].astype(xe.dtype))
        return buf[:-1].reshape(E, C, d), (dest, flat_t, flat_g, keep)

    buf, (dest, flat_t, flat_g, keep) = jax.vmap(dispatch_one)(x, idx, gate)
    buf = shard(buf, "batch", "experts", None, None)

    h = act(jnp.einsum("becd,edf->becf", buf, p["wg"])) * \
        jnp.einsum("becd,edf->becf", buf, p["wi"])
    out = jnp.einsum("becf,efd->becd", h, p["wo"])                # (B,E,C,d)
    out = shard(out, "batch", "experts", None, None)

    def combine_one(out_e, dest_e, flat_t_e, flat_g_e, keep_e):
        flat = jnp.concatenate([out_e.reshape(E * C, d),
                                jnp.zeros((1, d), out_e.dtype)])
        contrib = flat[dest_e] * (flat_g_e * keep_e).astype(out_e.dtype)[:, None]
        return jnp.zeros((S, d), out_e.dtype).at[flat_t_e].add(contrib)

    y = jax.vmap(combine_one)(out, dest, flat_t, flat_g, keep)
    if m.n_shared:
        sp = p["shared"]
        y = y + (act(x @ sp["wg"]) * (x @ sp["wi"])) @ sp["wo"]
    return y, aux.astype(jnp.float32)
