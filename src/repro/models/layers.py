"""Parameter metadata + primitive layers (pure JAX, no flax).

Parameters are declared as trees of :class:`P` metadata (shape, logical
axes, initializer). A single metadata tree is the source of truth for
initialization, ``jax.eval_shape`` stand-ins, and sharding specs — so the
three can never drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class P:
    """Parameter metadata. ``axes`` are logical-axis names per dimension."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # stddev; default fan_in**-0.5
    dtype: str | None = None      # override (norm scales stay f32)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_meta_leaf(x) -> bool:
    return isinstance(x, P)


def stack_meta(tree, n: int):
    """Prepend a stacking dim (for scan-over-blocks parameters)."""
    return jax.tree.map(
        lambda p: P((n, *p.shape), (None, *p.axes), p.init, p.scale, p.dtype),
        tree, is_leaf=is_meta_leaf)


def init_params(tree, key: jax.Array, dtype=jnp.float32):
    """Materialize a metadata tree into arrays (deterministic per-path)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_meta_leaf)

    def make(path, p: P):
        dt = jnp.dtype(p.dtype) if p.dtype else dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        k = key
        for entry in path:
            k = jax.random.fold_in(k, hash(str(entry)) % (2**31))
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = p.scale if p.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, p.shape, jnp.float32) * scale).astype(dt)

    return treedef.unflatten([make(path, p) for path, p in flat])


def abstract_params(tree, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(
            p.shape, jnp.dtype(p.dtype) if p.dtype else dtype),
        tree, is_leaf=is_meta_leaf)


def meta_axes(tree):
    """Tree of logical-axes tuples, same structure as params."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_meta_leaf)


def cast_params(params, dtype):
    """Compute-dtype cast: matrices -> dtype, 1-D scales stay put."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.ndim > 1 and a.dtype == jnp.float32 else a,
        params)


# --------------------------------------------------------------------------
# primitive layers
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (n * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(x: jax.Array, w: jax.Array, b: jax.Array,
                    eps: float = 64e-5) -> jax.Array:
    """Per-head groupnorm (RWKV output norm). x: (..., H, V)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (n * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_meta(cfg, d: int | None = None) -> dict:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": P((d,), (None,), "ones", dtype="float32"),
                "b": P((d,), (None,), "zeros", dtype="float32")}
    return {"w": P((d,), (None,), "ones", dtype="float32")}


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


def rope(x: jax.Array, positions: jax.Array, theta: float,
         rot_dims: int | None = None) -> jax.Array:
    """Rotary embedding, half-split convention.

    x: (B, S, H, D); positions: (S,) or (B, S). Rotates the first
    ``rot_dims`` dims of D (default: all).
    """
    B, S, H, D = x.shape
    R = rot_dims or D
    half = R // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :R].astype(jnp.float32)
    x1, x2 = xr[..., :half], xr[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., R:]], axis=-1)


def sincos_positions(S: int, d: int, offset=0) -> jax.Array:
    """Fixed sinusoidal position embeddings (whisper-style)."""
    pos = jnp.arange(S, dtype=jnp.float32) + offset
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = pos[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---- dense MLP -----------------------------------------------------------

def mlp_meta(cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_kind == "plain":
        return {"wi": P((d, f), ("embed", "mlp")),
                "bi": P((f,), ("mlp",), "zeros"),
                "wo": P((f, d), ("mlp", "embed")),
                "bo": P((d,), (None,), "zeros")}
    return {"wg": P((d, f), ("embed", "mlp")),
            "wi": P((d, f), ("embed", "mlp")),
            "wo": P((f, d), ("mlp", "embed"))}


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.act)
    if cfg.mlp_kind == "plain":
        h = act(x @ p["wi"] + p["bi"].astype(x.dtype))
        return h @ p["wo"] + p["bo"].astype(x.dtype)
    return (act(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


# ---- embeddings ----------------------------------------------------------

def embed_meta(cfg) -> dict:
    m = {"tok": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        m["head"] = P((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return m


def embed_tokens(cfg, p: dict, tokens: jax.Array, dtype) -> jax.Array:
    x = p["tok"].astype(dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return x


def unembed(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["tok"].astype(x.dtype).T
    return x @ p["head"].astype(x.dtype)
