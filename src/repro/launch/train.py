"""Training driver CLI.

Container scale (tiny smoke config, real training):
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \\
        --steps 100

Production lowering check (no execution, 512 fake devices):
    handled by repro.launch.dryrun; this driver runs REAL steps on
    whatever devices exist, with checkpoint/restart fault tolerance.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.tokens import TokenLoader
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig, adamw_update
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    print(f"{cfg.name}: {model.n_params():,} params on "
          f"{len(jax.devices())} device(s)")
    hp = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt, gn = adamw_update(grads, opt, params, hp)
        return params, opt, {"loss": loss, "grad_norm": gn,
                             "step": opt.count}

    loader = TokenLoader(cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    tc = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(model, jax.jit(step), loader, tc)
    trainer.run()


if __name__ == "__main__":
    main()
