"""Named lowering variants for the perf hillclimb.

A variant bundles the sharding rules + model/step knobs that one §Perf
iteration changes. ``baseline`` is the paper-faithful starting point; the
hillclimb registers additional variants and the dry-run lowers any of them
with ``--variant``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.distributed import sharding as shd


@dataclass(frozen=True)
class Variant:
    name: str
    train_rules: shd.Rules = field(default_factory=lambda: dict(shd.TRAIN_RULES))
    serve_rules: shd.Rules = field(default_factory=lambda: dict(shd.SERVE_RULES))
    # model-config overrides applied via cfg.replace(**model_overrides)
    model_overrides: dict = field(default_factory=dict)
    notes: str = ""


def _rules(base: shd.Rules, **kw) -> shd.Rules:
    r = dict(base)
    r.update(kw)
    return r


VARIANTS: dict[str, Variant] = {}


def register(v: Variant) -> Variant:
    VARIANTS[v.name] = v
    return v


register(Variant(
    name="baseline",
    train_rules=_rules(shd.TRAIN_RULES, attn_q=None),
    serve_rules=_rules(shd.SERVE_RULES, attn_q=None),
    notes="starting point: 2-D FSDPxTP train sharding, sequence-parallel "
          "boundaries, sequence-sharded serve caches; heads-only "
          "attention sharding (no q-row fallback)"))

# ---- hillclimb variants (see EXPERIMENTS.md §Perf for the log) -----------

register(Variant(
    name="attn_q",
    notes="§Perf iter: q-row sharding fallback for head counts that don't "
          "divide the model axis (qwen2.5 40H, whisper 20H, granite 24H)",
))

register(Variant(
    name="seq_data_cache",
    serve_rules=_rules(shd.SERVE_RULES, kv_seq=("model", "data"),
                       batch=("pod",)),
    notes="decode: shard cache sequence over BOTH data+model axes "
          "(batch stays on pod only) — for small-batch long-context decode",
))

register(Variant(
    name="serve_repl_w",
    serve_rules=_rules(shd.SERVE_RULES, embed=None),
    notes="§Perf iter (decode): drop the FSDP dimension at serve time — "
          "weights sharded only over the model axis, so decode stops "
          "all-gathering weight shards every step (latency path); "
          "memory check: weights/16 must fit beside the cache shard",
))

register(Variant(
    name="moe_cf1",
    model_overrides={"moe_capacity_factor": 1.0},
    notes="§Perf iter (MoE train): capacity_factor 1.25 -> 1.0 trims the "
          "dispatch buffer slack: less all-to-all + expert-compute waste "
          "at the cost of more dropped tokens under imbalance",
))


def get_variant(name: str) -> Variant:
    if name not in VARIANTS:
        raise KeyError(f"unknown variant {name!r}: {sorted(VARIANTS)}")
    return VARIANTS[name]
