"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips ("data", "model");
multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the leading
"pod" axis spans the inter-pod (DCN-class) links.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / smoke runs)."""
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
