"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (sharding propagation succeeds, memory fits, collectives
lower) and extracts the roofline terms (§Roofline) from the compiled
artifact. No arrays are ever allocated — inputs are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--variant baseline]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module (jax
# locks the device count on first init). Everything below is ordinary.
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import ARCHS, SHAPES, get_config, supports_shape
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.variants import get_variant
from repro.models.model import build_model
from repro.roofline import analysis
from repro.train.optimizer import AdamWConfig, OptState
from repro.train.train_step import make_train_shardings, make_train_step
from repro.serve.serve_step import (
    jit_decode_step, make_prefill, make_serve_shardings,
)


def _tree_bytes(tree) -> float:
    import numpy as np
    return float(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree.leaves(tree)))


def _abstract_opt(aparams, psh):
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    return (OptState(m=f32(aparams), v=f32(aparams),
                     count=jax.ShapeDtypeStruct((), jnp.int32)),
            OptState(m=psh.params, v=psh.params,
                     count=NamedSharding(psh.mesh, PS())))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               variant_name: str = "baseline"):
    """Returns (lowered, compiled, roofline, meta) for one cell."""
    variant = get_variant(variant_name)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if variant.model_overrides:
        import dataclasses
        overrides = dict(variant.model_overrides)
        cf = overrides.pop("moe_capacity_factor", None)
        if cf is not None and cfg.moe is not None:
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=cf))
        if overrides:
            cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    specs = model.input_specs(shape)
    t0 = time.time()
    param_bytes = cache_bytes = 0.0

    if shape.kind == "train":
        sh = make_train_shardings(model, mesh, variant.train_rules,
                                  batch_specs=specs)
        step = make_train_step(model, AdamWConfig(), sh)
        aparams = model.abstract_params(jnp.float32)
        param_bytes = _tree_bytes(aparams)
        aopt, osh = _abstract_opt(aparams, sh)
        with shd.use_sharding(mesh, sh.rules):
            lowered = jax.jit(
                step,
                in_shardings=(sh.params, osh, sh.batch),
                out_shardings=(sh.params, osh, NamedSharding(mesh, PS())),
                donate_argnums=(0, 1),
            ).lower(aparams, aopt, specs)
    elif shape.kind == "prefill":
        rules = variant.serve_rules
        ssh = make_serve_shardings(model, mesh, shape.global_batch,
                                   shape.seq_len, rules)
        prefill = make_prefill(model, ssh, cache_len=shape.seq_len)
        aparams = model.abstract_params(jnp.dtype(cfg.dtype))
        param_bytes = _tree_bytes(aparams)
        bsh = {k: NamedSharding(mesh, shd.spec_for(
                  ("batch",) + (None,) * (len(v.shape) - 1), v.shape, mesh,
                  rules)) for k, v in specs.items()}
        acache = model.abstract_cache(shape.global_batch, shape.seq_len)
        logit_sh = NamedSharding(mesh, shd.spec_for(
            ("batch", "vocab"), (shape.global_batch, cfg.vocab_size), mesh,
            rules))
        with shd.use_sharding(mesh, rules):
            lowered = jax.jit(
                prefill,
                in_shardings=(ssh.params, bsh),
                out_shardings=(logit_sh, ssh.cache),
            ).lower(aparams, specs)
    else:  # decode
        rules = variant.serve_rules
        ssh = make_serve_shardings(model, mesh, shape.global_batch,
                                   shape.seq_len, rules)
        aparams = model.abstract_params(jnp.dtype(cfg.dtype))
        acache = model.abstract_cache(shape.global_batch, shape.seq_len)
        param_bytes = _tree_bytes(aparams)
        cache_bytes = _tree_bytes(acache)
        with shd.use_sharding(mesh, rules):
            lowered = jit_decode_step(model, ssh, shape.global_batch).lower(
                aparams, acache, specs["tokens"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    roof = analysis.from_compiled(arch, shape_name, mesh_name, chips,
                                  compiled, cfg, shape,
                                  param_bytes=param_bytes,
                                  cache_bytes=cache_bytes)
    meta = {"t_lower_s": t_lower, "t_compile_s": t_compile,
            "variant": variant_name}
    return lowered, compiled, roof, meta


def run_cell(arch, shape_name, multi_pod, variant, out_dir):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}"
    os.makedirs(f"{out_dir}/{mesh_name}", exist_ok=True)
    path = f"{out_dir}/{mesh_name}/{tag}.json"
    if variant != "baseline":
        path = f"{out_dir}/{mesh_name}/{tag}__{variant}.json"
    try:
        lowered, compiled, roof, meta = lower_cell(
            arch, shape_name, multi_pod=multi_pod, variant_name=variant)
        mem = compiled.memory_analysis()
        print(f"== {tag} [{mesh_name}] ==")
        print(compiled.memory_analysis())       # proves it fits
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        print({k: cost[k] for k in ("flops", "bytes accessed")
               if k in cost})                    # FLOPs/bytes for §Roofline
        rec = roof.to_dict()
        rec.update(meta)
        rec["status"] = "ok"
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"OK {tag} flops/chip={roof.hlo_flops:.3e} "
              f"coll={roof.coll_bytes:.3e}B bottleneck={roof.bottleneck} "
              f"frac={roof.roofline_fraction:.3f} "
              f"(lower {meta['t_lower_s']:.0f}s compile {meta['t_compile_s']:.0f}s)")
        return True
    except Exception as e:  # noqa: BLE001 — record and continue
        traceback.print_exc()
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "error", "variant": variant,
                       "error": f"{type(e).__name__}: {e}"}, f, indent=1)
        print(f"FAIL {tag}: {type(e).__name__}: {e}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape_name in SHAPES:
                cfg = get_config(arch)
                if not supports_shape(cfg, shape_name):
                    print(f"SKIP {arch}__{shape_name} (documented: needs "
                          "sub-quadratic attention)")
                    continue
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    ok = 0
    for arch, shape_name in cells:
        ok += run_cell(arch, shape_name, args.multi_pod, args.variant,
                       args.out)
    print(f"dry-run: {ok}/{len(cells)} cells passed")
    sys.exit(0 if ok == len(cells) else 1)


if __name__ == "__main__":
    main()
