"""Serving driver CLI: batched requests through the ServingEngine with an
AI-tax report (the paper's measurement, applied to LM serving).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \\
        --requests 8 --max-tokens 8
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=args.slots,
                        cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        eng.submit(Request(rid,
                           rng.integers(0, cfg.vocab_size, args.prompt_len),
                           max_tokens=args.max_tokens))
    done = eng.run()
    print(f"served {len(done)} requests "
          f"({sum(len(r.tokens) for r in done)} tokens)")
    rep = eng.tax_report()
    print(f"AI fraction {rep['ai_fraction']:.1%}  "
          f"tax {rep['tax_fraction']:.1%}")
    for stage, v in sorted(rep["per_stage"].items()):
        print(f"  {stage:<10} {v*1e3:8.2f} ms")


if __name__ == "__main__":
    main()
