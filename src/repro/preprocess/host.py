"""Host-side (NumPy) pre/post-processing baselines.

These are the reference implementations of the pre/post-processing tax:
planar YUV decode, letterbox resize + normalization, and detection
post-processing (score threshold + greedy IoU NMS). They are what a
CPU-bound deployment actually runs — the paper's Fig 8 "supporting
code" — and the oracle the device programs in
:mod:`repro.preprocess.device` must match.

Numeric discipline: every float op runs in float32 with the same
expression order as the device path, so host/device NMS *decisions*
(comparisons against thresholds) are bit-identical, not merely close.
"""
from __future__ import annotations

import functools

import numpy as np

# BT.601 full-range YUV <-> RGB (the classic JPEG/video matrix).
_YUV_TO_RGB = np.array([[1.0, 0.0, 1.402],
                        [1.0, -0.344136, -0.714136],
                        [1.0, 1.772, 0.0]], np.float32)
_RGB_TO_YUV = np.array([[0.299, 0.587, 0.114],
                        [-0.168736, -0.331264, 0.5],
                        [0.5, -0.418688, -0.081312]], np.float32)


def rgb_to_yuv(rgb: np.ndarray) -> np.ndarray:
    """(..., H, W, 3) uint8 RGB -> (..., 3, H, W) planar uint8 YUV.

    The *encoder* — it emulates what the camera/codec put on the wire,
    so it is deliberately not part of any taxed stage; the pipeline's
    taxed pre-processing starts at :func:`yuv_to_rgb`.
    """
    x = rgb.astype(np.float32)
    yuv = x @ _RGB_TO_YUV.T
    yuv[..., 1:] += 128.0
    yuv = np.clip(np.round(yuv), 0, 255).astype(np.uint8)
    return np.moveaxis(yuv, -1, -3)


def yuv_to_rgb(yuv: np.ndarray) -> np.ndarray:
    """(..., 3, H, W) planar uint8 YUV -> (..., H, W, 3) uint8 RGB.

    Frame decode-emulation (4:4:4 planes): the per-pixel 3x3 color
    transform every decoded frame pays before any AI sees it.
    """
    x = np.moveaxis(yuv, -3, -1).astype(np.float32)
    x = x - np.array([0.0, 128.0, 128.0], np.float32)
    rgb = x @ _YUV_TO_RGB.T.astype(np.float32)
    return np.clip(np.round(rgb), 0, 255).astype(np.uint8)


def interp_matrix(out_n: int, in_n: int) -> np.ndarray:
    """Bilinear interpolation operator rows (align_corners=False).

    One implementation for the whole repo:
    :func:`repro.kernels.resize._interp_matrix` is the canonical owner
    (the Pallas resize, the FusedIdentifier fold, and this letterbox
    all build from it), so the resize convention cannot fork.
    """
    from repro.kernels.resize import _interp_matrix
    return _interp_matrix(out_n, in_n)


def letterbox_geometry(in_h: int, in_w: int, out_h: int, out_w: int,
                       ) -> tuple[int, int, int, int]:
    """(content_h, content_w, top, left): aspect-preserving fit + center.

    ``r = min(out_h/in_h, out_w/in_w)`` — the shared scale that makes
    letterboxing aspect-safe; the remainder of the canvas is padding.
    """
    r = min(out_h / in_h, out_w / in_w)
    ch = max(1, min(out_h, round(in_h * r)))
    cw = max(1, min(out_w, round(in_w * r)))
    return ch, cw, (out_h - ch) // 2, (out_w - cw) // 2


@functools.lru_cache(maxsize=64)
def embedded_interp_matrices(in_h: int, in_w: int, out_h: int, out_w: int,
                             ) -> tuple[np.ndarray, np.ndarray]:
    """Letterbox-embedded operators ``Ly (out_h, in_h)``, ``Lx (out_w,
    in_w)``: interpolation rows land on the content window, zero rows
    elsewhere — so ``Ly @ img @ Lx^T`` is the letterboxed resize with
    zeros in the pad region, ready for a mask/affine epilogue.

    Cached per geometry (read-only consumers): a per-frame ingest loop
    must not pay operator construction inside the taxed resize span —
    a real deployment hoists this setup out of the hot path."""
    ch, cw, top, left = letterbox_geometry(in_h, in_w, out_h, out_w)
    ly = np.zeros((out_h, in_h), np.float32)
    ly[top:top + ch] = interp_matrix(ch, in_h)
    lx = np.zeros((out_w, in_w), np.float32)
    lx[left:left + cw] = interp_matrix(cw, in_w)
    return ly, lx


def letterbox_normalize(img: np.ndarray, out_h: int, out_w: int, *,
                        scale: np.ndarray, offset: np.ndarray,
                        pad_value: float = 0.0) -> np.ndarray:
    """(B, H, W, C) any-real -> (B, out_h, out_w, C) float32.

    Aspect-preserving bilinear resize into a centered content window,
    per-channel affine normalization ``x * scale + offset`` on the
    content, ``pad_value`` (already in normalized units) outside it —
    the host baseline of the fused device program.
    """
    B, H, W, C = img.shape
    ly, lx = embedded_interp_matrices(H, W, out_h, out_w)
    x = img.astype(np.float32)
    # (B, C, out_h, out_w) = Ly @ img @ Lx^T per plane
    t = np.einsum("oh,bhwc,pw->bcop", ly, x, lx, optimize=True)
    s = np.asarray(scale, np.float32)[None, :, None, None]
    o = np.asarray(offset, np.float32)[None, :, None, None]
    out = t * s + o
    out = np.where(_content_mask(H, W, out_h, out_w)[None, None], out,
                   np.float32(pad_value))
    return np.moveaxis(out, 1, -1)


@functools.lru_cache(maxsize=64)
def _content_mask(in_h: int, in_w: int, out_h: int, out_w: int,
                  ) -> np.ndarray:
    ch, cw, top, left = letterbox_geometry(in_h, in_w, out_h, out_w)
    mask = np.zeros((out_h, out_w), bool)
    mask[top:top + ch, left:left + cw] = True
    return mask


def iou_matrix(boxes: np.ndarray) -> np.ndarray:
    """(N, 4) float32 [y0, x0, y1, x1] -> (N, N) float32 pairwise IoU.

    Expression order matches :func:`repro.preprocess.device.iou_matrix`
    exactly (float32 IEEE ops), so threshold comparisons agree bitwise.
    """
    b = boxes.astype(np.float32)
    y0, x0, y1, x1 = b[:, 0], b[:, 1], b[:, 2], b[:, 3]
    area = (y1 - y0) * (x1 - x0)
    ih = np.maximum(
        np.float32(0.0),
        np.minimum(y1[:, None], y1[None, :])
        - np.maximum(y0[:, None], y0[None, :]))
    iw = np.maximum(
        np.float32(0.0),
        np.minimum(x1[:, None], x1[None, :])
        - np.maximum(x0[:, None], x0[None, :]))
    inter = ih * iw
    union = area[:, None] + area[None, :] - inter
    return inter / np.maximum(union, np.float32(1e-12))


def nms(boxes: np.ndarray, scores: np.ndarray, *,
        iou_thresh: float = 0.5, score_thresh: float = 0.0,
        max_out: int | None = None) -> list[int]:
    """Greedy IoU NMS -> kept indices (into the input), best-first.

    Ties are broken by index (stable descending sort), matching the
    device path. ``score_thresh`` filters before suppression;
    ``max_out`` caps the number of survivors.
    """
    boxes = np.asarray(boxes, np.float32).reshape(-1, 4)
    scores = np.asarray(scores, np.float32).reshape(-1)
    order = np.argsort(-scores, kind="stable")
    alive = scores[order] >= np.float32(score_thresh)
    iou = iou_matrix(boxes[order])
    thr = np.float32(iou_thresh)
    keep: list[int] = []
    for i in range(len(order)):
        if not alive[i]:
            continue
        keep.append(int(order[i]))
        if max_out is not None and len(keep) >= max_out:
            break
        alive[i + 1:] &= ~(iou[i, i + 1:] > thr)
    return keep


def topk_boxes_from_heatmap(hm: np.ndarray, k: int, *, box_cells: float,
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Dense heatmap -> top-k candidate boxes + scores (cell units).

    Candidates are the k highest cells (stable flat-index tie-break,
    same selection as the device's full stable argsort), each expanded
    to a ``box_cells``-sided box around the cell center. Thresholding
    and suppression are NMS's job, not this function's.
    """
    Hc, Wc = hm.shape
    flat = hm.astype(np.float32).reshape(-1)
    k = min(k, flat.size)
    idx = np.argsort(-flat, kind="stable")[:k]
    cy = (idx // Wc).astype(np.float32) + np.float32(0.5)
    cx = (idx % Wc).astype(np.float32) + np.float32(0.5)
    h = np.float32(box_cells / 2.0)
    boxes = np.stack([cy - h, cx - h, cy + h, cx + h], axis=1)
    return boxes, flat[idx]
