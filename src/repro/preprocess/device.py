"""Device-side pre/post-processing programs (jitted; Pallas-backed).

Each entry point mirrors a host baseline in :mod:`repro.preprocess.host`
and dispatches on the repo's kernel-impl convention
(:mod:`repro.kernels.ops`): ``xla`` lowers anywhere (the default on this
CPU container), ``pallas``/``pallas_interpret`` route the dense parts
through :mod:`repro.kernels.preproc`. The greedy NMS scan is sequential
and tiny, so it stays a ``fori_loop`` inside the jitted program on every
impl — only the O(N^2) IoU matrix changes substrate.

Numerics match the host baselines operation-for-operation in float32:
host and device NMS make bit-identical keep decisions (asserted by
``tests/test_preprocess.py`` and ``benchmarks/fig_preprocess_offload``).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.preprocess import host as _host


def _use_pallas(impl: ops.Impl | None) -> tuple[bool, bool]:
    impl = ops._resolve(impl)
    return impl in ("pallas", "pallas_interpret"), impl == "pallas_interpret"


# --------------------------------------------------------------------------
# Decode-emulation: planar YUV -> RGB
# --------------------------------------------------------------------------

@jax.jit
def _yuv_to_rgb_xla(yuv):
    x = jnp.moveaxis(yuv, -3, -1).astype(jnp.float32)
    x = x - jnp.asarray([0.0, 128.0, 128.0], jnp.float32)
    rgb = x @ jnp.asarray(_host._YUV_TO_RGB.T)
    return jnp.clip(jnp.round(rgb), 0.0, 255.0).astype(jnp.uint8)


def yuv_to_rgb(yuv: jax.Array, *, impl: ops.Impl | None = None) -> jax.Array:
    """(B, 3, H, W) planar uint8 -> (B, H, W, 3) uint8, on device."""
    pallas, interp = _use_pallas(impl)
    if pallas:
        from repro.kernels import preproc
        return preproc.yuv_to_rgb(yuv, interpret=interp)
    return _yuv_to_rgb_xla(yuv)


# --------------------------------------------------------------------------
# Fused letterbox resize + normalization
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _letterbox_operators(in_h: int, in_w: int, out_h: int, out_w: int):
    """Device-resident (ly, lx, pad-mask) per geometry: the operator
    build + host->device upload happens once, not per taxed call."""
    ly, lx = _host.embedded_interp_matrices(in_h, in_w, out_h, out_w)
    mask = _host._content_mask(in_h, in_w, out_h, out_w)
    return jnp.asarray(ly), jnp.asarray(lx), jnp.asarray(mask)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _letterbox_xla(img, ly, lx, sb_scale, sb_offset):
    # one program: resize (two contractions), affine, pad fill — the
    # mask is implicit in the zero rows of the embedded operators, so
    # pad cells come out as 0 * scale + offset_pad handled below
    t = jnp.einsum("oh,bhwc,pw->bcop", ly, img.astype(jnp.float32), lx)
    s = jnp.asarray(sb_scale, jnp.float32)[None, :, None, None]
    o = jnp.asarray(sb_offset, jnp.float32)[None, :, None, None]
    return jnp.moveaxis(t * s + o, 1, -1)


def letterbox_normalize(img: jax.Array, out_h: int, out_w: int, *,
                        scale, offset, pad_value: float = 0.0,
                        impl: ops.Impl | None = None) -> jax.Array:
    """(B, H, W, C) -> (B, out_h, out_w, C) float32, one device program.

    Same semantics as :func:`repro.preprocess.host.letterbox_normalize`:
    aspect-preserving bilinear into a centered window, per-channel
    ``x * scale + offset`` on the content, ``pad_value`` outside.
    """
    B, H, W, C = img.shape
    ly, lx, mask = _letterbox_operators(H, W, out_h, out_w)
    pallas, interp = _use_pallas(impl)
    if pallas:
        from repro.kernels import preproc
        geom = _host.letterbox_geometry(H, W, out_h, out_w)
        planes = img.transpose(0, 3, 1, 2).reshape(B * C, H, W)
        sb = jnp.tile(jnp.stack([jnp.asarray(scale, jnp.float32),
                                 jnp.asarray(offset, jnp.float32)], axis=1),
                      (B, 1))
        out = preproc.letterbox_normalize(
            planes, ly, lx, sb, geom, pad_value=pad_value,
            interpret=interp)
        return out.reshape(B, C, out_h, out_w).transpose(0, 2, 3, 1)
    out = _letterbox_xla(img, ly, lx,
                         tuple(np.asarray(scale, np.float32).tolist()),
                         tuple(np.asarray(offset, np.float32).tolist()))
    return jnp.where(mask[None, :, :, None], out, jnp.float32(pad_value))


# --------------------------------------------------------------------------
# Detection post-processing: threshold + greedy IoU NMS
# --------------------------------------------------------------------------

def _iou_matrix_jnp(boxes):
    y0, x0, y1, x1 = boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3]
    area = (y1 - y0) * (x1 - x0)
    ih = jnp.maximum(0.0, jnp.minimum(y1[:, None], y1[None, :])
                     - jnp.maximum(y0[:, None], y0[None, :]))
    iw = jnp.maximum(0.0, jnp.minimum(x1[:, None], x1[None, :])
                     - jnp.maximum(x0[:, None], x0[None, :]))
    inter = ih * iw
    union = area[:, None] + area[None, :] - inter
    return inter / jnp.maximum(union, 1e-12)


def iou_matrix(boxes: jax.Array, *, impl: ops.Impl | None = None,
               ) -> jax.Array:
    """(N, 4) float32 -> (N, N) pairwise IoU (Pallas on TPU)."""
    pallas, interp = _use_pallas(impl)
    if pallas:
        from repro.kernels import preproc
        return preproc.iou_matrix(boxes.T, interpret=interp)
    return _iou_matrix_jnp(boxes.astype(jnp.float32))


@functools.partial(jax.jit, static_argnums=(2, 3))
def _nms_sorted_jit(iou, alive, iou_thresh, max_out):
    """Greedy scan over the full (padded) candidate length: visiting a
    dead/padded row is a no-op, so the compile is keyed only by the
    pow2 bucket + thresholds — one program per bucket, not per N."""
    N = alive.shape[0]
    idx = jnp.arange(N)
    thr = jnp.float32(iou_thresh)

    def body(i, state):
        alive, keep, count = state
        sel = alive[i] & (count < max_out)
        keep = keep.at[i].set(sel)
        count = count + sel.astype(jnp.int32)
        suppress = sel & (idx > i) & (iou[i] > thr)
        return alive & ~suppress, keep, count

    keep0 = jnp.zeros((N,), bool)
    _, keep, _ = jax.lax.fori_loop(0, N, body, (alive, keep0, jnp.int32(0)))
    return keep


def nms(boxes: np.ndarray, scores: np.ndarray, *, iou_thresh: float = 0.5,
        score_thresh: float = 0.0, max_out: int | None = None,
        impl: ops.Impl | None = None) -> list[int]:
    """Device-side greedy NMS; same contract as ``host.nms``.

    Sorting, thresholding and the suppression scan run in one jitted
    program over the (padded) candidate set; only the kept indices
    come back. Keep decisions are bit-identical to the host baseline.
    """
    boxes = np.asarray(boxes, np.float32).reshape(-1, 4)
    scores = np.asarray(scores, np.float32).reshape(-1)
    N = len(scores)
    if N == 0:
        return []
    # pow2 bucket (like facerec batch padding) so jit retraces stay
    # bounded across battery sizes; pads sort last via -inf scores and
    # are masked out of `alive`, so the scan ignores them
    Np = 1 << (N - 1).bit_length()
    cap = Np if max_out is None else max_out
    boxes_p = np.zeros((Np, 4), np.float32)
    boxes_p[:N] = boxes
    scores_p = np.full((Np,), -np.inf, np.float32)
    scores_p[:N] = scores
    order = jnp.argsort(-jnp.asarray(scores_p), stable=True)
    sboxes = jnp.asarray(boxes_p)[order]
    salive = (jnp.asarray(scores_p)[order] >= jnp.float32(score_thresh)) \
        & (order < N)
    iou = iou_matrix(sboxes, impl=impl)
    keep = _nms_sorted_jit(iou, salive, float(iou_thresh), cap)
    keep = np.asarray(keep)
    order = np.asarray(order)
    return [int(order[i]) for i in range(Np) if keep[i]]


# --------------------------------------------------------------------------
# Batched heatmap post-processing (the pipeline's device path)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def _postprocess_heatmaps_jit(hms, k, box_cells, score_thresh, iou_thresh,
                              max_out):
    """(B, Hc, Wc) heatmaps -> top-k boxes + NMS keep mask, on device.

    Per frame: stable descending argsort of the flattened heatmap picks
    the k candidate cells, boxes of ``box_cells`` side are built around
    their centers, and the greedy scan suppresses on IoU. Everything —
    candidate selection included — runs in the one program; only
    (boxes, scores, keep) cross back.
    """
    B, Hc, Wc = hms.shape
    flat = hms.astype(jnp.float32).reshape(B, -1)
    order = jnp.argsort(-flat, axis=1, stable=True)[:, :k]
    scores = jnp.take_along_axis(flat, order, axis=1)
    cy = (order // Wc).astype(jnp.float32) + 0.5
    cx = (order % Wc).astype(jnp.float32) + 0.5
    h = jnp.float32(box_cells / 2.0)
    boxes = jnp.stack([cy - h, cx - h, cy + h, cx + h], axis=-1)

    def one(bx, sc):
        iou = _iou_matrix_jnp(bx)
        alive = sc >= jnp.float32(score_thresh)
        return _nms_sorted_jit(iou, alive, iou_thresh, max_out)

    keep = jax.vmap(one)(boxes, scores)
    return boxes, scores, keep


def postprocess_heatmaps(hms: np.ndarray, *, k: int, box_cells: float,
                         score_thresh: float, iou_thresh: float,
                         max_out: int,
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched device post-processing; returns (boxes, scores, keep).

    ``hms``: (B, Hc, Wc). Candidates arrive already score-sorted per
    frame (the argsort IS the NMS visit order), so ``keep[b]`` marks
    survivors in best-first order. Shapes are fixed by ``k``; callers
    gather kept rows host-side. B is padded to its pow2 bucket (all-
    zero heatmaps detect nothing) so ragged micro-batch flushes reuse
    compiled programs instead of paying a mid-run jit inside the taxed
    ``post_nms`` span.
    """
    hms = np.asarray(hms)
    B = hms.shape[0]
    pad = (1 << (B - 1).bit_length()) - B
    if pad:
        hms = np.concatenate(
            [hms, np.zeros((pad, *hms.shape[1:]), hms.dtype)], axis=0)
    boxes, scores, keep = _postprocess_heatmaps_jit(
        jnp.asarray(hms), int(k), float(box_cells), float(score_thresh),
        float(iou_thresh), int(max_out))
    return (np.asarray(boxes)[:B], np.asarray(scores)[:B],
            np.asarray(keep)[:B])
