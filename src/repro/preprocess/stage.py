"""PreprocessStage: placement-switchable pre/post-processing with tax
accounting.

One object owns everything that happens around the AI kernels of the
face pipeline — decode-emulation (planar YUV -> RGB), letterbox resize
+ normalization, and detection post-processing (score threshold +
greedy IoU NMS) — behind a single ``placement`` switch:

  * ``placement="host"``   — the NumPy baselines
    (:mod:`repro.preprocess.host`): the paper's measured deployment,
    where this work rides the CPU and becomes the dominant tax once
    the AI is accelerated;
  * ``placement="device"`` — the jitted/Pallas programs
    (:mod:`repro.preprocess.device`): the offload the paper argues
    for, with the host<->device boundary bytes logged as transfer
    events.

Every call logs per-request events into the attached
:class:`repro.core.events.EventLog` under ``pre_*``/``post_*`` stage
names, which the five-way attribution
(:func:`repro.core.events.EventLog.five_way`) buckets into {pre, ai,
post, transfer, queue}. Batched calls amortize the span per item, the
same discipline as the streaming pipeline's AI stages
(docs/ai_tax_accounting.md).

The stage also owns the pipeline's normalization constants
(:class:`NormSpec`): the detector's frame norm and the identify
stage's crop norm. ``repro.core.facerec.Embedder`` and
``FusedIdentifier`` both derive their normalization from the stage's
``crop_norm``, so the host path and the fused device fold can never
apply different constants.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.events import EventLog, categorize
from repro.preprocess import host as _host


@dataclass(frozen=True)
class NormSpec:
    """Per-channel affine normalization ``x_norm = x * scale + offset``.

    Expressed in the familiar (mean, std, to_unit) vocabulary:
    ``to_unit`` first maps 0..255 to 0..1, then ``(x - mean) / std``.
    The default is the identity (uint8 scale preserved) — what the
    detector's brightness threshold expects; the identify stage uses
    ``NormSpec(to_unit=True)``, i.e. the historical ``/255``.
    """
    mean: tuple = (0.0, 0.0, 0.0)
    std: tuple = (1.0, 1.0, 1.0)
    to_unit: bool = False

    @property
    def scale(self) -> np.ndarray:
        base = 255.0 if self.to_unit else 1.0
        return (1.0 / (base * np.asarray(self.std, np.float64))) \
            .astype(np.float32)

    @property
    def offset(self) -> np.ndarray:
        return (-np.asarray(self.mean, np.float64)
                / np.asarray(self.std, np.float64)).astype(np.float32)

    @property
    def is_identity(self) -> bool:
        return (not self.to_unit and all(m == 0.0 for m in self.mean)
                and all(s == 1.0 for s in self.std))


@dataclass(frozen=True)
class DetectPostConfig:
    """Detection post-processing knobs (heatmap-cell units).

    ``box_cells``/``iou_thresh`` are sized so greedy NMS reproduces the
    classic peak-extraction suppression window (a kept peak silences
    candidates within ~±3 cells); ``score_thresh`` is the same
    brightness bar ``facerec.detect_faces`` uses.
    """
    score_thresh: float = 60.0
    iou_thresh: float = 0.12
    box_cells: float = 6.0
    max_candidates: int = 32
    max_faces: int = 5


class PreprocessStage:
    """Placement-switchable decode / letterbox / NMS with event logging.

    ``log`` may be attached after construction (the pipeline builds the
    stage through ``facerec.build_identify_stack`` and then points it
    at its own log); without one, calls still run, just unaccounted.
    """

    def __init__(self, placement: str = "host", *,
                 frame_norm: NormSpec | None = None,
                 crop_norm: NormSpec | None = None,
                 post: DetectPostConfig | None = None,
                 log: EventLog | None = None):
        if placement not in ("host", "device"):
            raise ValueError(f"placement must be host|device, got "
                             f"{placement!r}")
        self.placement = placement
        self.frame_norm = frame_norm or NormSpec()
        self.crop_norm = crop_norm or NormSpec(to_unit=True)
        self.post = post or DetectPostConfig()
        self.log = log

    # ---- accounting helpers ----------------------------------------------

    def _log_span(self, stage: str, rids, t0: float, t1: float,
                  payload_bytes: int) -> None:
        """Amortize one batched span into per-request events
        (EventLog.log_batch_span, tagged with this stage's placement).

        The stage name must resolve to a pre/post bucket through the
        ONE canonical table in ``repro.core.events`` — a renamed stage
        that would silently drift out of the five-way attribution
        raises here instead.
        """
        if categorize(stage, default=None) not in ("pre", "post"):
            raise ValueError(
                f"preprocess stage {stage!r} does not categorize as "
                "pre/post through repro.core.events.STAGE_CATEGORIES")
        if self.log is None:
            return
        self.log.log_batch_span(rids, stage, t0, t1, payload_bytes,
                                split_payload=True,
                                placement=self.placement)

    def _log_transfers(self, rids, boundary: str, h2d: int,
                       d2h: int) -> None:
        if self.log is None or self.placement != "device":
            return
        self.log.log_batch_transfers(rids, boundary, h2d, d2h)

    # ---- pre-processing ---------------------------------------------------

    def decode(self, yuv: np.ndarray, rids=None) -> np.ndarray:
        """(B, 3, H, W) planar uint8 YUV -> (B, H, W, 3) uint8 RGB."""
        rids = list(rids) if rids is not None else list(range(len(yuv)))
        t0 = time.perf_counter()
        if self.placement == "host":
            rgb = _host.yuv_to_rgb(yuv)
        else:
            from repro.preprocess import device
            import jax.numpy as jnp
            rgb = np.asarray(device.yuv_to_rgb(jnp.asarray(yuv)))
        self._log_span("pre_decode", rids, t0, time.perf_counter(),
                       yuv.nbytes)
        self._log_transfers(rids, "pre_decode", yuv.nbytes, rgb.nbytes)
        return rgb

    def letterbox(self, frames: np.ndarray, out_h: int, out_w: int,
                  rids=None, *, pad_value: float = 0.0) -> np.ndarray:
        """(B, H, W, C) -> (B, out_h, out_w, C) float32, frame-normed."""
        rids = list(rids) if rids is not None else list(range(len(frames)))
        n = self.frame_norm
        t0 = time.perf_counter()
        if self.placement == "host":
            out = _host.letterbox_normalize(
                frames, out_h, out_w, scale=n.scale, offset=n.offset,
                pad_value=pad_value)
        else:
            from repro.preprocess import device
            import jax.numpy as jnp
            out = np.asarray(device.letterbox_normalize(
                jnp.asarray(frames), out_h, out_w, scale=n.scale,
                offset=n.offset, pad_value=pad_value))
        self._log_span("pre_letterbox", rids, t0, time.perf_counter(),
                       frames.nbytes)
        self._log_transfers(rids, "pre_letterbox", frames.nbytes, out.nbytes)
        return out

    def ingest(self, yuv: np.ndarray, out_h: int, out_w: int,
               rids=None) -> np.ndarray:
        """Decode + letterbox, the full taxed ingest path."""
        return self.letterbox(self.decode(yuv, rids), out_h, out_w, rids)

    # ---- post-processing --------------------------------------------------

    def postprocess(self, hms: np.ndarray, pool: int, rids=None, *,
                    skip_nms: bool = False) -> list[list[tuple[int, int]]]:
        """(B, Hc, Wc) detection heatmaps -> face centers per frame.

        Threshold + greedy IoU NMS over top-k candidate cells; centers
        come back in full-resolution coordinates (``cell * pool +
        pool//2``), best-first — the same contract as
        ``facerec.detect_faces_batch``. Host and device placements make
        bit-identical keep decisions.

        ``skip_nms=True`` is the graceful-degradation cheap path
        (``DegradeLevel.post_nms`` False): threshold + plain top-k by
        score, no IoU re-rank. It runs on the host regardless of
        placement — the saving IS not launching the suppression
        program — and nearby duplicate detections are the accuracy
        cost the degrade ladder prices.
        """
        rids = list(rids) if rids is not None else list(range(len(hms)))
        p = self.post
        t0 = time.perf_counter()
        centers: list[list[tuple[int, int]]] = []
        if skip_nms:
            for hm in hms:
                boxes, scores = _host.topk_boxes_from_heatmap(
                    hm, p.max_candidates, box_cells=p.box_cells)
                # scores come back best-first: the first max_faces over
                # the bar are the plain top-k keeps
                keep = [i for i in range(len(scores))
                        if scores[i] >= p.score_thresh][:p.max_faces]
                centers.append(self._centers(boxes[keep], pool))
            self._log_span("post_nms", rids, t0, time.perf_counter(),
                           hms.nbytes)
            return centers
        if self.placement == "host":
            for hm in hms:
                boxes, scores = _host.topk_boxes_from_heatmap(
                    hm, p.max_candidates, box_cells=p.box_cells)
                keep = _host.nms(boxes, scores, iou_thresh=p.iou_thresh,
                                 score_thresh=p.score_thresh,
                                 max_out=p.max_faces)
                centers.append(self._centers(boxes[keep], pool))
        else:
            from repro.preprocess import device
            boxes, scores, keep = device.postprocess_heatmaps(
                hms, k=p.max_candidates, box_cells=p.box_cells,
                score_thresh=p.score_thresh, iou_thresh=p.iou_thresh,
                max_out=p.max_faces)
            for b in range(len(hms)):
                centers.append(self._centers(boxes[b][keep[b]], pool))
            out_bytes = boxes.nbytes + scores.nbytes + keep.nbytes
            # padding included — the pow2-padded heatmap rows cross too
            # (same convention as every other batched boundary)
            Bp = 1 << (len(hms) - 1).bit_length()
            self._log_transfers(rids, "post_nms", Bp * hms[0].nbytes,
                                out_bytes)
        self._log_span("post_nms", rids, t0, time.perf_counter(), hms.nbytes)
        return centers

    @staticmethod
    def _centers(kept_boxes: np.ndarray, pool: int,
                 ) -> list[tuple[int, int]]:
        out = []
        for y0, x0, y1, x1 in np.asarray(kept_boxes, np.float32):
            cy = int((y0 + y1) / 2.0 - 0.5)     # back to the cell index
            cx = int((x0 + x1) / 2.0 - 0.5)
            out.append((cy * pool + pool // 2, cx * pool + pool // 2))
        return out
