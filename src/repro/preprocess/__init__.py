"""Pre/post-processing tax subsystem (paper §4.3 / Figs 6 & 8).

The last unreproduced pillar of the paper: once the AI stages are
accelerated, the decode / resize / normalize / NMS / serialization work
*around* them dominates. This package makes that work a first-class,
placement-switchable stage instead of host-side glue:

  * ``host``   — NumPy baselines (the measured CPU deployment);
  * ``device`` — jitted programs + Pallas kernels
    (:mod:`repro.kernels.preproc`) for the same math;
  * ``stage``  — :class:`PreprocessStage`, the ``placement=
    "host"|"device"`` API the streaming pipeline, the fused
    identifier, and the serving cluster all consume via
    ``facerec.build_identify_stack``.

``benchmarks/fig_preprocess_offload.py`` sweeps acceleration ×
placement over this package to reproduce the Fig 6/8 story from
executed runs: the pre/post tax fraction grows under host placement
and collapses when the stage moves on-device.
"""
from repro.preprocess.stage import (
    DetectPostConfig, NormSpec, PreprocessStage,
)

__all__ = ["DetectPostConfig", "NormSpec", "PreprocessStage"]
