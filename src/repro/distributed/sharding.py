"""Logical-axis sharding: rules, contexts, and constraint helpers.

Model code annotates tensors with *logical* axes ("batch", "embed",
"heads", ...). A rule table maps logical axes to mesh axes; the active
(mesh, rules) pair lives in a context so the same model code lowers
unsharded on one CPU device and fully sharded on the production mesh.

Indivisible dims are handled by *dropping* the offending mesh axis (e.g.
8 KV heads can't shard over a 16-way model axis -> replicated), and a mesh
axis is never used twice in one spec (first logical axis wins).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# logical axis -> mesh axis (or tuple of mesh axes)
Rules = dict[str, str | tuple[str, ...] | None]

TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": "data",          # FSDP dimension for 2-D weight sharding
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "lora": "model",
    "inner": "model",         # SSM/RWKV inner feature dim
    "kv_seq": None,
    "seq": None,
    "seq_block": "model",     # sequence-parallel saved layer boundaries
    "attn_q": "model",        # fallback: shard q rows when heads can't
}

SERVE_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "lora": "model",
    "inner": "model",
    "kv_seq": "model",        # sequence-sharded KV caches (distributed LSE)
    "seq": None,
    "seq_block": None,
    "attn_q": "model",
}

_CTX: contextvars.ContextVar[tuple[Mesh, Rules] | None] = \
    contextvars.ContextVar("sharding_ctx", default=None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh | None, rules: Rules | None):
    tok = _CTX.set((mesh, rules) if mesh is not None else None)
    try:
        yield
    finally:
        _CTX.reset(tok)


def active() -> tuple[Mesh, Rules] | None:
    return _CTX.get()


def _mesh_axes_for(logical: str | None, rules: Rules):
    if logical is None:
        return ()
    m = rules.get(logical, None)
    if m is None:
        return ()
    return (m,) if isinstance(m, str) else tuple(m)


def spec_for(axes: Sequence[str | None], shape: Sequence[int] | None,
             mesh: Mesh, rules: Rules) -> PS:
    """Build a PartitionSpec, dropping indivisible / duplicate mesh axes."""
    used: set[str] = set()
    entries = []
    for i, logical in enumerate(axes):
        mesh_axes = []
        for ax in _mesh_axes_for(logical, rules):
            if ax in used or ax not in mesh.shape:
                continue
            size = math.prod([mesh.shape[a] for a in mesh_axes + [ax]])
            if shape is not None and shape[i] % size != 0:
                continue
            mesh_axes.append(ax)
            used.add(ax)
        entries.append(tuple(mesh_axes) if len(mesh_axes) > 1
                       else (mesh_axes[0] if mesh_axes else None))
    while entries and entries[-1] is None:
        entries.pop()
    return PS(*entries)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules: Rules):
    """NamedShardings for a whole param/cache tree.

    ``axes_tree`` holds logical-axes tuples; ``shape_tree`` anything with
    ``.shape`` leaves (ShapeDtypeStructs are fine)."""
    return jax.tree.map(
        lambda axes, s: NamedSharding(mesh, spec_for(axes, s.shape, mesh, rules)),
        axes_tree, shape_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PS())
