"""Distributed-optimization tricks: compressed gradients, distributed LSE.

``compressed_psum``: int8 error-feedback gradient all-reduce. Per-leaf
block scaling (max-abs), quantize to int8, psum the int8 payload (8x less
ICI traffic than f32), dequantize; the quantization residual is carried in
an error-feedback buffer added to the NEXT step's gradient, which keeps
SGD/Adam convergence (Karimireddy et al. semantics).

``distributed_lse_combine``: merges per-shard (max, sumexp, weighted-sum)
attention partials — the manual form of the sequence-sharded decode path,
used by tests to pin down what GSPMD generates for sharded-cache softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err):
    """Returns (quantized tree, scales tree, new error-feedback tree)."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, err)
    qs = jax.tree.map(quantize_int8, corrected)
    q = jax.tree.map(lambda t: t[0], qs,
                     is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs,
                     is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree.map(dequantize_int8, q, s)
    new_err = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return q, s, new_err


def compressed_psum(grads, err, axis_name: str):
    """int8 error-feedback all-reduce (use inside shard_map/pmap)."""
    q, s, new_err = compress_grads(grads, err)
    summed = jax.tree.map(
        lambda qq, ss: jax.lax.psum(qq.astype(jnp.int32), axis_name)
        .astype(jnp.float32) * ss,
        q, s)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(lambda g: g / n, summed)
    return mean, new_err


def distributed_lse_combine(m_parts, l_parts, o_parts):
    """Merge attention partials across shards.

    m/l: (..., shards), o: (..., shards, d). Returns combined output."""
    m = jnp.max(m_parts, axis=-1, keepdims=True)
    w = jnp.exp(m_parts - m)
    l = jnp.sum(l_parts * w, axis=-1)
    o = jnp.sum(o_parts * w[..., None], axis=-2)
    return o / l[..., None]
