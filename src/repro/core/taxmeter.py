"""TaxMeter: AI-tax instrumentation for real JAX serving/training steps.

The paper's tax categories, applied to a TPU-resident step: host
pre-processing, host->device transfer, device compute (the only "AI"
part), device->host transfer, and post-processing. Wraps any step
function; produces the same breakdown structure as the cluster sim so
both substrates are comparable in one table.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.events import EventLog, categorize, five_way_fractions


def taxed_stage_category(stage: str) -> str:
    """TaxedStep stage name -> five-way bucket.

    The step's own stages are suffix-typed (``<name>/pre``,
    ``<name>/h2d``, ``<name>/compute``, ``<name>/d2h``,
    ``<name>/post``); queue waits logged alongside (``wait``/``reject``
    or a ``/wait`` suffix) land in ``queue``. This is the attribution
    the paper-figure benchmarks consume instead of hard-coded stage
    lists (``fig06``/``fig08``). Resolution happens through the ONE
    canonical table + suffix rules in ``repro.core.events``
    (:func:`repro.core.events.categorize`), so this map can never
    drift from ``facerec.stage_category``.
    """
    return categorize(stage)


@dataclass
class TaxedStep:
    log: EventLog
    name: str = "step"

    def run(self, request_id: int, *, pre=None, compute=None, post=None,
            payload=None):
        """Executes pre -> h2d -> compute (block_until_ready) -> post."""
        t = time.perf_counter
        x = payload
        if pre is not None:
            t0 = t()
            x = pre(x)
            self.log.log(request_id, f"{self.name}/pre", t0, t(),
                         _nbytes(x))
        t0 = t()
        x_dev = jax.device_put(x) if x is not None else None
        jax.block_until_ready(x_dev)
        self.log.log_transfer(request_id, "h2d", _nbytes(x), self.name,
                              t0, t(), stage=f"{self.name}/h2d")
        t0 = t()
        y = compute(x_dev) if x_dev is not None else compute()
        jax.block_until_ready(y)
        self.log.log(request_id, f"{self.name}/compute", t0, t())
        t0 = t()
        y_host = jax.device_get(y)
        self.log.log_transfer(request_id, "d2h", _nbytes(y_host), self.name,
                              t0, t(), stage=f"{self.name}/d2h")
        if post is not None:
            t0 = t()
            y_host = post(y_host)
            self.log.log(request_id, f"{self.name}/post", t0, t())
        return y_host

    def breakdown(self) -> dict:
        per = self.log.breakdown()
        fr = five_way_fractions(per, taxed_stage_category)
        compute = sum(v for k, v in per.items() if k.endswith("/compute"))
        transfer = sum(v for k, v in per.items()
                       if k.endswith(("/h2d", "/d2h")))
        total = sum(per.values())
        return {"per_stage": per,
                "ai_fraction": compute / total if total else 0.0,
                "tax_fraction": 1 - (compute / total if total else 0.0),
                "transfer_fraction": transfer / total if total else 0.0,
                "fractions": fr,
                "pre_fraction": fr["pre"],
                "post_fraction": fr["post"],
                "transfer_bytes": self.log.transfer_bytes()}


def _nbytes(x) -> int:
    if x is None:
        return 0
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree.leaves(x)))
