"""Live streaming pipeline: the paper's application shape, actually running.

Stages run in their own threads connected by broker queues (the in-process
analogue of the Kafka topics in Fig 4), with per-request event logging at
every boundary: the same instrumentation produces Fig-6-style breakdowns
for this REAL pipeline as for the simulated cluster.

Supports both deployments of paper Fig 3:
  * two-stage  (fuse_ingest_detect=True, the paper's choice): frames move
    in-process; only face thumbnails cross the broker;
  * three-stage: frames also cross a broker topic.

Stages are micro-batched (the paper's batching lever, §5.5): consumers
drain their topic through a :class:`repro.core.batching.Batcher` bounded
by ``batch_size``/``batch_timeout_ms``, and the AI stages run vectorized
over the whole batch — one heatmap call per frame stack, one embed +
identify matmul per face stack. Per-request accounting survives: queue
waits are logged individually per item, and batched AI spans are
amortized back to per-request events (see docs/ai_tax_accounting.md).
With ``batch_size=1`` the pipeline degenerates to per-item processing
through the very same code path, so batched and unbatched runs are
directly comparable.

The identify hot loop is device-resident by default (``fast_path=True``):
raw uint8 crops go up, (name-index, score) pairs come down, and the
resize/embed/classify chain runs as one jitted program
(:class:`repro.core.facerec.FusedIdentifier`). Every host<->device
boundary logs a ``transfer`` event with its payload bytes, so
``PipelineResult.ai_tax()`` splits AI vs pre/post-processing vs data
movement and ``benchmarks/fig_fused_path.py`` can show the transfer
bytes the fused path eliminates. ``fast_path=False`` keeps the unfused
crop -> device resize -> thumbnail -> device embed -> host classify
chain for comparison.

Pre/post-processing is a first-class stage
(:class:`repro.preprocess.PreprocessStage`, built by the shared
``facerec.build_identify_stack`` factory): frames arrive as planar YUV
(the camera wire format), are decoded and letterbox-resized by the
stage (``pre_decode``/``pre_letterbox`` events), the detection heatmap
is thresholded + NMS-suppressed by it (``post_nms``), and crop
extraction is logged as ``pre_crop`` — so
``PipelineResult.ai_tax()["fractions"]`` attributes every microsecond
to {pre, ai, post, transfer, queue}. ``placement="device"`` moves the
decode/letterbox/NMS math into jitted (Pallas-backed) device programs
and logs the extra boundary bytes; ``placement="host"`` is the paper's
measured CPU deployment.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import facerec
from repro.core.batching import Batcher, BatchStats
from repro.core.events import EventLog, Timer
from repro.data.video import VideoStream


_STOP = object()


@dataclass
class PipelineResult:
    log: EventLog
    identities: list
    detected: int
    ground_truth: int
    matched: int
    batch_stats: dict = field(default_factory=dict)   # stage -> BatchStats

    @property
    def recall(self) -> float:
        return self.matched / self.ground_truth if self.ground_truth else 1.0

    def ai_tax(self) -> dict:
        return self.log.ai_tax(ai_stages={"detect", "identify"},
                               category_of=facerec.stage_category)


class StreamingPipeline:
    def __init__(self, *, n_frames: int = 60, fuse_ingest_detect: bool = True,
                 n_identify_workers: int = 2, seed: int = 0,
                 gallery_size: int = 8, batch_size: int = 1,
                 batch_timeout_ms: float = 5.0, fast_path: bool = True,
                 placement: str = "host"):
        self.n_frames = n_frames
        self.fused = fuse_ingest_detect
        self.n_workers = n_identify_workers
        self.batch_size = max(1, batch_size)
        self.batch_timeout_s = batch_timeout_ms / 1e3
        self.fast_path = fast_path
        self.video = VideoStream(seed=seed)
        self.log = EventLog()
        # the identify stage's model stack comes from the shared factory
        # (cluster replicas build theirs from the same one): embedder,
        # gallery classifier, the placement-switchable preprocess stage
        # (decode/letterbox/NMS, accounting into this pipeline's log),
        # and — with fast_path — the device-resident FusedIdentifier
        # whose resize operator + crop norm are pre-composed with the
        # embedder's first layer; fast_path=False keeps the unfused
        # crop->resize->embed->host-classify chain for comparison
        stack = facerec.build_identify_stack(
            seed=seed, gallery_size=gallery_size, fast_path=fast_path,
            placement=placement, log=self.log)
        self.embedder = stack.embedder
        self.classifier = stack.classifier
        self.fused_identifier = stack.fused
        self.preprocess = stack.preprocess
        # broker topics (queues); maxsize models bounded broker capacity
        self.faces_topic: queue.Queue = queue.Queue(maxsize=4096)
        self.frames_topic: queue.Queue = queue.Queue(maxsize=1024)
        self.identities: list = []
        self._ident_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.batch_stats: dict[str, BatchStats] = {}
        self.detected = 0
        self.ground_truth = 0
        self.matched = 0

    def _merge_stats(self, stage: str, stats: BatchStats) -> None:
        with self._stats_lock:
            base = self.batch_stats.get(stage, BatchStats())
            self.batch_stats[stage] = base.merge(stats)

    def _log_batch_transfers(self, items, boundary: str, h2d: int,
                             d2h: int) -> None:
        """Per-item transfer events for one batched boundary crossing
        (items are (rid, ...) tuples; see EventLog.log_batch_transfers)."""
        self.log.log_batch_transfers([it[0] for it in items], boundary,
                                     h2d, d2h)

    # ---- stages ------------------------------------------------------------

    def _ingest_frames(self):
        """Decode + letterbox resize (pre-processing only — no AI).

        The synthetic camera ships planar YUV (``rgb_to_yuv`` stands
        for the codec and is deliberately outside every taxed span);
        the taxed ingest is the preprocess stage's decode + letterbox,
        logged as ``pre_decode``/``pre_letterbox``, with the residual
        dtype cast under the ``ingest`` stage name.
        """
        from repro.preprocess import host as pre_host
        # fused mode: push-fed batcher — in-process micro-batching at the
        # ingest->detect boundary with the same flush policy as the
        # broker-fed stages
        batcher = (Batcher(batch_size=self.batch_size,
                           timeout_s=self.batch_timeout_s)
                   if self.fused else None)
        for i in range(self.n_frames):
            frame = self.video.next_frame()
            H, W = frame.pixels.shape[:2]
            yuv = pre_host.rgb_to_yuv(frame.pixels)[None]    # wire format
            small_f = self.preprocess.ingest(yuv, H // 2, W // 2,
                                             rids=[frame.index])[0]
            with Timer(self.log, frame.index, "ingest",
                       payload_bytes=frame.pixels.nbytes):
                # emit uint8 once: 4x smaller broker payloads, and every
                # downstream consumer (detect cast, crop) sees one dtype
                small = np.clip(small_f, 0, 255).astype(np.uint8)
            item = (frame.index, small, frame.true_boxes, time.perf_counter())
            if self.fused:
                if (batch := batcher.push(item)) is not None:
                    self._log_frame_waits(batch)
                    self._detect_batch(batch)
            else:
                self.frames_topic.put(item)
        if self.fused:
            if (tail := batcher.flush()) is not None:
                self._log_frame_waits(tail)
                self._detect_batch(tail)
            self._merge_stats("detect", batcher.stats)
        else:
            self.frames_topic.put(_STOP)

    def _log_frame_waits(self, batch):
        """Per-item wait_frames events: batching linger (fused) or broker
        transit + linger (three-stage) — the tax stays per-request."""
        t = time.perf_counter()
        for rid, small, _boxes, t_q in batch:
            self.log.log(rid, "wait_frames", t_q, t,
                         payload_bytes=small.nbytes)

    def _detect_loop(self):
        batcher = Batcher(self.frames_topic, batch_size=self.batch_size,
                          timeout_s=self.batch_timeout_s, stop=_STOP)
        for batch in batcher:
            self._log_frame_waits(batch)
            self._detect_batch(batch)
        self._merge_stats("detect", batcher.stats)

    def _detect_batch(self, items):
        """Detect + NMS + crop over a stacked frame batch.

        The three phases log under their own tax buckets: the dense
        heatmap is the AI (``detect``), the threshold + IoU NMS is the
        preprocess stage's ``post_nms`` (host or device per its
        placement), and crop extraction — input preparation for the
        identify stage — is ``pre_crop``. fast_path: the per-face
        payload pushed to the faces topic is the raw uint8 crop (pure
        numpy slicing — the resize moved on-device into the fused
        identify program). Unfused: crops round-trip through the
        device resize here and float32 thumbnails cross the broker,
        exactly the transfer tax the fused path eliminates.
        """
        import jax.numpy as jnp
        B = len(items)
        rids = [it[0] for it in items]
        frames = [it[1] for it in items]
        smalls = np.stack(frames)
        t0 = time.perf_counter()
        hms = np.asarray(facerec.detect_heatmap_batch(
            jnp.asarray(facerec._pad_rows_pow2(smalls))))[:B]
        t1 = time.perf_counter()
        # amortize the batched span back to per-request detect events
        self.log.log_batch_span(rids, "detect", t0, t1,
                                payload_bytes=smalls[0].nbytes)
        # post-processing: threshold + greedy IoU NMS (logs "post_nms")
        centers_per = self.preprocess.postprocess(
            hms, facerec.DETECT_POOL, rids=rids)
        t2 = time.perf_counter()
        if self.fast_path:
            crops, counts = facerec.crop_stacks(frames, centers_per)
            faces_per = (facerec._regroup(crops, counts) if crops is not None
                         else [[] for _ in items])
        else:
            faces_per = facerec.crop_thumbnails_batch(frames, centers_per)
        t3 = time.perf_counter()
        crop_bytes = sum(f.nbytes for faces in faces_per for f in faces)
        self.log.log_batch_span(rids, "pre_crop", t2, t3,
                                payload_bytes=crop_bytes, split_payload=True)
        # boundary bytes: padded frame stack up, heatmaps down (both
        # paths); the unfused path pays the crop->thumbnail resize
        # round trip on top
        Bp = facerec._pad_pow2(B)
        H, W = smalls.shape[1:3]
        pool = facerec.DETECT_POOL
        self._log_batch_transfers(
            items, "detect",
            h2d=Bp * H * W * 3 * smalls.itemsize,
            d2h=Bp * (H // pool) * (W // pool) * 4)
        n_faces = sum(len(c) for c in centers_per)
        if not self.fast_path and n_faces:
            Np = facerec._pad_pow2(n_faces)
            crop_px = facerec.CROP_SIZE * facerec.CROP_SIZE * 3
            thumb_px = facerec.THUMB * facerec.THUMB * 3
            self._log_batch_transfers(items, "crop_resize",
                                      h2d=Np * crop_px * 4,
                                      d2h=Np * thumb_px * 4)
        for (rid, _small, true_boxes, _), centers, faces in zip(
                items, centers_per, faces_per):
            # under _stats_lock: accuracy counters are shared with the
            # reporting path (stats()), which already reads them locked
            with self._stats_lock:
                self.ground_truth += len(true_boxes)
                self.detected += len(centers)
                # match detections to ground truth (within 1.5x blob
                # size)
                for (ty, tx, ts) in true_boxes:
                    if any(abs(cy - ty / 2) < 1.5 * ts
                           and abs(cx - tx / 2) < 1.5 * ts
                           for cy, cx in centers):
                        self.matched += 1
            for face in faces:
                self.faces_topic.put((rid, face, time.perf_counter()))

    def _identify_loop(self):
        batcher = Batcher(self.faces_topic, batch_size=self.batch_size,
                          timeout_s=self.batch_timeout_s, stop=_STOP)
        for batch in batcher:
            t_deq = time.perf_counter()
            for rid, face, t_q in batch:
                self.log.log(rid, "wait", t_q, t_deq,
                             payload_bytes=face.nbytes)
            B = len(batch)
            stack = np.stack([face for _, face, _ in batch])
            t0 = time.perf_counter()
            if self.fused_identifier is not None:
                # one device program: uint8 crops up, (name-idx, score)
                # down — embed + gallery similarity never leave HBM
                named = self.fused_identifier.identify_crops(stack)
            else:
                embs = self.embedder.embed_batch(stack)
                named = self.classifier.identify_batch(embs)
            t1 = time.perf_counter()
            Bp = facerec._pad_pow2(B)
            if self.fused_identifier is not None:
                # downlink: one int32 name-index + one f32 score per row
                self._log_batch_transfers(batch, "identify_fused",
                                          h2d=Bp * stack[0].nbytes,
                                          d2h=Bp * (np.int32().nbytes
                                                    + np.float32().nbytes))
            else:
                self._log_batch_transfers(batch, "embed",
                                          h2d=Bp * stack[0].nbytes,
                                          d2h=Bp * facerec.EMBED_DIM * 4)
            self.log.log_batch_span([rid for rid, _, _ in batch],
                                    "identify", t0, t1,
                                    payload_bytes=stack[0].nbytes)
            results = [(rid, name, sim) for (rid, _, _), (name, sim)
                       in zip(batch, named)]
            with self._ident_lock:
                self.identities.extend(results)
        self._merge_stats("identify", batcher.stats)

    # ---- run ---------------------------------------------------------------

    def run(self) -> PipelineResult:
        workers = [threading.Thread(target=self._identify_loop)
                   for _ in range(self.n_workers)]
        for w in workers:
            w.start()
        det = None
        if not self.fused:
            det = threading.Thread(target=self._detect_loop)
            det.start()
        self._ingest_frames()
        if det is not None:
            det.join()
        for _ in workers:
            self.faces_topic.put(_STOP)
        for w in workers:
            w.join()
        return PipelineResult(self.log, self.identities, self.detected,
                              self.ground_truth, self.matched,
                              dict(self.batch_stats))
