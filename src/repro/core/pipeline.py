"""Live streaming pipeline: the paper's application shape, actually running.

Stages run in their own threads connected by broker queues (the in-process
analogue of the Kafka topics in Fig 4), with per-request event logging at
every boundary: the same instrumentation produces Fig-6-style breakdowns
for this REAL pipeline as for the simulated cluster.

Supports both deployments of paper Fig 3:
  * two-stage  (fuse_ingest_detect=True, the paper's choice): frames move
    in-process; only face thumbnails cross the broker;
  * three-stage: frames also cross a broker topic.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import facerec
from repro.core.events import EventLog, Timer
from repro.data.video import VideoStream


_STOP = object()


@dataclass
class PipelineResult:
    log: EventLog
    identities: list
    detected: int
    ground_truth: int
    matched: int

    @property
    def recall(self) -> float:
        return self.matched / self.ground_truth if self.ground_truth else 1.0

    def ai_tax(self) -> dict:
        return self.log.ai_tax(ai_stages={"detect", "identify"})


class StreamingPipeline:
    def __init__(self, *, n_frames: int = 60, fuse_ingest_detect: bool = True,
                 n_identify_workers: int = 2, seed: int = 0,
                 gallery_size: int = 8):
        self.n_frames = n_frames
        self.fused = fuse_ingest_detect
        self.n_workers = n_identify_workers
        self.video = VideoStream(seed=seed)
        self.log = EventLog()
        self.embedder = facerec.Embedder()
        rng = np.random.default_rng(seed)
        gallery = {}
        for i in range(gallery_size):
            thumb = rng.uniform(0, 255, (facerec.THUMB, facerec.THUMB, 3))
            gallery[f"person_{i}"] = self.embedder(thumb.astype(np.float32))
        self.classifier = facerec.Classifier(gallery)
        # broker topics (queues); maxsize models bounded broker capacity
        self.faces_topic: queue.Queue = queue.Queue(maxsize=4096)
        self.frames_topic: queue.Queue = queue.Queue(maxsize=1024)
        self.identities: list = []
        self._ident_lock = threading.Lock()
        self.detected = 0
        self.ground_truth = 0
        self.matched = 0

    # ---- stages ------------------------------------------------------------

    def _ingest_frames(self):
        """Parse + resize (pre-processing only — no AI)."""
        from repro.kernels import ops
        import jax.numpy as jnp
        for i in range(self.n_frames):
            frame = self.video.next_frame()
            with Timer(self.log, frame.index, "ingest",
                       payload_bytes=frame.pixels.nbytes):
                small = np.asarray(ops.resize_bilinear(
                    jnp.asarray(frame.pixels, jnp.float32),
                    frame.pixels.shape[0] // 2, frame.pixels.shape[1] // 2))
            item = (frame.index, small, frame.true_boxes, time.perf_counter())
            if self.fused:
                self._detect_one(item)
            else:
                self.frames_topic.put(item)
        if not self.fused:
            self.frames_topic.put(_STOP)

    def _detect_loop(self):
        while True:
            item = self.frames_topic.get()
            if item is _STOP:
                break
            rid, small, boxes, t_q = item
            self.log.log(rid, "wait_frames", t_q, time.perf_counter(),
                         payload_bytes=small.nbytes)
            self._detect_one((rid, small, boxes, t_q))

    def _detect_one(self, item):
        rid, small, true_boxes, _ = item
        with Timer(self.log, rid, "detect", payload_bytes=small.nbytes):
            centers = facerec.detect_faces(small.astype(np.uint8))
            thumbs = [facerec.crop_thumbnail(small, y, x) for y, x in centers]
        self.ground_truth += len(true_boxes)
        self.detected += len(centers)
        # match detections to ground truth (within 1.5x blob size)
        for (ty, tx, ts) in true_boxes:
            if any(abs(cy - ty / 2) < 1.5 * ts and abs(cx - tx / 2) < 1.5 * ts
                   for cy, cx in centers):
                self.matched += 1
        for thumb in thumbs:
            self.faces_topic.put((rid, thumb, time.perf_counter()))

    def _identify_loop(self):
        while True:
            item = self.faces_topic.get()
            if item is _STOP:
                break
            rid, thumb, t_q = item
            self.log.log(rid, "wait", t_q, time.perf_counter(),
                         payload_bytes=thumb.nbytes)
            with Timer(self.log, rid, "identify", payload_bytes=thumb.nbytes):
                emb = self.embedder(thumb)
                name, sim = self.classifier.identify(emb)
            with self._ident_lock:
                self.identities.append((rid, name, sim))

    # ---- run ---------------------------------------------------------------

    def run(self) -> PipelineResult:
        workers = [threading.Thread(target=self._identify_loop)
                   for _ in range(self.n_workers)]
        for w in workers:
            w.start()
        det = None
        if not self.fused:
            det = threading.Thread(target=self._detect_loop)
            det.start()
        self._ingest_frames()
        if det is not None:
            det.join()
        for _ in workers:
            self.faces_topic.put(_STOP)
        for w in workers:
            w.join()
        return PipelineResult(self.log, self.identities, self.detected,
                              self.ground_truth, self.matched)
