"""Live streaming pipeline: the paper's application shape, actually running.

Stages run in their own threads connected by broker queues (the in-process
analogue of the Kafka topics in Fig 4), with per-request event logging at
every boundary: the same instrumentation produces Fig-6-style breakdowns
for this REAL pipeline as for the simulated cluster.

Supports both deployments of paper Fig 3:
  * two-stage  (fuse_ingest_detect=True, the paper's choice): frames move
    in-process; only face thumbnails cross the broker;
  * three-stage: frames also cross a broker topic.

Stages are micro-batched (the paper's batching lever, §5.5): consumers
drain their topic through a :class:`repro.core.batching.Batcher` bounded
by ``batch_size``/``batch_timeout_ms``, and the AI stages run vectorized
over the whole batch — one heatmap call per frame stack, one embed +
identify matmul per face stack. Per-request accounting survives: queue
waits are logged individually per item, and batched AI spans are
amortized back to per-request events (see docs/ai_tax_accounting.md).
With ``batch_size=1`` the pipeline degenerates to per-item processing
through the very same code path, so batched and unbatched runs are
directly comparable.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import facerec
from repro.core.batching import Batcher, BatchStats
from repro.core.events import EventLog, Timer
from repro.data.video import VideoStream


_STOP = object()


@dataclass
class PipelineResult:
    log: EventLog
    identities: list
    detected: int
    ground_truth: int
    matched: int
    batch_stats: dict = field(default_factory=dict)   # stage -> BatchStats

    @property
    def recall(self) -> float:
        return self.matched / self.ground_truth if self.ground_truth else 1.0

    def ai_tax(self) -> dict:
        return self.log.ai_tax(ai_stages={"detect", "identify"})


class StreamingPipeline:
    def __init__(self, *, n_frames: int = 60, fuse_ingest_detect: bool = True,
                 n_identify_workers: int = 2, seed: int = 0,
                 gallery_size: int = 8, batch_size: int = 1,
                 batch_timeout_ms: float = 5.0):
        self.n_frames = n_frames
        self.fused = fuse_ingest_detect
        self.n_workers = n_identify_workers
        self.batch_size = max(1, batch_size)
        self.batch_timeout_s = batch_timeout_ms / 1e3
        self.video = VideoStream(seed=seed)
        self.log = EventLog()
        self.embedder = facerec.Embedder()
        rng = np.random.default_rng(seed)
        thumbs = rng.uniform(
            0, 255, (gallery_size, facerec.THUMB, facerec.THUMB, 3))
        gallery_embs = self.embedder.embed_batch(thumbs.astype(np.float32))
        self.classifier = facerec.Classifier(
            {f"person_{i}": gallery_embs[i] for i in range(gallery_size)})
        # broker topics (queues); maxsize models bounded broker capacity
        self.faces_topic: queue.Queue = queue.Queue(maxsize=4096)
        self.frames_topic: queue.Queue = queue.Queue(maxsize=1024)
        self.identities: list = []
        self._ident_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.batch_stats: dict[str, BatchStats] = {}
        self.detected = 0
        self.ground_truth = 0
        self.matched = 0

    def _merge_stats(self, stage: str, stats: BatchStats) -> None:
        with self._stats_lock:
            base = self.batch_stats.get(stage, BatchStats())
            self.batch_stats[stage] = base.merge(stats)

    # ---- stages ------------------------------------------------------------

    def _ingest_frames(self):
        """Parse + resize (pre-processing only — no AI)."""
        from repro.kernels import ops
        import jax.numpy as jnp
        # fused mode: push-fed batcher — in-process micro-batching at the
        # ingest->detect boundary with the same flush policy as the
        # broker-fed stages
        batcher = (Batcher(batch_size=self.batch_size,
                           timeout_s=self.batch_timeout_s)
                   if self.fused else None)
        for i in range(self.n_frames):
            frame = self.video.next_frame()
            with Timer(self.log, frame.index, "ingest",
                       payload_bytes=frame.pixels.nbytes):
                small = np.asarray(ops.resize_bilinear(
                    jnp.asarray(frame.pixels, jnp.float32),
                    frame.pixels.shape[0] // 2, frame.pixels.shape[1] // 2))
            item = (frame.index, small, frame.true_boxes, time.perf_counter())
            if self.fused:
                if (batch := batcher.push(item)) is not None:
                    self._log_frame_waits(batch)
                    self._detect_batch(batch)
            else:
                self.frames_topic.put(item)
        if self.fused:
            if (tail := batcher.flush()) is not None:
                self._log_frame_waits(tail)
                self._detect_batch(tail)
            self._merge_stats("detect", batcher.stats)
        else:
            self.frames_topic.put(_STOP)

    def _log_frame_waits(self, batch):
        """Per-item wait_frames events: batching linger (fused) or broker
        transit + linger (three-stage) — the tax stays per-request."""
        t = time.perf_counter()
        for rid, small, _boxes, t_q in batch:
            self.log.log(rid, "wait_frames", t_q, t,
                         payload_bytes=small.nbytes)

    def _detect_loop(self):
        batcher = Batcher(self.frames_topic, batch_size=self.batch_size,
                          timeout_s=self.batch_timeout_s, stop=_STOP)
        for batch in batcher:
            self._log_frame_waits(batch)
            self._detect_batch(batch)
        self._merge_stats("detect", batcher.stats)

    def _detect_batch(self, items):
        """Detect + crop over a stacked frame batch; per-request events."""
        B = len(items)
        smalls = np.stack([it[1] for it in items]).astype(np.uint8)
        t0 = time.perf_counter()
        centers_per = facerec.detect_faces_batch(smalls)
        thumbs_per = facerec.crop_thumbnails_batch(
            [it[1] for it in items], centers_per)
        t1 = time.perf_counter()
        # amortize the batched span back to per-request detect events
        dt = (t1 - t0) / B
        for i, (rid, small, _, _) in enumerate(items):
            self.log.log(rid, "detect", t0 + i * dt, t0 + (i + 1) * dt,
                         payload_bytes=small.nbytes, batch_size=B)
        for (rid, _small, true_boxes, _), centers, thumbs in zip(
                items, centers_per, thumbs_per):
            self.ground_truth += len(true_boxes)
            self.detected += len(centers)
            # match detections to ground truth (within 1.5x blob size)
            for (ty, tx, ts) in true_boxes:
                if any(abs(cy - ty / 2) < 1.5 * ts
                       and abs(cx - tx / 2) < 1.5 * ts
                       for cy, cx in centers):
                    self.matched += 1
            for thumb in thumbs:
                self.faces_topic.put((rid, thumb, time.perf_counter()))

    def _identify_loop(self):
        batcher = Batcher(self.faces_topic, batch_size=self.batch_size,
                          timeout_s=self.batch_timeout_s, stop=_STOP)
        for batch in batcher:
            t_deq = time.perf_counter()
            for rid, thumb, t_q in batch:
                self.log.log(rid, "wait", t_q, t_deq,
                             payload_bytes=thumb.nbytes)
            B = len(batch)
            stack = np.stack([thumb for _, thumb, _ in batch])
            t0 = time.perf_counter()
            embs = self.embedder.embed_batch(stack)
            named = self.classifier.identify_batch(embs)
            t1 = time.perf_counter()
            dt = (t1 - t0) / B
            results = []
            for i, ((rid, thumb, _), (name, sim)) in enumerate(
                    zip(batch, named)):
                self.log.log(rid, "identify", t0 + i * dt, t0 + (i + 1) * dt,
                             payload_bytes=thumb.nbytes, batch_size=B)
                results.append((rid, name, sim))
            with self._ident_lock:
                self.identities.extend(results)
        self._merge_stats("identify", batcher.stats)

    # ---- run ---------------------------------------------------------------

    def run(self) -> PipelineResult:
        workers = [threading.Thread(target=self._identify_loop)
                   for _ in range(self.n_workers)]
        for w in workers:
            w.start()
        det = None
        if not self.fused:
            det = threading.Thread(target=self._detect_loop)
            det.start()
        self._ingest_frames()
        if det is not None:
            det.join()
        for _ in workers:
            self.faces_topic.put(_STOP)
        for w in workers:
            w.join()
        return PipelineResult(self.log, self.identities, self.detected,
                              self.ground_truth, self.matched,
                              dict(self.batch_stats))
