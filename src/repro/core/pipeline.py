"""Live streaming pipeline: the paper's application shape, actually running.

Stages run in their own threads connected by broker queues (the in-process
analogue of the Kafka topics in Fig 4), with per-request event logging at
every boundary: the same instrumentation produces Fig-6-style breakdowns
for this REAL pipeline as for the simulated cluster.

Supports both deployments of paper Fig 3:
  * two-stage  (fuse_ingest_detect=True, the paper's choice): frames move
    in-process; only face thumbnails cross the broker;
  * three-stage: frames also cross a broker topic.

Stages are micro-batched (the paper's batching lever, §5.5): consumers
drain their topic through a :class:`repro.core.batching.Batcher` bounded
by ``batch_size``/``batch_timeout_ms``, and the AI stages run vectorized
over the whole batch — one heatmap call per frame stack, one embed +
identify matmul per face stack. Per-request accounting survives: queue
waits are logged individually per item, and batched AI spans are
amortized back to per-request events (see docs/ai_tax_accounting.md).
With ``batch_size=1`` the pipeline degenerates to per-item processing
through the very same code path, so batched and unbatched runs are
directly comparable.

The identify hot loop is device-resident by default (``fast_path=True``):
raw uint8 crops go up, (name-index, score) pairs come down, and the
resize/embed/classify chain runs as one jitted program
(:class:`repro.core.facerec.FusedIdentifier`). Every host<->device
boundary logs a ``transfer`` event with its payload bytes, so
``PipelineResult.ai_tax()`` splits AI vs pre/post-processing vs data
movement and ``benchmarks/fig_fused_path.py`` can show the transfer
bytes the fused path eliminates. ``fast_path=False`` keeps the unfused
crop -> device resize -> thumbnail -> device embed -> host classify
chain for comparison.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import facerec
from repro.core.batching import Batcher, BatchStats
from repro.core.events import EventLog, Timer
from repro.data.video import VideoStream


_STOP = object()


@dataclass
class PipelineResult:
    log: EventLog
    identities: list
    detected: int
    ground_truth: int
    matched: int
    batch_stats: dict = field(default_factory=dict)   # stage -> BatchStats

    @property
    def recall(self) -> float:
        return self.matched / self.ground_truth if self.ground_truth else 1.0

    def ai_tax(self) -> dict:
        return self.log.ai_tax(ai_stages={"detect", "identify"})


class StreamingPipeline:
    def __init__(self, *, n_frames: int = 60, fuse_ingest_detect: bool = True,
                 n_identify_workers: int = 2, seed: int = 0,
                 gallery_size: int = 8, batch_size: int = 1,
                 batch_timeout_ms: float = 5.0, fast_path: bool = True):
        self.n_frames = n_frames
        self.fused = fuse_ingest_detect
        self.n_workers = n_identify_workers
        self.batch_size = max(1, batch_size)
        self.batch_timeout_s = batch_timeout_ms / 1e3
        self.fast_path = fast_path
        self.video = VideoStream(seed=seed)
        self.log = EventLog()
        # the identify stage's model stack comes from the shared factory
        # (cluster replicas build theirs from the same one): embedder,
        # gallery classifier, and — with fast_path — the device-resident
        # FusedIdentifier whose resize operator is pre-composed with the
        # embedder's first layer; fast_path=False keeps the unfused
        # crop->resize->embed->host-classify chain for comparison
        self.embedder, self.classifier, self.fused_identifier = \
            facerec.build_identify_stack(seed=seed, gallery_size=gallery_size,
                                         fast_path=fast_path)
        # broker topics (queues); maxsize models bounded broker capacity
        self.faces_topic: queue.Queue = queue.Queue(maxsize=4096)
        self.frames_topic: queue.Queue = queue.Queue(maxsize=1024)
        self.identities: list = []
        self._ident_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.batch_stats: dict[str, BatchStats] = {}
        self.detected = 0
        self.ground_truth = 0
        self.matched = 0

    def _merge_stats(self, stage: str, stats: BatchStats) -> None:
        with self._stats_lock:
            base = self.batch_stats.get(stage, BatchStats())
            self.batch_stats[stage] = base.merge(stats)

    def _log_batch_transfers(self, items, boundary: str, h2d: int,
                             d2h: int) -> None:
        """Per-item transfer events for one batched boundary crossing.

        The batch's boundary bytes (padding included — padded rows
        cross too) are split across its items, remainder on the first,
        so per-request accounting and batch totals both stay exact.
        """
        t = time.perf_counter()
        B = len(items)
        for j, item in enumerate(items):
            rid = item[0]
            extra_up, extra_dn = (h2d % B, d2h % B) if j == 0 else (0, 0)
            self.log.log_transfer(rid, "h2d", h2d // B + extra_up,
                                  boundary, t)
            self.log.log_transfer(rid, "d2h", d2h // B + extra_dn,
                                  boundary, t)

    # ---- stages ------------------------------------------------------------

    def _ingest_frames(self):
        """Parse + resize (pre-processing only — no AI)."""
        from repro.kernels import ops
        import jax.numpy as jnp
        # fused mode: push-fed batcher — in-process micro-batching at the
        # ingest->detect boundary with the same flush policy as the
        # broker-fed stages
        batcher = (Batcher(batch_size=self.batch_size,
                           timeout_s=self.batch_timeout_s)
                   if self.fused else None)
        for i in range(self.n_frames):
            frame = self.video.next_frame()
            with Timer(self.log, frame.index, "ingest",
                       payload_bytes=frame.pixels.nbytes):
                small = np.asarray(ops.resize_bilinear(
                    jnp.asarray(frame.pixels, jnp.float32),
                    frame.pixels.shape[0] // 2, frame.pixels.shape[1] // 2))
                # emit uint8 once: 4x smaller broker payloads, and every
                # downstream consumer (detect cast, crop) sees one dtype
                small = np.clip(small, 0, 255).astype(np.uint8)
            self.log.log_transfer(frame.index, "h2d",
                                  frame.pixels.size * 4, "ingest_resize")
            self.log.log_transfer(frame.index, "d2h",
                                  small.size * 4, "ingest_resize")
            item = (frame.index, small, frame.true_boxes, time.perf_counter())
            if self.fused:
                if (batch := batcher.push(item)) is not None:
                    self._log_frame_waits(batch)
                    self._detect_batch(batch)
            else:
                self.frames_topic.put(item)
        if self.fused:
            if (tail := batcher.flush()) is not None:
                self._log_frame_waits(tail)
                self._detect_batch(tail)
            self._merge_stats("detect", batcher.stats)
        else:
            self.frames_topic.put(_STOP)

    def _log_frame_waits(self, batch):
        """Per-item wait_frames events: batching linger (fused) or broker
        transit + linger (three-stage) — the tax stays per-request."""
        t = time.perf_counter()
        for rid, small, _boxes, t_q in batch:
            self.log.log(rid, "wait_frames", t_q, t,
                         payload_bytes=small.nbytes)

    def _detect_loop(self):
        batcher = Batcher(self.frames_topic, batch_size=self.batch_size,
                          timeout_s=self.batch_timeout_s, stop=_STOP)
        for batch in batcher:
            self._log_frame_waits(batch)
            self._detect_batch(batch)
        self._merge_stats("detect", batcher.stats)

    def _detect_batch(self, items):
        """Detect + crop over a stacked frame batch; per-request events.

        fast_path: the per-face payload pushed to the faces topic is the
        raw uint8 crop (pure numpy slicing — the resize moved on-device
        into the fused identify program). Unfused: crops round-trip
        through the device resize here and float32 thumbnails cross the
        broker, exactly the transfer tax the fused path eliminates.
        """
        B = len(items)
        frames = [it[1] for it in items]
        smalls = np.stack(frames)
        t0 = time.perf_counter()
        centers_per = facerec.detect_faces_batch(smalls)
        if self.fast_path:
            crops, counts = facerec.crop_stacks(frames, centers_per)
            faces_per = (facerec._regroup(crops, counts) if crops is not None
                         else [[] for _ in items])
        else:
            faces_per = facerec.crop_thumbnails_batch(frames, centers_per)
        t1 = time.perf_counter()
        # amortize the batched span back to per-request detect events
        dt = (t1 - t0) / B
        for i, (rid, small, _, _) in enumerate(items):
            self.log.log(rid, "detect", t0 + i * dt, t0 + (i + 1) * dt,
                         payload_bytes=small.nbytes, batch_size=B)
        # boundary bytes: padded frame stack up, heatmaps down (both
        # paths); the unfused path pays the crop->thumbnail resize
        # round trip on top
        Bp = facerec._pad_pow2(B)
        H, W = smalls.shape[1:3]
        pool = facerec.DETECT_POOL
        self._log_batch_transfers(
            items, "detect",
            h2d=Bp * H * W * 3 * smalls.itemsize,
            d2h=Bp * (H // pool) * (W // pool) * 4)
        n_faces = sum(len(c) for c in centers_per)
        if not self.fast_path and n_faces:
            Np = facerec._pad_pow2(n_faces)
            crop_px = facerec.CROP_SIZE * facerec.CROP_SIZE * 3
            thumb_px = facerec.THUMB * facerec.THUMB * 3
            self._log_batch_transfers(items, "crop_resize",
                                      h2d=Np * crop_px * 4,
                                      d2h=Np * thumb_px * 4)
        for (rid, _small, true_boxes, _), centers, faces in zip(
                items, centers_per, faces_per):
            self.ground_truth += len(true_boxes)
            self.detected += len(centers)
            # match detections to ground truth (within 1.5x blob size)
            for (ty, tx, ts) in true_boxes:
                if any(abs(cy - ty / 2) < 1.5 * ts
                       and abs(cx - tx / 2) < 1.5 * ts
                       for cy, cx in centers):
                    self.matched += 1
            for face in faces:
                self.faces_topic.put((rid, face, time.perf_counter()))

    def _identify_loop(self):
        batcher = Batcher(self.faces_topic, batch_size=self.batch_size,
                          timeout_s=self.batch_timeout_s, stop=_STOP)
        for batch in batcher:
            t_deq = time.perf_counter()
            for rid, face, t_q in batch:
                self.log.log(rid, "wait", t_q, t_deq,
                             payload_bytes=face.nbytes)
            B = len(batch)
            stack = np.stack([face for _, face, _ in batch])
            t0 = time.perf_counter()
            if self.fused_identifier is not None:
                # one device program: uint8 crops up, (name-idx, score)
                # down — embed + gallery similarity never leave HBM
                named = self.fused_identifier.identify_crops(stack)
            else:
                embs = self.embedder.embed_batch(stack)
                named = self.classifier.identify_batch(embs)
            t1 = time.perf_counter()
            Bp = facerec._pad_pow2(B)
            if self.fused_identifier is not None:
                # downlink: one int32 name-index + one f32 score per row
                self._log_batch_transfers(batch, "identify_fused",
                                          h2d=Bp * stack[0].nbytes,
                                          d2h=Bp * (np.int32().nbytes
                                                    + np.float32().nbytes))
            else:
                self._log_batch_transfers(batch, "embed",
                                          h2d=Bp * stack[0].nbytes,
                                          d2h=Bp * facerec.EMBED_DIM * 4)
            dt = (t1 - t0) / B
            results = []
            for i, ((rid, face, _), (name, sim)) in enumerate(
                    zip(batch, named)):
                self.log.log(rid, "identify", t0 + i * dt, t0 + (i + 1) * dt,
                             payload_bytes=face.nbytes, batch_size=B)
                results.append((rid, name, sim))
            with self._ident_lock:
                self.identities.extend(results)
        self._merge_stats("identify", batcher.stats)

    # ---- run ---------------------------------------------------------------

    def run(self) -> PipelineResult:
        workers = [threading.Thread(target=self._identify_loop)
                   for _ in range(self.n_workers)]
        for w in workers:
            w.start()
        det = None
        if not self.fused:
            det = threading.Thread(target=self._detect_loop)
            det.start()
        self._ingest_frames()
        if det is not None:
            det.join()
        for _ in workers:
            self.faces_topic.put(_STOP)
        for w in workers:
            w.join()
        return PipelineResult(self.log, self.identities, self.detected,
                              self.ground_truth, self.matched,
                              dict(self.batch_stats))
