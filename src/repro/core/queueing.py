"""Analytic stability / utilization model (paper §5.3-5.4).

The DES shows *that* the system destabilizes; this module shows *why*,
with closed-form resource utilizations: the system is stable iff every
resource's utilization rho = demand/capacity < 1. Under AI acceleration S
the face arrival rate scales with S while storage capacity is fixed —
broker storage write bandwidth is the first rho to cross 1.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.broker import BrokerConfig
from repro.core.simulator import FaceRecWorkload


@dataclass
class ResourceUtilization:
    name: str
    demand: float          # bytes/s or busy-seconds/s
    capacity: float

    @property
    def rho(self) -> float:
        return self.demand / self.capacity if self.capacity else float("inf")

    @property
    def stable(self) -> bool:
        return self.rho < 1.0


def utilizations(wl: FaceRecWorkload, bk: BrokerConfig,
                 speedup: float = 1.0,
                 n_consumers: int | None = None) -> dict[str, ResourceUtilization]:
    """Per-resource rho at acceleration ``speedup``.

    ``n_consumers`` overrides the workload's consumer pool size — the
    cluster uses it to price a deployment of N replica consumers
    without forging a new workload object.
    """
    consumers = wl.n_consumers if n_consumers is None else n_consumers
    div = speedup if wl.accelerate_ingest else 1.0
    frame_rate = wl.n_producers / (wl.frame_period / div)
    if wl.batch_per_tick:
        frame_rate = wl.n_producers * speedup / wl.frame_period
    face_rate = frame_rate * wl.faces_per_frame
    byte_rate = face_rate * (wl.face_bytes + bk.write_overhead_bytes)

    # producer send-path busy fraction. Pipelined (FaceRec): only the
    # client send cost serializes; batch-per-tick (ObjectDet): ingest + the
    # whole set's sends must fit in the tick.
    if wl.batch_per_tick:
        per_tick = wl.t_ingest + speedup * wl.faces_per_frame * wl.t_send
        period = wl.frame_period
    else:
        per_tick = wl.faces_per_frame * wl.t_send
        period = wl.frame_period / div
    return {
        "broker_storage_write": ResourceUtilization(
            "broker_storage_write", byte_rate / bk.n_brokers,
            bk.storage_write_capacity),
        "broker_network": ResourceUtilization(
            "broker_network", 2 * byte_rate / bk.n_brokers, bk.net_bw),
        "producer_send": ResourceUtilization(
            "producer_send", per_tick / period, 1.0),
        "consumers": ResourceUtilization(
            "consumers", face_rate * wl.t_identify / speedup,
            float(consumers)),
    }


def max_stable_speedup(wl: FaceRecWorkload, bk: BrokerConfig,
                       resource: str = "broker_storage_write",
                       hi: float = 64.0) -> float:
    """Largest S with rho < 1 for the given resource (bisection)."""
    lo, hi_ = 0.5, hi
    for _ in range(40):
        mid = 0.5 * (lo + hi_)
        if utilizations(wl, bk, mid)[resource].stable:
            lo = mid
        else:
            hi_ = mid
    return lo


def stability_knee(wl: FaceRecWorkload, bk: BrokerConfig,
                   n_consumers: int | None = None,
                   hi: float = 64.0) -> float:
    """Largest S with EVERY resource's rho < 1 (bisection).

    Unlike :func:`max_stable_speedup` (one named resource), this is the
    whole-system destabilization point the DES and the live cluster
    measure — the quantity the three models are cross-validated on.
    """
    def stable(s: float) -> bool:
        return all(u.stable
                   for u in utilizations(wl, bk, s, n_consumers).values())

    lo, hi_ = 0.5, hi
    if not stable(lo):
        return lo
    for _ in range(40):
        mid = 0.5 * (lo + hi_)
        if stable(mid):
            lo = mid
        else:
            hi_ = mid
    return lo


def bottleneck(wl: FaceRecWorkload, bk: BrokerConfig,
               speedup: float) -> ResourceUtilization:
    us = utilizations(wl, bk, speedup)
    return max(us.values(), key=lambda u: u.rho)
