"""Face Recognition demo models (tiny, pure JAX, CPU-runnable).

The paper's application uses MTCNN + FaceNet; this module provides
family-equivalent stand-ins sized for the container so the *pipeline* is
real end-to-end: a blob detector (heatmap + peak extraction = the
"detection model"), a thumbnail embedder (conv-ish MLP = "feature
extraction"), and a nearest-centroid classifier (the "SVM"). Synthetic
frames carry ground-truth face positions (repro.data.video), so detection
recall is testable.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops

THUMB = 32          # thumbnail side (paper: 160x160)
EMBED_DIM = 128     # paper: 128-byte feature vector


@functools.partial(jax.jit, static_argnums=(1,))
def detect_heatmap(frame: jax.Array, pool: int = 8) -> jax.Array:
    """Brightness heatmap at 1/pool resolution. frame: (H, W, 3) uint8."""
    x = frame.astype(jnp.float32).mean(-1)
    H, W = x.shape
    x = x[:H - H % pool, :W - W % pool]
    x = x.reshape(H // pool, pool, W // pool, pool).mean((1, 3))
    return x


def detect_faces(frame: np.ndarray, pool: int = 8, thresh: float = 60.0,
                 max_faces: int = 5) -> list[tuple[int, int]]:
    """Peak extraction on the heatmap -> face centers (full-res coords)."""
    hm = np.asarray(detect_heatmap(jnp.asarray(frame), pool))
    out = []
    hm = hm.copy()
    for _ in range(max_faces):
        ij = np.unravel_index(np.argmax(hm), hm.shape)
        if hm[ij] < thresh:
            break
        out.append((int(ij[0] * pool + pool // 2),
                    int(ij[1] * pool + pool // 2)))
        y0, x0 = ij
        hm[max(0, y0 - 3):y0 + 4, max(0, x0 - 3):x0 + 4] = 0.0
    return out


def crop_thumbnail(frame: np.ndarray, y: int, x: int,
                   size: int = 48) -> np.ndarray:
    H, W, _ = frame.shape
    half = size // 2
    y = int(np.clip(y, half, H - half))
    x = int(np.clip(x, half, W - half))
    crop = frame[y - half:y + half, x - half:x + half]
    # the paper's resize tax: normalize crop to the model's input size
    return np.asarray(ops.resize_bilinear(
        jnp.asarray(crop, jnp.float32), THUMB, THUMB))


class Embedder:
    """Feature extraction: fixed random projection MLP (FaceNet stand-in)."""

    def __init__(self, seed: int = 7):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        d_in = THUMB * THUMB * 3
        self.w1 = jax.random.normal(k1, (d_in, 256)) / d_in**0.5
        self.w2 = jax.random.normal(k2, (256, EMBED_DIM)) / 16.0
        self._fn = jax.jit(self._embed)

    def _embed(self, thumb):
        x = thumb.reshape(-1) / 255.0
        h = jnp.tanh(x @ self.w1)
        e = h @ self.w2
        return e / jnp.linalg.norm(e)

    def __call__(self, thumb: np.ndarray) -> np.ndarray:
        return np.asarray(self._fn(jnp.asarray(thumb)))


class Classifier:
    """Nearest-centroid over a gallery of known identities."""

    def __init__(self, gallery: dict[str, np.ndarray]):
        self.names = list(gallery)
        self.mat = np.stack([gallery[n] for n in self.names])

    def identify(self, emb: np.ndarray) -> tuple[str, float]:
        sims = self.mat @ emb
        i = int(np.argmax(sims))
        return self.names[i], float(sims[i])
