"""Face Recognition demo models (tiny, pure JAX, CPU-runnable).

The paper's application uses MTCNN + FaceNet; this module provides
family-equivalent stand-ins sized for the container so the *pipeline* is
real end-to-end: a blob detector (heatmap + peak extraction = the
"detection model"), a thumbnail embedder (conv-ish MLP = "feature
extraction"), and a nearest-centroid classifier (the "SVM"). Synthetic
frames carry ground-truth face positions (repro.data.video), so detection
recall is testable.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.preprocess.stage import NormSpec, PreprocessStage

THUMB = 32          # thumbnail side (paper: 160x160)
EMBED_DIM = 128     # paper: 128-byte feature vector
CROP_SIZE = 48      # detection crop window fed to the THUMB resize
DETECT_POOL = 8     # heatmap downsampling factor (full-res / pool)

def stage_category(stage: str) -> str:
    """Face-pipeline stage name -> {pre, ai, post, transfer, queue}.

    Thin alias over the canonical table in ``repro.core.events``
    (:data:`repro.core.events.STAGE_CATEGORIES` +
    :func:`repro.core.events.categorize`): the live pipeline, the DES
    and the fig06/fig08 benchmarks all resolve through ONE map, so
    figures and runtime share one attribution. Prefix-typed stages
    (``pre_*``/``post_*`` from
    :class:`repro.preprocess.PreprocessStage`) classify themselves;
    unknown supporting stages default to ``pre`` (work around the AI
    that isn't a queue or a crossing is pre/post-processing — the
    paper's residual-tax convention).
    """
    from repro.core.events import categorize
    return categorize(stage)


def _pad_pow2(n: int) -> int:
    """Batch-size bucket: next power of two, so jit traces stay bounded."""
    return 1 << (n - 1).bit_length()


def _pad_rows_pow2(arr: np.ndarray) -> np.ndarray:
    """Zero-pad the leading dim to its power-of-two bucket.

    Every batch entry point pads through here (and slices the result
    back to the true B) so the jit-retrace bucketing can't drift
    between stages.
    """
    pad = _pad_pow2(len(arr)) - len(arr)
    if pad:
        arr = np.concatenate(
            [arr, np.zeros((pad, *arr.shape[1:]), arr.dtype)], axis=0)
    return arr


@functools.partial(jax.jit, static_argnums=(1,))
def detect_heatmap(frame: jax.Array,
                   pool: int = DETECT_POOL) -> jax.Array:
    """Brightness heatmap at 1/pool resolution. frame: (H, W, 3) uint8."""
    return detect_heatmap_batch(frame[None], pool)[0]


@functools.partial(jax.jit, static_argnums=(1,))
def detect_heatmap_batch(frames: jax.Array,
                         pool: int = DETECT_POOL) -> jax.Array:
    """Heatmaps for a stacked batch. frames: (B, H, W, 3) uint8."""
    x = frames.astype(jnp.float32).mean(-1)
    B, H, W = x.shape
    x = x[:, :H - H % pool, :W - W % pool]
    return x.reshape(B, H // pool, pool, W // pool, pool).mean((2, 4))


def _extract_peaks(hm: np.ndarray, pool: int, thresh: float,
                   max_faces: int) -> list[tuple[int, int]]:
    out = []
    hm = hm.copy()
    for _ in range(max_faces):
        ij = np.unravel_index(np.argmax(hm), hm.shape)
        if hm[ij] < thresh:
            break
        out.append((int(ij[0] * pool + pool // 2),
                    int(ij[1] * pool + pool // 2)))
        y0, x0 = ij
        hm[max(0, y0 - 3):y0 + 4, max(0, x0 - 3):x0 + 4] = 0.0
    return out


def detect_faces(frame: np.ndarray, pool: int = DETECT_POOL,
                 thresh: float = 60.0,
                 max_faces: int = 5) -> list[tuple[int, int]]:
    """Peak extraction on the heatmap -> face centers (full-res coords)."""
    return detect_faces_batch(frame[None], pool, thresh, max_faces)[0]


def detect_faces_batch(frames: np.ndarray, pool: int = DETECT_POOL,
                       thresh: float = 60.0,
                       max_faces: int = 5) -> list[list[tuple[int, int]]]:
    """Face centers per frame; one heatmap call for the whole stack.

    frames: (B, H, W, 3). Peak extraction stays per-frame numpy (it is
    data-dependent and tiny); only the dense heatmap is batched. B is
    padded to a power-of-two bucket (like Embedder.embed_batch) so
    ragged timeout-flushed batches don't each retrace the jit.
    """
    B = frames.shape[0]
    hms = np.asarray(detect_heatmap_batch(
        jnp.asarray(_pad_rows_pow2(frames)), pool))[:B]
    return [_extract_peaks(hm, pool, thresh, max_faces) for hm in hms]


def crop_thumbnail(frame: np.ndarray, y: int, x: int,
                   size: int = CROP_SIZE) -> np.ndarray:
    return crop_thumbnails_batch([frame], [[(y, x)]], size)[0][0]


def crop_stacks(frames: list[np.ndarray],
                centers_per_frame: list[list[tuple[int, int]]],
                size: int = CROP_SIZE) -> tuple[np.ndarray | None, list[int]]:
    """Host-side crop extraction shared by the fused and unfused paths.

    Pure numpy slicing (no resize, no device work): every detection
    becomes a (size, size, C) window clipped to the frame, zero-padded
    when the frame is smaller than the window. Returns the stacked
    crops (N_faces, size, size, C) — or None when there are none — plus
    the per-frame face counts for regrouping.
    """
    half = size // 2
    crops, counts = [], []
    for frame, centers in zip(frames, centers_per_frame):
        H, W, C = frame.shape
        counts.append(len(centers))
        for y, x in centers:
            y0 = int(np.clip(y - half, 0, max(0, H - size)))
            x0 = int(np.clip(x - half, 0, max(0, W - size)))
            crop = frame[y0:y0 + size, x0:x0 + size]
            if crop.shape[:2] != (size, size):
                # frame smaller than the crop window: zero-pad so the
                # stacked resize still sees uniform (size, size, C)
                padded = np.zeros((size, size, C), crop.dtype)
                padded[:crop.shape[0], :crop.shape[1]] = crop
                crop = padded
            crops.append(crop)
    if not crops:
        return None, counts
    return np.stack(crops), counts


def _regroup(flat: list, counts: list[int]) -> list[list]:
    out, i = [], 0
    for n in counts:
        out.append(list(flat[i:i + n]))
        i += n
    return out


def crop_thumbnails_batch(frames: list[np.ndarray],
                          centers_per_frame: list[list[tuple[int, int]]],
                          size: int = CROP_SIZE) -> list[list[np.ndarray]]:
    """Crop every detection in a batch of frames; one resize call total.

    The paper's resize tax: each crop is normalized to the model's THUMB
    input size. Batching turns B_faces separate resizes into a single
    (B_faces, size, size, 3) -> (B_faces, THUMB, THUMB, 3) kernel call.
    Returns thumbnails grouped per frame (same nesting as the centers).
    """
    crops, counts = crop_stacks(frames, centers_per_frame, size)
    if crops is None:
        return [[] for _ in frames]
    stack = _pad_rows_pow2(crops.astype(np.float32))
    thumbs = np.asarray(ops.resize_bilinear(
        jnp.asarray(stack), THUMB, THUMB))[:len(crops)]
    return _regroup(thumbs, counts)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _embed_batch_jit(thumbs, w1, w2, impl, norm):
    """Module-level jit: the compile cache is shared across Embedder
    instances (weights are traced arguments), so fresh pipelines reuse
    already-compiled batch buckets. The kernel impl is a static arg —
    resolved by the caller at call time, not frozen at first trace —
    so ops.set_default_impl/default_impl switches keep working. The
    norm spec is static too: the default (to_unit, zero mean, unit
    std) traces to the literal ``/ 255.0`` this path always had."""
    x = thumbs.astype(jnp.float32)
    if norm.to_unit:
        x = x / 255.0
    if any(m != 0.0 for m in norm.mean):
        x = x - jnp.asarray(norm.mean, jnp.float32)
    if any(s != 1.0 for s in norm.std):
        x = x / jnp.asarray(norm.std, jnp.float32)
    x = x.reshape(x.shape[0], -1)
    h = jnp.tanh(ops.matmul(x, w1, impl=impl))
    e = ops.matmul(h, w2, impl=impl)
    # clamp: zero-padded rows would otherwise normalize 0/0 -> NaN
    # (sliced off, but poisonous under JAX_DEBUG_NANS)
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True),
                           1e-12)


class Embedder:
    """Feature extraction: fixed random projection MLP (FaceNet stand-in).

    The batch path is the production one: a single jitted call over a
    (B, THUMB, THUMB, 3) stack, two ops.matmul contractions (Pallas on
    TPU), so B faces cost one kernel launch instead of B. The scalar
    ``__call__`` delegates to it with B=1 so the two paths never drift.

    ``norm`` is the crop normalization (default: the historical
    ``/255``), normally supplied by the preprocess stage's
    ``crop_norm`` so host embed and the fused device fold share one
    set of constants.
    """

    def __init__(self, seed: int = 7, norm: NormSpec | None = None):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        d_in = THUMB * THUMB * 3
        self.w1 = jax.random.normal(k1, (d_in, 256)) / d_in**0.5
        self.w2 = jax.random.normal(k2, (256, EMBED_DIM)) / 16.0
        self.norm = norm or NormSpec(to_unit=True)

    def embed_batch(self, thumbs: np.ndarray) -> np.ndarray:
        """thumbs: (B, THUMB, THUMB, 3) -> (B, EMBED_DIM), unit rows.

        B is padded to a power-of-two bucket so jit retraces stay
        bounded when timeout flushes produce ragged batch sizes.
        """
        B = thumbs.shape[0]
        return np.asarray(_embed_batch_jit(
            jnp.asarray(_pad_rows_pow2(thumbs)), self.w1, self.w2,
            ops.get_default_impl(), self.norm))[:B]

    def __call__(self, thumb: np.ndarray) -> np.ndarray:
        return self.embed_batch(np.asarray(thumb)[None])[0]


class Classifier:
    """Nearest-centroid over a gallery of known identities."""

    def __init__(self, gallery: dict[str, np.ndarray]):
        self.names = list(gallery)
        self.mat = np.stack([gallery[n] for n in self.names])

    def identify(self, emb: np.ndarray) -> tuple[str, float]:
        return self.identify_batch(emb[None])[0]

    def identify_batch(self, embs: np.ndarray) -> list[tuple[str, float]]:
        """One (B, G) similarity matmul instead of B gallery sweeps."""
        sims = embs @ self.mat.T
        idx = np.argmax(sims, axis=1)
        return [(self.names[i], float(sims[b, i]))
                for b, i in enumerate(idx)]


# --------------------------------------------------------------------------
# Device-resident fast path: crop-stack -> embed -> gallery, one program
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(5,))
def _fused_identify_jit(crops, w1f, b1, w2, gal_t, impl):
    """One device program for the whole identify hot loop.

    The bilinear resize is linear, so it is pre-composed into ``w1f``
    (see :class:`FusedIdentifier`): the raw crop pixels hit a single
    (crop_px, 256) matmul whose fused tanh epilogue keeps the hidden
    layer in VMEM, then the embedding matmul, normalization, and the
    gallery similarity + argmax all run on-device. Only the crop stack
    crosses host->device and only (name-index, score) crosses back.
    ``b1`` carries the normalization offset fold (None when the crop
    norm has no mean shift — the default — keeping the historical
    trace).
    """
    x = crops.reshape(crops.shape[0], -1).astype(jnp.float32)
    h = ops.matmul(x, w1f, bias=b1, epilogue="tanh", impl=impl)
    e = ops.matmul(h, w2, impl=impl)
    e = e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-12)
    sims = e @ gal_t
    return jnp.argmax(sims, axis=1).astype(jnp.int32), jnp.max(sims, axis=1)


class FusedIdentifier:
    """Crop -> resize -> embed -> classify as ONE jitted device program.

    The unfused hot loop crosses the host<->device boundary four times
    per face batch (crop upload for the thumbnail resize, thumbnail
    download, thumbnail upload for the embed, embedding download) and
    classifies on the host. This path exploits that bilinear resize is
    *linear*: ``thumb = Ry @ crop @ Rx^T`` per channel, so the
    interpolation operator, the ``/255`` normalization, and the
    flatten are pre-composed with the embedder's first layer ONCE at
    init —

        w1_fold[(sy, sx, c), j] = sum_{ty,tx} Ry[ty,sy] Rx[tx,sx]
                                  * w1[(ty,tx,c), j] / 255

    — turning crop-pixels -> hidden into a single (crop_px, 256)
    matmul. Per call, only the uint8 crop stack goes up and a
    (name-index, score) pair per face comes down.

    The crop normalization folds in the same way: its per-channel
    scale multiplies the folded columns (the historical ``/255`` is
    just the default spec) and its offset becomes a bias on the first
    matmul's fused epilogue. The spec comes from the preprocess
    stage's ``crop_norm`` when one is supplied — the stage is the
    single owner of normalization constants — else the embedder's.
    """

    def __init__(self, embedder: Embedder, classifier: Classifier,
                 crop_size: int = CROP_SIZE,
                 preprocess: PreprocessStage | None = None):
        from repro.kernels.resize import _interp_matrix
        self.size = crop_size
        self.names = classifier.names
        norm = preprocess.crop_norm if preprocess is not None \
            else embedder.norm
        ry = _interp_matrix(THUMB, crop_size).astype(np.float64)
        rx = _interp_matrix(THUMB, crop_size).astype(np.float64)
        w1r = np.asarray(embedder.w1, np.float64).reshape(THUMB, THUMB, 3, -1)
        # optimize=True: contract pairwise (Ry first, then Rx) instead of
        # a naive 6-index loop — ~100x faster, identical result
        scale64 = 1.0 / ((255.0 if norm.to_unit else 1.0)
                         * np.asarray(norm.std, np.float64))
        w1f = np.einsum("ts,uv,tucj->svcj", ry, rx, w1r, optimize=True) \
            * scale64[None, None, :, None]
        self.w1f = jnp.asarray(
            w1f.reshape(crop_size * crop_size * 3, -1).astype(np.float32))
        offset = -np.asarray(norm.mean, np.float64) \
            / np.asarray(norm.std, np.float64)
        if np.any(offset):
            # bias_j = sum_{ty,tx,c} v_c * w1[(ty,tx,c), j]: the affine
            # offset is spatially constant, so it bypasses the resize
            self.b1 = jnp.asarray(
                np.einsum("c,tucj->j", offset, w1r).astype(np.float32))
        else:
            self.b1 = None
        self.w2 = embedder.w2
        self.gal_t = jnp.asarray(classifier.mat.T)    # (EMBED_DIM, G)

    def identify_crops(self, crops: np.ndarray) -> list[tuple[str, float]]:
        """crops: (B, size, size, 3) any real dtype -> [(name, score)].

        B is padded to its power-of-two bucket (same bucketing as the
        unfused stages) so ragged timeout-flushed batches reuse traces;
        B=1 degenerates to the same code path.
        """
        B = crops.shape[0]
        idx, score = _fused_identify_jit(
            jnp.asarray(_pad_rows_pow2(np.ascontiguousarray(crops))),
            self.w1f, self.b1, self.w2, self.gal_t, ops.get_default_impl())
        idx, score = np.asarray(idx)[:B], np.asarray(score)[:B]
        return [(self.names[i], float(s)) for i, s in zip(idx, score)]

    def identify_batch(self, frames: list[np.ndarray],
                       centers_per_frame: list[list[tuple[int, int]]],
                       ) -> list[list[tuple[str, float]]]:
        """Fused analogue of crop_thumbnails_batch + Embedder.embed_batch
        + Classifier.identify_batch, grouped per frame like the centers."""
        crops, counts = crop_stacks(frames, centers_per_frame, self.size)
        if crops is None:
            return [[] for _ in frames]
        return _regroup(self.identify_crops(crops), counts)


@dataclass
class IdentifyStack:
    """Everything one deployment of the identify stage needs.

    ``preprocess`` is first-class: the stage that owns decode /
    letterbox / NMS and every normalization constant, switchable
    between ``placement="host"`` and ``"device"``. The embedder and
    the fused identifier both derive their crop normalization from it,
    so the three consumers (streaming pipeline, serving-cluster
    replicas, standalone benchmarks) cannot drift apart.
    """
    embedder: Embedder
    classifier: Classifier
    fused: FusedIdentifier | None
    preprocess: PreprocessStage


def build_identify_stack(seed: int = 0, gallery_size: int = 8,
                         fast_path: bool = True, placement: str = "host",
                         log=None) -> IdentifyStack:
    """The identification stage's model stack, built once.

    Shared by every deployment of the stage: ``StreamingPipeline``
    constructs its identify workers from this, and ``repro.cluster``
    replicas running in ``service="real"`` mode call the very same
    factory — so a cluster replica IS the pipeline's identify stage,
    not a reimplementation. The gallery is ``gallery_size`` synthetic
    identities embedded at init (deterministic in ``seed``).

    ``placement`` selects where the pre/post-processing runs (host
    NumPy vs jitted/Pallas device programs); ``log`` is the EventLog
    the preprocess stage accounts into (attachable later via
    ``stack.preprocess.log = ...``).
    """
    preprocess = PreprocessStage(placement, log=log)
    embedder = Embedder(norm=preprocess.crop_norm)
    rng = np.random.default_rng(seed)
    thumbs = rng.uniform(0, 255, (gallery_size, THUMB, THUMB, 3))
    gallery_embs = embedder.embed_batch(thumbs.astype(np.float32))
    classifier = Classifier(
        {f"person_{i}": gallery_embs[i] for i in range(gallery_size)})
    fused = (FusedIdentifier(embedder, classifier, preprocess=preprocess)
             if fast_path else None)
    return IdentifyStack(embedder, classifier, fused, preprocess)


def identify_fused_batch(frames: list[np.ndarray],
                         centers_per_frame: list[list[tuple[int, int]]],
                         embedder: Embedder, classifier: Classifier,
                         crop_size: int = CROP_SIZE,
                         ) -> list[list[tuple[str, float]]]:
    """One-shot convenience over :class:`FusedIdentifier` (which callers
    on a hot loop should construct once — the resize fold happens at
    init)."""
    return FusedIdentifier(embedder, classifier,
                           crop_size).identify_batch(frames,
                                                     centers_per_frame)
