"""Apache-Kafka-model broker substrate (paper §3.4).

Models the mechanisms the paper measures:
  * topics split into partitions (max one consumer per partition);
  * leader + follower replication (``replication`` copies, acks=1:
    a message is consumable after the leader write; follower traffic is
    asynchronous background load);
  * producer-side batching (``linger_s``, ``batch_bytes``);
  * broker-side consumer fetch batching (``fetch_min_bytes``,
    ``fetch_max_wait_s``) — the mechanism behind §5.5's waiting-time floor;
  * storage write channel per broker with configurable drive count —
    the resource §5.4 shows saturating under AI acceleration.

Calibration note (documented in EXPERIMENTS.md §Paper-validation): the
paper reports broker storage write utilization of ~10% at 1x with the
Fig-10 setup, which matches leader-write accounting; async follower
replication in their deployment evidently consolidated into large
sequential writes whose marginal cost is folded into the drive-efficiency
constant rather than tripling byte volume.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class BrokerConfig:
    n_brokers: int = 3
    replication: int = 3
    drives_per_broker: int = 1
    drive_write_bw: float = 1.1e9        # bytes/s (Intel P4510, Table 2)
    drive_read_bw: float = 2.85e9
    # multi-drive striping efficiency (queue-depth effects; calibrated to
    # the paper's Fig 15a unlock points)
    drive_efficiency: tuple = (0.75, 0.65, 0.83, 0.83)
    write_overhead_bytes: int = 800      # per-record log overhead (fs+index)
    linger_s: float = 0.005              # producer batching window
    batch_bytes: int = 16384
    fetch_min_bytes: int = 150 * 1024    # broker withholds until this...
    fetch_max_wait_s: float = 0.5        # ...or this timeout (Kafka defaults)
    net_bw: float = 100e9 / 8            # 100 Gbps NIC, bytes/s
    page_cache_reads: bool = True        # consumer reads served from memory

    @property
    def storage_write_capacity(self) -> float:
        """Effective bytes/s per broker across its drives."""
        d = self.drives_per_broker
        eff = self.drive_efficiency[min(d, len(self.drive_efficiency)) - 1]
        return d * self.drive_write_bw * eff

    def write_time(self, nbytes: float) -> float:
        """Seconds the leader's storage channel is busy for one record
        (log payload + per-record overhead). Both the DES and the live
        cluster pace writes with this, so their knees are comparable."""
        return (nbytes + self.write_overhead_bytes) / self.storage_write_capacity

    def leader_for(self, partition_index: int) -> int:
        """Static round-robin partition->leader placement (how Topic
        assigns leaders; exposed so live partitions match the model)."""
        return partition_index % self.n_brokers

    def scaled(self, eff: float) -> "BrokerConfig":
        """A copy with per-broker bandwidths scaled by ``eff``.

        Scale-model runs shrink producer counts by ``eff`` and broker
        capacity together, preserving every utilization ratio (and thus
        the stability knee) while cutting the event/thread count —
        the same trick as ``ClusterSim``'s ``scale`` knob, lifted here
        so the live cluster and the closed form share it.
        """
        from dataclasses import replace
        return replace(self, drive_write_bw=self.drive_write_bw * eff,
                       net_bw=self.net_bw * eff)


def range_assignment(members, n_partitions: int) -> dict:
    """Kafka's range assignment: partitions split contiguously over the
    sorted member list (first ``extra`` members get one more).

    Pure and deterministic in (members, n_partitions) — no RNG — so the
    live ``ConsumerGroup`` and the DES's fault-mode membership map share
    one implementation and can never disagree about who owns what.
    Members beyond ``n_partitions`` own nothing (idle standbys).
    """
    table: dict = {}
    ms = sorted(members)
    if not ms:
        return table
    base, extra = divmod(n_partitions, len(ms))
    start = 0
    for i, m in enumerate(ms):
        width = base + (1 if i < extra else 0)
        table[m] = tuple(range(start, start + width))
        start += width
    return table


def pick_victim(members, rank):
    """Rank-th member of the sorted alive list (None when empty).

    The ONE victim-selection rule for fault injection, shared by the
    live ``FaultEngine`` and the DES so a fault plan names the same
    casualty in both runtimes.
    """
    ms = sorted(members)
    if not ms:
        return None
    return ms[(rank or 0) % len(ms)]


@dataclass
class Partition:
    topic: str
    index: int
    leader: int                        # broker id
    backlog: list = field(default_factory=list)   # (ready_time, msg)
    bytes_in: float = 0.0

    def append(self, ready_time: float, msg) -> None:
        self.backlog.append((ready_time, msg))
        self.bytes_in += msg.size


@dataclass
class Message:
    key: int
    size: float
    t_produced: float                  # end of producing stage
    t_published: float = 0.0           # after producer batching
    t_written: float = 0.0             # leader write done (consumable)
    t_consumed: float = 0.0            # consumer picks it up
    meta: dict = field(default_factory=dict)

    @property
    def broker_wait(self) -> float:
        return self.t_consumed - self.t_produced


class Topic:
    """Partitioned topic with round-robin producer assignment."""

    def __init__(self, name: str, n_partitions: int, cfg: BrokerConfig):
        self.name = name
        self.cfg = cfg
        self.partitions = [
            Partition(name, i, leader=i % cfg.n_brokers)
            for i in range(n_partitions)]
        self._rr = 0

    def pick_partition(self) -> Partition:
        p = self.partitions[self._rr % len(self.partitions)]
        self._rr += 1
        return p

    def bytes_per_broker(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for p in self.partitions:
            out[p.leader] = out.get(p.leader, 0.0) + p.bytes_in
        return out
