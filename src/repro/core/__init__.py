"""The paper's application and analysis layer (its primary contribution).

The SYSTEM lives here, in the host framework: the live streaming
pipeline and its micro-batching, the event-level tax instrumentation,
the face-recognition model stack and shared identify-stack factory,
the Kafka-style broker model, closed-form queueing stability, the
discrete-event cluster simulator, Amdahl/acceleration analytics, and
the TCO tables. Sibling subpackages supply substrates: ``kernels``
(Pallas), ``preprocess`` (the pre/post tax), ``cluster`` (live
multi-replica serving), ``roofline`` (calibrated cost model).
"""
