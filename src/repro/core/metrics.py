"""Tail-latency metrics and SLOs (shared by cluster and serving engine).

The paper's argument lives in the tail: mean latency hides the broker
waiting-time floor and the pre-knee queueing blow-up, so deployments
report p50/p95/p99 per request and check them against explicit
service-level objectives. Lives in core (pure stdlib, no deps) so the
serving engine and benchmarks use the same vocabulary as the
multi-replica cluster without importing its runtime;
``repro.cluster.metrics`` re-exports it under the cluster namespace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


def percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (the EventLog.tail convention)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(math.ceil(q * len(s))) - 1))]


@dataclass
class LatencyStats:
    """Per-request latency summary in seconds (model time)."""
    n: int = 0
    mean: float = 0.0
    p50: float = 0.0
    p95: float = 0.0
    p99: float = 0.0
    max: float = 0.0

    @classmethod
    def from_samples(cls, xs: list[float]) -> "LatencyStats":
        if not xs:
            return cls()
        return cls(n=len(xs), mean=sum(xs) / len(xs),
                   p50=percentile(xs, 0.50), p95=percentile(xs, 0.95),
                   p99=percentile(xs, 0.99), max=max(xs))

    def to_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass(frozen=True)
class TailSLO:
    """Latency objectives; ``None`` means "not part of the contract"."""
    p50_s: float | None = None
    p95_s: float | None = None
    p99_s: float | None = None
    max_drop_fraction: float | None = None

    def check(self, stats: LatencyStats,
              drop_fraction: float = 0.0) -> "SLOReport":
        violations = []
        for name, bound, got in (("p50", self.p50_s, stats.p50),
                                 ("p95", self.p95_s, stats.p95),
                                 ("p99", self.p99_s, stats.p99)):
            if bound is not None and got > bound:
                violations.append(f"{name}={got:.4f}s > {bound:.4f}s")
        if (self.max_drop_fraction is not None
                and drop_fraction > self.max_drop_fraction):
            violations.append(
                f"drops={drop_fraction:.3f} > {self.max_drop_fraction:.3f}")
        return SLOReport(ok=not violations, violations=violations)


@dataclass
class SLOReport:
    ok: bool
    violations: list = field(default_factory=list)
