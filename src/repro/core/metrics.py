"""Tail-latency metrics and SLOs (shared by cluster and serving engine).

The paper's argument lives in the tail: mean latency hides the broker
waiting-time floor and the pre-knee queueing blow-up, so deployments
report p50/p95/p99 per request and check them against explicit
service-level objectives. Lives in core (pure stdlib, no deps) so the
serving engine and benchmarks use the same vocabulary as the
multi-replica cluster without importing its runtime;
``repro.cluster.metrics`` re-exports it under the cluster namespace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


def percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (the EventLog.tail convention)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(math.ceil(q * len(s))) - 1))]


@dataclass
class LatencyStats:
    """Per-request latency summary in seconds (model time)."""
    n: int = 0
    mean: float = 0.0
    p50: float = 0.0
    p95: float = 0.0
    p99: float = 0.0
    max: float = 0.0

    @classmethod
    def from_samples(cls, xs: list[float]) -> "LatencyStats":
        if not xs:
            return cls()
        return cls(n=len(xs), mean=sum(xs) / len(xs),
                   p50=percentile(xs, 0.50), p95=percentile(xs, 0.95),
                   p99=percentile(xs, 0.99), max=max(xs))

    def to_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass(frozen=True)
class TailSLO:
    """Latency objectives; ``None`` means "not part of the contract"."""
    p50_s: float | None = None
    p95_s: float | None = None
    p99_s: float | None = None
    max_drop_fraction: float | None = None

    def check(self, stats: LatencyStats,
              drop_fraction: float = 0.0) -> "SLOReport":
        violations = []
        for name, bound, got in (("p50", self.p50_s, stats.p50),
                                 ("p95", self.p95_s, stats.p95),
                                 ("p99", self.p99_s, stats.p99)):
            if bound is not None and got > bound:
                violations.append(f"{name}={got:.4f}s > {bound:.4f}s")
        if (self.max_drop_fraction is not None
                and drop_fraction > self.max_drop_fraction):
            violations.append(
                f"drops={drop_fraction:.3f} > {self.max_drop_fraction:.3f}")
        return SLOReport(ok=not violations, violations=violations)


@dataclass
class SLOReport:
    ok: bool
    violations: list = field(default_factory=list)


# ---- fault-recovery accounting ---------------------------------------------
#
# Everything below windows a stream of (completion_time, latency)
# samples around a fault so both execution engines (DES simulated time,
# live compressed wall time) report recovery in the same vocabulary:
# how high the rebalance spike pushed the tail, and how long after the
# repair the tail took to return to its pre-fault level.


def windowed_percentile(samples, q: float,
                        window_s: float) -> list[tuple[float, float, int]]:
    """Tumbling-window tail over ``(t, latency)`` samples.

    Returns ``(window_end_t, percentile, n)`` per non-empty window,
    aligned to ``t=0`` so same-seed runs window identically. Windows
    with no completions are simply absent — during a full outage
    nothing completes, and an empty window must not read as "tail
    recovered to zero".
    """
    if window_s <= 0:
        raise ValueError("window_s must be positive")
    buckets: dict[int, list[float]] = {}
    for t, lat in samples:
        buckets.setdefault(int(t // window_s), []).append(lat)
    return [((i + 1) * window_s, percentile(xs, q), len(xs))
            for i, xs in sorted(buckets.items())]


@dataclass
class RecoveryReport:
    """How a fault window moved the tail, and how fast it came back.

    ``recovery_s`` is measured from the REPAIR (``t_restore``), not the
    fault: it answers "once capacity returned, how long until the tail
    forgot the outage" — the backlog-drain time the paper's queueing
    model prices. ``inf`` means the tail never re-entered
    ``factor * baseline_p99`` before the run ended.
    """
    baseline_p99: float           # pre-fault tail
    spike_p99: float              # worst window at/after the fault
    recovery_s: float             # repair -> tail back under factor*baseline
    drain_s: float                # repair -> backlog back under pre-fault mean
    windows: list = field(default_factory=list)

    def to_dict(self) -> dict:
        return {k: (v if k != "windows" else list(v))
                for k, v in self.__dict__.items()}


# ---- reliability accounting -------------------------------------------------
#
# The retry/hedge/deadline layer (``repro.cluster.reliability``) changes
# what "throughput" means: a request that completes after its deadline,
# or completes twice because a hedge raced the primary, is load the
# cluster carried but value the client never saw. Both execution engines
# emit the raw counters; this report turns them into the paper-style
# quantities — goodput vs throughput, retry amplification, deadline-miss
# rate — so live and DES runs can be compared number-for-number.


@dataclass
class ReliabilityReport:
    """Client-visible value vs cluster-carried load for one run.

    ``goodput`` counts only unique completions inside their deadline;
    ``throughput`` counts every unique completion; ``amplification`` is
    published attempts per offered request (1.0 = no retries/hedges —
    the retry-storm metric). ``breaker_timeline`` /
    ``degrade_timeline`` are ``(t, state_or_depth, ...)`` transition
    lists, empty when the corresponding policy is off.
    """
    offered: int = 0              # unique requests submitted
    attempts: int = 0             # publishes incl. retries + hedges
    completed: int = 0            # unique completions (dedup by rid)
    in_deadline: int = 0          # completions within the deadline
    deadline_misses: int = 0      # deadline passed with no completion yet
    retries: int = 0
    hedges: int = 0
    hedge_cancels: int = 0        # duplicate killed at dequeue (cheap)
    hedge_wastes: int = 0         # duplicate fully served (wasted work)
    breaker_sheds: int = 0        # attempts refused: every circuit open
    throughput: float = 0.0       # unique completions / span
    goodput: float = 0.0          # in-deadline completions / span
    amplification: float = 1.0    # attempts / offered
    deadline_miss_rate: float = 0.0
    accuracy_proxy_mean: float = 1.0
    breaker_timeline: list = field(default_factory=list)
    degrade_timeline: list = field(default_factory=list)

    def to_dict(self) -> dict:
        out = dict(self.__dict__)
        out["breaker_timeline"] = [list(x) for x in self.breaker_timeline]
        out["degrade_timeline"] = [list(x) for x in self.degrade_timeline]
        return out


def reliability_report(completions, deadline_s: float, span_s: float, *,
                       offered: int, attempts: int, deadline_misses: int = 0,
                       retries: int = 0, hedges: int = 0,
                       hedge_cancels: int = 0, hedge_wastes: int = 0,
                       breaker_sheds: int = 0,
                       accuracy_proxy_mean: float = 1.0,
                       breaker_timeline=(), degrade_timeline=(),
                       ) -> ReliabilityReport:
    """Fold a unique-completion stream + lifecycle counters into a report.

    ``completions`` is the deduped ``(t_complete, latency)`` stream
    (one entry per request id, the winning attempt); ``deadline_s``
    classifies each into goodput or late; ``span_s`` converts counts to
    rates. Shared verbatim by the DES and the live cluster so
    ``crossval`` can gate their agreement.
    """
    if span_s <= 0:
        raise ValueError("span_s must be positive")
    completed = len(completions)
    in_deadline = sum(1 for _, lat in completions if lat <= deadline_s)
    offered = max(int(offered), 0)
    return ReliabilityReport(
        offered=offered, attempts=int(attempts), completed=completed,
        in_deadline=in_deadline, deadline_misses=int(deadline_misses),
        retries=int(retries), hedges=int(hedges),
        hedge_cancels=int(hedge_cancels), hedge_wastes=int(hedge_wastes),
        breaker_sheds=int(breaker_sheds),
        throughput=completed / span_s, goodput=in_deadline / span_s,
        amplification=(attempts / offered) if offered else 1.0,
        deadline_miss_rate=(1.0 - in_deadline / offered) if offered else 0.0,
        accuracy_proxy_mean=accuracy_proxy_mean,
        breaker_timeline=list(breaker_timeline),
        degrade_timeline=list(degrade_timeline))


def goodput_timeline(completions, deadline_s: float,
                     window_s: float) -> list[tuple[float, float]]:
    """Tumbling-window goodput over ``(t, latency)`` completions.

    Returns ``(window_end_t, in_deadline_per_second)`` for every window
    from the first to the last completion — unlike
    :func:`windowed_percentile`, empty windows ARE emitted (as 0.0):
    during an outage zero goodput is the finding, not missing data.
    """
    if window_s <= 0:
        raise ValueError("window_s must be positive")
    if not completions:
        return []
    buckets: dict[int, int] = {}
    for t, lat in completions:
        buckets[int(t // window_s)] = (buckets.get(int(t // window_s), 0)
                                       + (1 if lat <= deadline_s else 0))
    lo, hi = min(buckets), max(buckets)
    return [((i + 1) * window_s, buckets.get(i, 0) / window_s)
            for i in range(lo, hi + 1)]


def recovery_report(samples, t_fault: float, t_restore: float,
                    window_s: float = 0.5, q: float = 0.99,
                    factor: float = 1.5,
                    depth_samples=None) -> RecoveryReport:
    """Window ``(t, latency)`` completions around a fault.

    ``samples``: completion stream; ``t_fault``/``t_restore``: model
    times of the outage and the repair; ``factor``: recovered means the
    windowed tail is back within ``factor * baseline``. Optional
    ``depth_samples`` ``(t, depth)`` adds backlog drain time.
    """
    if t_restore < t_fault:
        raise ValueError("t_restore must not precede t_fault")
    windows = windowed_percentile(samples, q, window_s)
    pre = [p for t, p, _ in windows if t <= t_fault]
    baseline = percentile(pre, q) if pre else 0.0
    post = [(t, p) for t, p, _ in windows if t > t_fault]
    spike = max((p for _, p in post), default=baseline)
    recovery = float("inf")
    for t, p in post:
        if t >= t_restore and p <= factor * max(baseline, 1e-12):
            recovery = max(0.0, t - t_restore)
            break
    drain = 0.0
    if depth_samples:
        pre_d = [d for t, d in depth_samples if t <= t_fault]
        floor = (sum(pre_d) / len(pre_d)) if pre_d else 0.0
        drain = float("inf")
        for t, d in depth_samples:
            if t >= t_restore and d <= max(floor, 1.0):
                drain = max(0.0, t - t_restore)
                break
    return RecoveryReport(baseline_p99=baseline, spike_p99=spike,
                          recovery_s=recovery, drain_s=drain,
                          windows=windows)
