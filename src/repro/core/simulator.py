"""Discrete-event simulation of the edge data center (paper §3-§6).

Entities: producer containers (ingest/detect) with a client send path,
Kafka-model brokers with storage write channels, a consumer pool
(identification) with fetch batching, and the event log. Compute times are
the paper's measured stage latencies divided by the AI-acceleration factor
S (the paper's emulation technique, §5.2) while payload sizes are
preserved — inverting their sleep-based emulation into a simulated clock.

The simulator exposes the quantities behind the paper's figures: stage
latency breakdown (Fig 6), latency/throughput vs S (Fig 10), broker
network/storage utilization (Fig 11), the producer-side "Delay" tax of
Object Detection (Fig 14), and the Fig 15 mitigations (drives, brokers,
thumbnail scaling).
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field

from repro.core.broker import (BrokerConfig, Message, Topic,
                               pick_victim, range_assignment)
from repro.core.events import EventLog


@dataclass
class FaceRecWorkload:
    """Calibrated from the paper's measurements (§4, Table 2)."""
    name: str = "face_recognition"
    t_ingest: float = 0.0188
    t_detect: float = 0.0748
    t_identify: float = 0.1315
    face_bytes: float = 37_300.0
    faces_per_frame: float = 1.0        # §5 emulation: exactly one
    face_dist: str = "fixed"            # fixed | empirical (0.64 avg, spiky)
    n_producers: int = 840
    n_consumers: int = 1680
    t_send: float = 0.0005              # producer client per-message cost
    accelerate_ingest: bool = True      # §5.2 emulates ingest/detect /S
    batch_per_tick: bool = False        # ObjectDet: S frames per fixed tick
    fps_cap: float | None = None
    ai_stages: tuple = ("detect", "identify")

    @property
    def frame_period(self) -> float:
        if self.fps_cap:
            return 1.0 / self.fps_cap
        return self.t_ingest + self.t_detect

    def sample_faces(self, rng: random.Random) -> int:
        if self.face_dist == "fixed":
            return max(1, round(self.faces_per_frame))
        # empirical-like: 0..5 faces/frame, mean ~0.64, occasional spikes
        r = rng.random()
        if r < 0.55:
            return 0
        if r < 0.80:
            return 1
        if r < 0.92:
            return 2
        return rng.choice([3, 4, 5])


def object_detection_workload() -> FaceRecWorkload:
    """Second application (paper §6): every frame is sent, 30 FPS cap,
    acceleration = more simultaneous streams per producer."""
    return FaceRecWorkload(
        name="object_detection",
        t_ingest=0.0045, t_detect=0.0, t_identify=0.687,
        face_bytes=120_000.0, faces_per_frame=1.0,
        n_producers=21, n_consumers=36 * 56,
        t_send=0.0023, accelerate_ingest=False, batch_per_tick=True,
        fps_cap=30.0, ai_stages=("identify",))


class _Channel:
    """FIFO bandwidth/latency server."""

    def __init__(self, rate: float | None = None):
        self.rate = rate
        self.free_at = 0.0
        self.busy = 0.0
        self.bytes = 0.0

    def submit_bytes(self, t: float, nbytes: float) -> float:
        start = max(t, self.free_at)
        dur = nbytes / self.rate
        self.free_at = start + dur
        self.busy += dur
        self.bytes += nbytes
        return self.free_at

    def submit_time(self, t: float, dur: float, nbytes: float = 0.0) -> float:
        start = max(t, self.free_at)
        self.free_at = start + dur
        self.busy += dur
        self.bytes += nbytes
        return self.free_at


@dataclass
class SimResult:
    workload: str
    speedup: float
    mean_latency: float
    p99_latency: float
    throughput: float
    waiting_mean: float
    waiting_share: float
    stage_means: dict
    unstable: bool
    broker_write_util: float
    broker_net_util: float
    producer_net_util: float
    consumer_net_util: float
    ingest_delay_mean: float = 0.0
    messages: int = 0
    p50_latency: float = 0.0
    p95_latency: float = 0.0
    backlog: int = 0
    unwritten: int = 0
    # measured-only instability: queue growth / producer lag observed in
    # THIS run, with no analytic-rho escape hatch. ``unstable`` keeps the
    # rho short-circuit (short sims near the knee may end before a
    # just-unstable queue visibly diverges); cross-validation against
    # the closed form must use ``diverged`` or the agreement would be
    # circular.
    diverged: bool = False
    # fault/elasticity accounting (dynamic-membership runs only)
    requeues: int = 0               # in-flight work re-enqueued by kills
    fault_events: int = 0           # fault-plan transitions applied
    scale_events: int = 0           # autoscaler actions applied
    final_consumers: int = 0        # alive consumers at sim end
    # reliability accounting (runs with retry/breaker/degrade policies):
    # the ReliabilityReport dict — goodput vs throughput, retry
    # amplification, deadline misses, breaker/degrade timelines
    reliability: dict | None = None

    def to_dict(self):
        return dict(self.__dict__)


class ClusterSim:
    """Event-driven simulation of the deployed application."""

    def __init__(self, wl: FaceRecWorkload, bk: BrokerConfig,
                 speedup: float = 1.0, scale: float = 0.05,
                 sim_time: float = 40.0, warmup: float = 8.0,
                 seed: int = 0, fault_plan=None, autoscale=None,
                 n_partitions: int | None = None, sample_dt: float = 0.25,
                 retry=None, breaker=None, degrade=None, trace=None):
        """``scale`` shrinks producer/consumer counts and broker bandwidth
        together, preserving utilizations and latencies while cutting the
        event count (840 producers -> 42 at scale=0.05).

        ``fault_plan`` (any object with ``.events`` of ``.t/.action/
        .target`` — duck-typed so core never imports the cluster
        package) and ``autoscale`` (an ``AutoscalerConfig``-shaped
        object with ``.interval_s`` and ``.controller()``) switch the
        run onto the dynamic-membership path: consumers become group
        members over ``n_partitions`` partitions (default: one per
        consumer) with range assignment, kills requeue in-flight work,
        and the controller adds/removes members live. Without either,
        the legacy static path runs byte-identically to before (the
        golden DES fixtures pin this).

        ``retry`` / ``breaker`` / ``degrade`` (RetryPolicy /
        BreakerConfig / DegradePolicy-shaped objects from
        ``repro.cluster.reliability``, duck-typed under the same
        layering rule) put the client reliability lifecycle into the
        simulation: attempt timeouts re-publish with jittered backoff,
        hedges duplicate stragglers, per-partition breakers shed toward
        healthy partitions, and the degradation ladder trades accuracy
        for service time under pressure. They require unique message
        keys (the default one-face-per-frame emulation) because the
        lifecycle dedupes by request id, and they force the dynamic
        path.

        ``trace`` (a ``WorkloadTrace``-shaped object with ``.events``
        of ``.t/.rid/.partition_key/.payload_bytes`` and
        ``.heartbeat_s`` — duck-typed under the same layering rule)
        replaces the producer tick process entirely: each trace event
        publishes one message at its timestamp (post-client wire
        arrival — no send cost, no linger, mirroring the live
        ``TraceReplayProducer``), keyed events pin partition
        ``key % n_partitions``, and a zero-duration ``heartbeat``
        marker is logged per ``heartbeat_s`` window. Trace runs force
        the dynamic path; without a trace nothing here changes."""
        self.wl = wl
        self.bk = bk
        self.S = speedup
        self.sim_time = sim_time
        self.warmup = warmup
        self.rng = random.Random(seed)
        self.n_prod = max(1, round(wl.n_producers * scale))
        self.n_cons = max(1, round(wl.n_consumers * scale))
        self.eff_scale = self.n_prod / wl.n_producers
        self.write_ch = [_Channel(bk.storage_write_capacity * self.eff_scale)
                         for _ in range(bk.n_brokers)]
        self.prod_ch = [_Channel() for _ in range(self.n_prod)]
        self.fault_plan = fault_plan
        self.autoscale = autoscale
        self.retry = retry
        self.breaker = breaker
        self.degrade = degrade
        self.trace = trace
        self.heartbeats: list = []              # (window, t) trace markers
        self.dynamic = (fault_plan is not None or autoscale is not None
                        or n_partitions is not None or retry is not None
                        or breaker is not None or degrade is not None
                        or trace is not None)
        self.n_partitions = n_partitions or self.n_cons
        self.sample_dt = sample_dt
        self.topic = Topic("faces", self.n_partitions, bk)
        self.log = EventLog()
        self.msgs: list[Message] = []
        self.ingest_delays: list[float] = []
        self._id = 0
        self._published = 0     # messages handed to a write channel
        # dynamic-path state (inert on the legacy path)
        self._stalled: set[int] = set()              # broker ids
        self._stall_buf: dict[int, list] = {}        # broker -> [(part, msg)]
        self.completions: list = []                  # (t_done, latency)
        self.depth_samples: list = []                # (t, backlog)
        self.requeues = 0
        self.fault_applied: list = []                # (t, action, victim)
        self.scale_actions: list = []
        self.generation = 0
        self._final_alive = self.n_cons
        # reliability state (inert unless retry/breaker/degrade are set)
        self._send = None                       # publish hook for _do_tick
        self._breakers: dict[int, object] = {}  # partition -> CircuitBreaker
        self._completed_map: dict[int, float] = {}   # rid -> t of first win
        self._rel_state: dict[int, dict] = {}        # rid -> attempts/t0
        self.rel_offered = 0
        self.rel_attempts = 0
        self.rel_retries = 0
        self.rel_hedges = 0
        self.rel_hedge_cancels = 0
        self.rel_hedge_wastes = 0
        self.rel_deadline_misses = 0
        self.rel_sheds = 0
        self._deg_depth = 0
        self.degrade_timeline: list = []             # (t, depth, level name)
        self._acc_sum = 0.0
        self._acc_n = 0

    # ---- run ---------------------------------------------------------------

    def run(self) -> SimResult:
        if self.dynamic:
            return self._run_dynamic()
        wl, S = self.wl, self.S
        heap: list = []
        seq = 0

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        period = (wl.frame_period if wl.batch_per_tick
                  else wl.frame_period / (S if wl.accelerate_ingest else 1))
        for p in range(self.n_prod):
            push(self.rng.random() * period, "tick",
                 {"producer": p, "scheduled": None})

        consumer_free = [0.0] * self.n_cons

        while heap:
            t, _, kind, pl = heapq.heappop(heap)
            if t > self.sim_time:
                break
            if kind == "tick":
                self._do_tick(t, pl, push, period)
            elif kind == "deliver":
                part, msg = pl["part"], pl["msg"]
                msg.t_written = t
                part.append(t, msg)
                push(t, "poll", {"ci": part.index})
            elif kind == "poll":
                ci = pl["ci"]
                part = self.topic.partitions[ci]
                if not part.backlog:
                    continue
                t_free = max(t, consumer_free[ci])
                ready = sum(m.size for _, m in part.backlog)
                oldest = part.backlog[0][0]
                if (ready < self.bk.fetch_min_bytes
                        and t_free - oldest < self.bk.fetch_max_wait_s - 1e-9):
                    # epsilon guards the float-ulp case where the deferred
                    # poll lands a hair before oldest+max_wait and would
                    # re-defer at the same timestamp forever
                    push(max(oldest + self.bk.fetch_max_wait_s, t_free) + 1e-9,
                         "poll", {"ci": ci})
                    continue
                batch, part.backlog = list(part.backlog), []
                t_busy = t_free
                for _, m in batch:
                    m.t_consumed = t_busy
                    dur = wl.t_identify / S
                    self.log.log(m.key, "wait", m.t_produced, m.t_consumed,
                                 int(m.size))
                    self.log.log(m.key, "identify", t_busy, t_busy + dur,
                                 int(m.size))
                    t_busy += dur
                    self.msgs.append(m)
                consumer_free[ci] = t_busy
        return self._result()

    # ---- dynamic membership (faults + elasticity) --------------------------

    def _run_dynamic(self) -> SimResult:
        """Event loop with live membership over the partition set.

        Consumers become group MEMBERS: ownership is the same
        ``range_assignment`` the live ``ConsumerGroup`` uses, recomputed
        whole on every membership change — the AsyncFlow O(1)-per-
        transition design, so the serve path below carries zero outage
        awareness (it just reads the current owner map). Service is
        event-driven (``done`` events carrying the member's epoch)
        instead of the legacy inline fast-forward, so a kill can fence
        not-yet-finished work with an epoch bump and requeue it for the
        new owner instead of dropping it.
        """
        from repro.core.metrics import percentile
        wl, S = self.wl, self.S
        heap: list = []
        seq = 0

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        period = (wl.frame_period if wl.batch_per_tick
                  else wl.frame_period / (S if wl.accelerate_ingest else 1))
        if self.trace is None:
            for p in range(self.n_prod):
                push(self.rng.random() * period, "tick",
                     {"producer": p, "scheduled": None})
        else:
            # trace replay owns the arrival process: events are pushed
            # lazily (each schedules its successor) so a long trace
            # never pre-loads the heap, and the heartbeat chain marks
            # the comparison grid in lock-step with the live replayer
            if self.trace.events:
                push(self.trace.events[0].t, "tev", {"i": 0})
            push(self.trace.heartbeat_s, "hb", {"k": 1})

        alive = set(range(self.n_cons))
        next_cid = self.n_cons
        consumer_free = {c: 0.0 for c in alive}
        epoch = {c: 0 for c in alive}
        # inflight entries are (pi, msg, accuracy_proxy) FIFO
        inflight: dict[int, list] = {c: [] for c in alive}
        owner: dict[int, int] = {}                          # partition -> member
        drives = {b: self.bk.drives_per_broker
                  for b in range(self.bk.n_brokers)}

        # ---- client reliability lifecycle (retry / hedge / breaker) ----
        retry, degrade = self.retry, self.degrade
        rel_on = retry is not None
        rel_active = (retry is not None or self.breaker is not None
                      or degrade is not None)
        # reliability runs poll bounded batches (the live replica's
        # fetch quantum) and re-poll: a member must not serialize an
        # outage-deep queue onto itself — after a revive the NEW owner
        # takes the remainder, exactly like the live sweep re-reading
        # ownership between batches. Plain dynamic runs keep the greedy
        # poll the golden fixtures pin.
        poll_cap = (max(1, int(self.bk.fetch_min_bytes
                               // max(wl.face_bytes, 1.0)))
                    if rel_active else None)
        if self.breaker is not None:
            self._breakers = {pi: self.breaker.make(pi)
                              for pi in range(self.n_partitions)}

        def pick_part_allowed(t):
            # one round-robin candidate per attempt: its breaker either
            # admits or the attempt is shed (and retried against the
            # NEXT partition after backoff). Scanning for any willing
            # partition instead would compound per-partition probe
            # rates into near-certain admission and defeat the breaker.
            part = self.topic.pick_partition()
            b = self._breakers.get(part.index)
            if b is None or b.allow(t):
                return part
            return None

        def rel_send(msg, push, origin="attempt", part=None):
            # publish one attempt (first / retry / hedge) through the
            # breaker-aware partition pick; schedules its own timeout
            # check, plus the request's deadline check and hedge on the
            # first attempt. A keyed trace arrival passes ``part``;
            # the pin sticks for the request's whole retry chain —
            # keyed traffic is partition-affine, so a retry must face
            # the SAME (possibly melted) partition's breaker rather
            # than rotate around the hot key.
            rid = msg.key
            st = self._rel_state.get(rid)
            if st is None:
                st = self._rel_state[rid] = {
                    "n": 0, "t0": msg.t_produced,
                    "pin": part.index if part is not None else None}
                self.rel_offered += 1
                if rel_on:
                    push(st["t0"] + retry.deadline_s, "dlcheck", {"rid": rid})
                    if retry.hedge_delay_s is not None:
                        push(msg.t_published + retry.hedge_delay_s, "hedge",
                             {"rid": rid, "size": msg.size})
            st["n"] += 1
            self.rel_attempts += 1
            retryable = rel_on and origin != "hedge"
            pin = st.get("pin")
            if pin is not None:
                cand = self.topic.partitions[pin]
                b = self._breakers.get(pin)
                part = cand if (b is None or b.allow(msg.t_published)) \
                    else None
            else:
                part = pick_part_allowed(msg.t_published)
            if part is None:
                self.rel_sheds += 1
                self.log.log(rid, "reject", msg.t_published, msg.t_published,
                             int(msg.size), reason="breaker_open")
                # a shed attempt fails instantly: back off and retry
                if retryable and retry.retry_allowed(
                        msg.t_published, st["t0"], st["n"]):
                    push(msg.t_published + retry.backoff_s(rid, st["n"]),
                         "republish", {"rid": rid, "size": msg.size})
                return
            self._route(msg, part, push)
            if rel_on:
                push(msg.t_published + retry.attempt_timeout_s, "rcheck",
                     {"rid": rid, "pi": part.index, "size": msg.size,
                      "retryable": retryable})

        if rel_on or self._breakers:
            self._send = rel_send

        def rebalance(t):
            self.generation += 1
            owner.clear()
            for m, parts in range_assignment(alive, self.n_partitions).items():
                for pi in parts:
                    owner[pi] = m
            for pi in range(self.n_partitions):
                push(t, "poll", {"pi": pi})

        def requeue_member(t, cid):
            # fence cid's scheduled completions, hand its in-flight work
            # back to the partitions — never dropped, so the five-way
            # attribution keeps summing to 1 through a fault
            epoch[cid] += 1
            for pi, m, _acc in reversed(inflight[cid]):
                self.topic.partitions[pi].backlog.insert(0, (t, m))
                self.log.log(m.key, "requeue", t, t, int(m.size))
                self.requeues += 1
            inflight[cid] = []

        def kill(t, rank):
            victim = pick_victim(alive, rank)
            if victim is not None:
                alive.discard(victim)
                requeue_member(t, victim)
                rebalance(t)
            return victim

        def revive(t):
            nonlocal next_cid
            cid = next_cid
            next_cid += 1
            alive.add(cid)
            consumer_free[cid] = t
            epoch[cid] = 0
            inflight[cid] = []
            rebalance(t)
            return cid

        def apply_fault(t, ev):
            act, tgt = ev.action, ev.target
            if act == "kill":
                self.fault_applied.append((t, act, kill(t, tgt)))
                return
            if act == "revive":
                self.fault_applied.append((t, act, revive(t)))
                return
            brokers = (range(self.bk.n_brokers) if tgt is None
                       else [tgt % self.bk.n_brokers])
            if act == "stall":
                self._stalled.update(brokers)
            elif act == "restore":
                for b in brokers:
                    self._stalled.discard(b)
                    # replay deferred writes at pacing from the repair
                    for part, msg in self._stall_buf.pop(b, []):
                        t_avail = self.write_ch[b].submit_bytes(
                            t, msg.size + self.bk.write_overhead_bytes)
                        push(t_avail, "deliver", {"part": part, "msg": msg})
            elif act in ("drive_drop", "drive_restore"):
                from dataclasses import replace
                delta = -1 if act == "drive_drop" else 1
                for b in brokers:
                    drives[b] = max(1, min(drives[b] + delta,
                                           self.bk.drives_per_broker))
                    cap = replace(self.bk, drives_per_broker=drives[b]
                                  ).storage_write_capacity
                    self.write_ch[b].rate = cap * self.eff_scale
            self.fault_applied.append((t, act, tgt))

        rebalance(0.0)
        for ev in (self.fault_plan.events if self.fault_plan else ()):
            push(ev.t, "fault", {"ev": ev})
        ctl = self.autoscale.controller() if self.autoscale else None
        if ctl is not None:
            push(self.autoscale.interval_s, "ctl", {})
        push(self.sample_dt, "sample", {})
        p99_idx = 0     # completions pointer for the recent-window tail

        def backlog_now():
            # undelivered + in-service + stall-deferred, matching the
            # live cluster's produced-minus-done signal the controller
            # is tuned on (LiveTopic.backlog counts writer inboxes too)
            return (sum(len(p.backlog) for p in self.topic.partitions)
                    + sum(len(q) for q in inflight.values())
                    + sum(len(b) for b in self._stall_buf.values()))

        while heap:
            t, _, kind, pl = heapq.heappop(heap)
            if t > self.sim_time:
                break
            if kind == "tick":
                self._do_tick(t, pl, push, period)
            elif kind == "tev":
                # one trace arrival: publish at its timestamp (wire
                # arrival — no send cost / linger, like the live
                # replayer), then schedule the next event lazily
                ev = self.trace.events[pl["i"]]
                msg = Message(key=ev.rid, size=float(ev.payload_bytes),
                              t_produced=t)
                msg.t_published = t
                self._published += 1
                part = (self.topic.partitions[
                    ev.partition_key % self.n_partitions]
                    if ev.partition_key is not None else None)
                if self._send is not None:
                    self._send(msg, push, part=part)
                else:
                    self._route(msg, part if part is not None
                                else self.topic.pick_partition(), push)
                if pl["i"] + 1 < len(self.trace.events):
                    push(self.trace.events[pl["i"] + 1].t, "tev",
                         {"i": pl["i"] + 1})
            elif kind == "hb":
                # heartbeat-window marker: the twin comparison grid
                self.heartbeats.append((pl["k"], t))
                self.log.log(-1, "heartbeat", t, t, window=pl["k"])
                t_next = (pl["k"] + 1) * self.trace.heartbeat_s
                if t_next <= self.sim_time + 1e-9:
                    push(t_next, "hb", {"k": pl["k"] + 1})
            elif kind == "deliver":
                part, msg = pl["part"], pl["msg"]
                msg.t_written = t
                part.append(t, msg)
                push(t, "poll", {"pi": part.index})
            elif kind == "poll":
                pi = pl["pi"]
                part = self.topic.partitions[pi]
                if not part.backlog:
                    continue
                ci = owner.get(pi)
                if ci is None:          # group empty; retry until revive
                    push(t + 10 * period, "poll", {"pi": pi})
                    continue
                t_free = max(t, consumer_free[ci])
                ready = sum(m.size for _, m in part.backlog)
                oldest = part.backlog[0][0]
                if (ready < self.bk.fetch_min_bytes
                        and t_free - oldest
                        < self.bk.fetch_max_wait_s - 1e-9):
                    push(max(oldest + self.bk.fetch_max_wait_s, t_free)
                         + 1e-9, "poll", {"pi": pi})
                    continue
                if poll_cap is None or len(part.backlog) <= poll_cap:
                    batch, part.backlog = list(part.backlog), []
                else:
                    batch = part.backlog[:poll_cap]
                    part.backlog = part.backlog[poll_cap:]
                if rel_on:
                    # request-id dedupe at dequeue: a duplicate whose
                    # twin already won is cancelled before costing any
                    # service time (the cheap hedge outcome)
                    fresh = []
                    for tt, m in batch:
                        if m.key in self._completed_map:
                            self.rel_hedge_cancels += 1
                            self.log.log(m.key, "hedge_cancel", t_free,
                                         t_free, int(m.size))
                        else:
                            fresh.append((tt, m))
                    batch = fresh
                    if not batch:
                        continue
                lvl = degrade.level(self._deg_depth) if degrade else None
                dur = wl.t_identify / S * (lvl.service_factor if lvl else 1.0)
                acc = lvl.accuracy_proxy if lvl else 1.0
                t_busy = t_free
                for _, m in batch:
                    m.t_consumed = t_busy
                    inflight[ci].append((pi, m, acc))
                    push(t_busy + dur, "done",
                         {"ci": ci, "epoch": epoch[ci], "t_start": t_busy})
                    t_busy += dur
                consumer_free[ci] = t_busy
                if part.backlog:
                    # bounded fetch left a remainder: re-poll when this
                    # member frees up (whoever owns the partition THEN
                    # takes it — the rebalance window)
                    push(t_busy, "poll", {"pi": pi})
            elif kind == "done":
                ci = pl["ci"]
                if pl["epoch"] != epoch.get(ci, -1) or not inflight[ci]:
                    continue            # fenced: member killed/shrunk away
                pi, m, acc = inflight[ci].pop(0)
                b = self._breakers.get(pi)
                if b is not None and not (
                        rel_on and t - m.t_published
                        > retry.attempt_timeout_s + 1e-12):
                    # a late completion is not a success signal: its
                    # rcheck already recorded the timeout as the outcome
                    b.record(t, True)
                if rel_on:
                    if m.key in self._completed_map:
                        # both attempts were in service at once: the
                        # loser's span is wasted work, not a completion
                        self.rel_hedge_wastes += 1
                        self.log.log(m.key, "hedge_waste", pl["t_start"], t,
                                     int(m.size))
                        continue
                    self._completed_map[m.key] = t
                self.log.log(m.key, "wait", m.t_produced, m.t_consumed,
                             int(m.size))
                self.log.log(m.key, "identify", pl["t_start"], t,
                             int(m.size))
                if acc < 1.0:
                    name = next((l.name for l in degrade.levels
                                 if l.accuracy_proxy == acc), "degraded")
                    self.log.log(m.key, "degrade", t, t, int(m.size),
                                 accuracy_proxy=acc, level=name)
                self._acc_sum += acc
                self._acc_n += 1
                self.msgs.append(m)
                self.completions.append((t, t - m.t_produced))
            elif kind == "fault":
                apply_fault(t, pl["ev"])
            elif kind == "rcheck":
                # attempt timeout: presumed lost -> breaker failure, and
                # (for the primary chain) a backed-off re-publish
                rid = pl["rid"]
                if rid in self._completed_map:
                    continue
                b = self._breakers.get(pl["pi"])
                if b is not None:
                    b.record(t, False)
                st = self._rel_state[rid]
                if (pl["retryable"]
                        and retry.retry_allowed(t, st["t0"], st["n"])):
                    push(t + retry.backoff_s(rid, st["n"]), "republish",
                         {"rid": rid, "size": pl["size"]})
            elif kind == "republish":
                rid = pl["rid"]
                if rid in self._completed_map:
                    continue
                self.rel_retries += 1
                self.log.log(rid, "retry", t, t, int(pl["size"]))
                m2 = Message(key=rid, size=pl["size"],
                             t_produced=self._rel_state[rid]["t0"])
                m2.t_published = t + self.bk.linger_s
                self._published += 1
                rel_send(m2, push, "retry")
            elif kind == "hedge":
                rid = pl["rid"]
                if rid in self._completed_map:
                    continue
                self.rel_hedges += 1
                self.log.log(rid, "hedge", t, t, int(pl["size"]))
                m2 = Message(key=rid, size=pl["size"],
                             t_produced=self._rel_state[rid]["t0"])
                m2.t_published = t + self.bk.linger_s
                self._published += 1
                rel_send(m2, push, "hedge")
            elif kind == "dlcheck":
                rid = pl["rid"]
                if rid not in self._completed_map:
                    self.rel_deadline_misses += 1
                    self.log.log(rid, "deadline_miss", t, t)
            elif kind == "sample":
                self.depth_samples.append((t, backlog_now()))
                if degrade is not None:
                    per = backlog_now() / max(len(alive), 1)
                    bs = list(self._breakers.values())
                    of = (sum(1 for b in bs if b.state != "closed")
                          / len(bs)) if bs else 0.0
                    nd = degrade.decide(per, of, self._deg_depth)
                    if nd != self._deg_depth:
                        self._deg_depth = nd
                        self.degrade_timeline.append(
                            (t, nd, degrade.level(nd).name))
                push(t + self.sample_dt, "sample", {})
            elif kind == "ctl":
                horizon = 4 * self.autoscale.interval_s
                while (p99_idx < len(self.completions)
                       and self.completions[p99_idx][0] < t - horizon):
                    p99_idx += 1
                recent = [lat for _, lat in self.completions[p99_idx:]]
                p99 = percentile(recent, 0.99) if recent else None
                delta = ctl.decide(t, backlog_now(), len(alive), p99)
                for _ in range(delta):
                    revive(t)
                for _ in range(-delta):
                    if len(alive) > 1:
                        # shrink the newest member, kill-style: fence +
                        # requeue so scale-down loses no in-flight work
                        kill(t, len(alive) - 1)
                push(t + self.autoscale.interval_s, "ctl", {})

        if ctl is not None:
            self.scale_actions = list(ctl.actions)
        self._final_alive = len(alive)
        return self._result()

    def _do_tick(self, t, pl, push, period):
        wl, S = self.wl, self.S
        p = pl["producer"]
        ch = self.prod_ch[p]
        sched = pl.get("scheduled")
        n_frames = max(1, round(S)) if wl.batch_per_tick else 1
        div = S if wl.accelerate_ingest else 1.0
        t_ing = wl.t_ingest / div
        t_det = wl.t_detect / div
        if wl.batch_per_tick:
            # ObjectDet: a frame SET must finish its sends before the next
            # set starts — the client send path is the §6.3 "Delay" tax.
            start = max(t, ch.free_at)
            t_busy = ch.submit_time(start, t_ing)
        else:
            # FaceRec: stages are pipelined — the tick rate carries the
            # throughput; only the client send cost rides the channel.
            start = t
            t_busy = start + t_ing + t_det
        if sched is not None:
            self.ingest_delays.append(max(0.0, start - sched))
        for _ in range(n_frames):
            rid = self._id
            self._id += 1
            self.log.log(rid, "ingest", start, start + t_ing)
            if wl.t_detect:
                self.log.log(rid, "detect", start + t_ing, start + t_ing + t_det)
            for _ in range(wl.sample_faces(self.rng)):
                # client send path (per-message cost), then linger, then
                # the leader broker's storage write channel
                t_sent = ch.submit_time(t_busy, wl.t_send, wl.face_bytes)
                self._published += 1
                msg = Message(key=rid, size=wl.face_bytes, t_produced=t_busy)
                msg.t_published = t_sent + self.bk.linger_s
                if self._send is not None:
                    # reliability lifecycle owns partition choice and
                    # timeout/hedge scheduling for this attempt
                    self._send(msg, push)
                    continue
                self._route(msg, self.topic.pick_partition(), push)
        push(t + period, "tick", {"producer": p, "scheduled": t + period})

    def _route(self, msg, part, push):
        """Hand one message to its leader's write channel (or the stall
        buffer while the fault engine has that broker down — the legacy
        path never populates ``_stalled``, so never defers)."""
        if part.leader in self._stalled:
            self._stall_buf.setdefault(part.leader, []).append((part, msg))
            return
        wch = self.write_ch[part.leader]
        t_avail = wch.submit_bytes(
            msg.t_published, msg.size + self.bk.write_overhead_bytes)
        push(t_avail, "deliver", {"part": part, "msg": msg})

    # ---- results -----------------------------------------------------------

    def _result(self) -> SimResult:
        wl, S = self.wl, self.S
        div = S if wl.accelerate_ingest else 1.0
        msgs = [m for m in self.msgs if m.t_produced >= self.warmup]
        span = max(self.sim_time - self.warmup, 1e-9)
        delays = self.ingest_delays or [0.0]
        d_mean = sum(delays) / len(delays)
        if self.trace is not None:
            # trace replay measures latency per completion (arrival ->
            # done); the frame-period reconstruction below assumes the
            # tick process and would misprice a recorded arrival shape
            lat = sorted(l for tt, l in self.completions
                         if tt - l >= self.warmup)
        else:
            lat = sorted((wl.frame_period / div) + m.broker_wait
                         + wl.t_identify / S + d_mean for m in msgs)
        mean_lat = sum(lat) / len(lat) if lat else float("inf")

        # shared nearest-rank convention (repro.core.metrics), so the
        # DES and live-cluster tails overlay under one definition
        from repro.core.metrics import percentile

        def pct(q: float) -> float:
            return percentile(lat, q) if lat else float("inf")

        p50, p95, p99 = pct(0.50), pct(0.95), pct(0.99)
        backlog = sum(len(p.backlog) for p in self.topic.partitions)
        # a saturated write channel accumulates its queue as deliveries
        # scheduled past sim_time: published-but-never-written messages
        # are backlog too, or storage saturation would be invisible to
        # the measured signal (consumed + partition backlog both stall).
        # Deduped duplicates and shed attempts were published but can
        # never complete — they are amplification, not backlog.
        dups = (self.rel_hedge_cancels + self.rel_hedge_wastes
                + self.rel_sheds)
        unwritten = self._published - len(self.msgs) - backlog - dups
        diverged = ((backlog + unwritten) > 0.08 * max(self._published, 1)
                    or d_mean > 5 * wl.frame_period)
        # instability = measured divergence OR analytic rho >= 1 (a short
        # sim can end before a just-unstable queue visibly diverges)
        if self.trace is None:
            from repro.core.queueing import utilizations
            rho_max = max(u.rho
                          for u in utilizations(wl, self.bk, S).values())
        else:
            # the analytic rho prices the tick process; a trace's
            # offered load is whatever it recorded, so only measured
            # divergence can call a trace run unstable
            rho_max = 0.0
        unstable = (backlog > 0.15 * max(len(self.msgs), 1)
                    or d_mean > 5 * wl.frame_period
                    or rho_max >= 0.995)
        waits = [m.broker_wait for m in msgs]
        waits_m = sum(waits) / len(waits) if waits else float("inf")
        share = (waits_m / mean_lat) if lat and mean_lat > 0 else 1.0
        # utilization vs NOMINAL drive bandwidth (how the paper reports it)
        nominal = (self.bk.drives_per_broker * self.bk.drive_write_bw
                   * self.eff_scale)
        util = (sum(c.bytes for c in self.write_ch)
                / (len(self.write_ch) * nominal * self.sim_time))
        raw = sum(c.bytes for c in self.write_ch) / self.sim_time
        nic = self.bk.net_bw * self.eff_scale
        return SimResult(
            workload=wl.name, speedup=S,
            mean_latency=(float("inf") if unstable else mean_lat),
            p99_latency=(float("inf") if unstable else p99),
            throughput=len(msgs) / span,
            waiting_mean=waits_m, waiting_share=share,
            stage_means=self.log.breakdown(), unstable=unstable,
            broker_write_util=min(util, 1.0 / self._drive_eff()),
            broker_net_util=raw / (len(self.write_ch) * nic),
            producer_net_util=raw / (self.n_prod * nic),
            consumer_net_util=raw / (self.n_cons * nic),
            ingest_delay_mean=d_mean, messages=len(msgs),
            p50_latency=(float("inf") if unstable else p50),
            p95_latency=(float("inf") if unstable else p95),
            backlog=backlog, unwritten=unwritten, diverged=diverged,
            requeues=self.requeues, fault_events=len(self.fault_applied),
            scale_events=len(self.scale_actions),
            final_consumers=self._final_alive,
            reliability=self._reliability_dict())

    def _reliability_dict(self) -> dict | None:
        if (self.retry is None and self.breaker is None
                and self.degrade is None):
            return None
        from repro.core.metrics import reliability_report
        timeline = sorted((tt, pi, s)
                          for pi, b in sorted(self._breakers.items())
                          for tt, s in b.timeline)
        # without a retry policy every publish is its own sole attempt
        offered = (self.rel_offered if self.retry is not None
                   else self._published)
        attempts = (self.rel_attempts if self.retry is not None
                    else self._published)
        deadline = (self.retry.deadline_s if self.retry is not None
                    else float("inf"))
        return reliability_report(
            self.completions, deadline, max(self.sim_time, 1e-9),
            offered=offered, attempts=attempts,
            deadline_misses=self.rel_deadline_misses,
            retries=self.rel_retries, hedges=self.rel_hedges,
            hedge_cancels=self.rel_hedge_cancels,
            hedge_wastes=self.rel_hedge_wastes,
            breaker_sheds=self.rel_sheds,
            accuracy_proxy_mean=(self._acc_sum / self._acc_n
                                 if self._acc_n else 1.0),
            breaker_timeline=timeline,
            degrade_timeline=self.degrade_timeline).to_dict()

    def _drive_eff(self) -> float:
        d = self.bk.drives_per_broker
        return self.bk.drive_efficiency[min(d, len(self.bk.drive_efficiency)) - 1]


def sweep_acceleration(wl: FaceRecWorkload, bk: BrokerConfig,
                       speedups=(1, 2, 4, 6, 8), **kw) -> list[SimResult]:
    return [ClusterSim(wl, bk, speedup=s, **kw).run() for s in speedups]
