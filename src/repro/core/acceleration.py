"""Acceleration analytics (paper §5.1): Amdahl limits per stage and the
emulated-acceleration transform applied to measured stage profiles."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class StageProfile:
    """CPU-time split of one pipeline stage (paper Fig 8)."""
    name: str
    ai_fraction: float          # fraction of cycles in AI kernels

    def amdahl_speedup(self, s: float) -> float:
        """Overall stage speedup when ONLY the AI part runs s x faster."""
        f = self.ai_fraction
        return 1.0 / ((1.0 - f) + f / s)

    @property
    def asymptote(self) -> float:
        return 1.0 / (1.0 - self.ai_fraction) if self.ai_fraction < 1 else float("inf")


# paper Fig 8 measurements
INGESTION = StageProfile("ingestion", 0.0)
DETECTION = StageProfile("detection", 0.42)
IDENTIFICATION = StageProfile("identification", 0.88)

# paper §4.3: end-to-end compute-cycle split of Face Recognition
E2E_AI_FRACTION = 0.552
E2E_TAX = {
    "ai": 0.552, "resizing": 0.178, "networking": 0.090,
    "tensor_prep": 0.052, "kafka": 0.036, "other": 0.092,
}


def amdahl_curve(profile: StageProfile, speedups) -> list[tuple[float, float]]:
    return [(s, profile.amdahl_speedup(s)) for s in speedups]


def emulated_times(t_measured: dict[str, float], s: float,
                   ai_only: bool = False,
                   profiles: dict[str, StageProfile] | None = None
                   ) -> dict[str, float]:
    """The paper's §5.2 emulation: stage times / s.

    With ``ai_only=True``, apply Amdahl per stage instead (only the AI
    portion accelerates — §5.1's analytical view)."""
    out = {}
    for stage, t in t_measured.items():
        if ai_only and profiles and stage in profiles:
            out[stage] = t / profiles[stage].amdahl_speedup(s)
        else:
            out[stage] = t / s
    return out
