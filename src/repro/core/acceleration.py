"""Acceleration analytics (paper §5.1): Amdahl limits per stage and the
emulated-acceleration transform applied to measured stage profiles."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class StageProfile:
    """CPU-time split of one pipeline stage (paper Fig 8)."""
    name: str
    ai_fraction: float          # fraction of cycles in AI kernels

    def amdahl_speedup(self, s: float) -> float:
        """Overall stage speedup when ONLY the AI part runs s x faster."""
        f = self.ai_fraction
        return 1.0 / ((1.0 - f) + f / s)

    @property
    def asymptote(self) -> float:
        return 1.0 / (1.0 - self.ai_fraction) if self.ai_fraction < 1 else float("inf")


# paper Fig 8 measurements
INGESTION = StageProfile("ingestion", 0.0)
DETECTION = StageProfile("detection", 0.42)
IDENTIFICATION = StageProfile("identification", 0.88)

# paper §4.3: end-to-end compute-cycle split of Face Recognition
E2E_AI_FRACTION = 0.552
E2E_TAX = {
    "ai": 0.552, "resizing": 0.178, "networking": 0.090,
    "tensor_prep": 0.052, "kafka": 0.036, "other": 0.092,
}


def amdahl_curve(profile: StageProfile, speedups) -> list[tuple[float, float]]:
    return [(s, profile.amdahl_speedup(s)) for s in speedups]


def residual_tax_fraction(profile: StageProfile, s: float) -> float:
    """Fraction of the REMAINING time that is tax after the AI part runs
    s× faster — the paper's central quantity: accelerating the AI makes
    the supporting work dominate. At s→∞ this →1 for any profile with
    ai_fraction < 1."""
    f = profile.ai_fraction
    denom = (1.0 - f) + f / s
    return (1.0 - f) / denom if denom else 0.0


def roofline_sweep(profile: StageProfile, speedups
                   ) -> list[tuple[float, float, float]]:
    """(s, overall Amdahl speedup, residual tax fraction) per point.

    ``profile`` may come from the paper's measured constants OR from a
    measured roofline (``Roofline.stage_profile()`` /
    :func:`profile_from_roofline`) — the latter is what
    ``benchmarks/fig_roofline_sweep.py`` feeds in, replacing the paper
    constants with this container's calibrated cost model."""
    return [(s, profile.amdahl_speedup(s), residual_tax_fraction(profile, s))
            for s in speedups]


def profile_from_roofline(name: str, t_compute: float, t_memory: float,
                          t_collective: float = 0.0) -> StageProfile:
    """A measured Amdahl profile from roofline terms: the compute term is
    the accelerable "AI" share; memory + collective terms are the
    infrastructure tax an accelerator does not shrink."""
    tot = t_compute + t_memory + t_collective
    return StageProfile(name, t_compute / tot if tot else 0.0)


def emulated_times(t_measured: dict[str, float], s: float,
                   ai_only: bool = False,
                   profiles: dict[str, StageProfile] | None = None
                   ) -> dict[str, float]:
    """The paper's §5.2 emulation: stage times / s.

    With ``ai_only=True``, apply Amdahl per stage instead (only the AI
    portion accelerates — §5.1's analytical view)."""
    out = {}
    for stage, t in t_measured.items():
        if ai_only and profiles and stage in profiles:
            out[stage] = t / profiles[stage].amdahl_speedup(s)
        else:
            out[stage] = t / s
    return out
