"""Micro-batching over broker topics (the paper's batching lever, §5.5).

The paper shows that once the AI stages are accelerated, the win comes
from amortizing per-item overheads — but batching also *creates* tax:
items wait in the topic for the batch to fill, and that wait is exactly
the broker/queueing time Fig 6 shows dominating. ``Batcher`` makes the
trade explicit and measurable: it drains a ``queue.Queue`` (the
in-process stand-in for a Kafka partition) into batches bounded by a
max size AND a max linger — the same (batch.size, linger.ms) pair a
Kafka consumer/producer exposes.

Consumers log each item's queue wait individually (the Batcher never
touches the EventLog), so per-request AI-tax accounting survives
batching; see docs/ai_tax_accounting.md.

One Batcher per consumer thread: stop-sentinel handling is stateful
(a partial batch is flushed before the iterator ends), so sharing one
across threads would swallow peers' sentinels.
"""
from __future__ import annotations

import queue
import time
from dataclasses import dataclass, fields


@dataclass
class BatchStats:
    """Why batches flushed — the observable knob/latency trade."""
    n_batches: int = 0
    n_items: int = 0
    flush_size: int = 0      # batch filled to batch_size
    flush_timeout: int = 0   # linger expired with a partial batch
    flush_stop: int = 0      # stop sentinel ended a partial batch
    flush_drain: int = 0     # non-blocking poll emptied the queue

    @property
    def mean_batch_size(self) -> float:
        return self.n_items / self.n_batches if self.n_batches else 0.0

    def merge(self, other: "BatchStats") -> "BatchStats":
        return BatchStats(*(getattr(self, f.name) + getattr(other, f.name)
                            for f in fields(self)))

    def _count(self, batch_len: int, reason: str) -> None:
        # one Batcher (and its stats) per consumer thread by contract;
        # cross-thread totals go through the associative merge() only
        self.n_batches += 1  # lint: waive race-check -- per-consumer-thread stats object; aggregation uses merge()
        self.n_items += batch_len  # lint: waive race-check -- per-consumer-thread stats object; aggregation uses merge()
        setattr(self, f"flush_{reason}", getattr(self, f"flush_{reason}") + 1)


class Batcher:
    """Size/timeout-bounded batches, pull- or push-fed.

    Pull (consumer threads): iterate, or call ``next_batch``. Blocks
    for the first item of each batch (idle consumers cost nothing),
    then lingers at most ``timeout_s`` past that first item while
    filling up to ``batch_size``. A ``stop`` sentinel ends iteration
    (required for it); a partial batch in flight is flushed first.

    ``poll`` is the non-blocking pull variant for callers with their
    own scheduling loop (e.g. serving-engine admission).

    Push (in-process producers with no consumer thread, e.g. the fused
    ingest->detect stage): ``push`` each item — it returns a batch
    when the size or linger bound trips — and ``flush`` at end of
    stream. One flush policy, either way.
    """

    def __init__(self, source: queue.Queue | None = None, *,
                 batch_size: int = 8, timeout_s: float = 0.005,
                 stop: object = None):
        self.source = source
        self.batch_size = max(1, batch_size)
        self.timeout_s = timeout_s
        self.stop = stop
        self.stats = BatchStats()
        self._stopped = False
        self._pending: list = []      # push-side partial batch
        self._deadline = 0.0

    # ---- push interface ---------------------------------------------------

    def push(self, item) -> list | None:
        """Add one item; returns a batch to process when a bound trips.

        The linger is checked at push time (there is no thread to wake
        on a timer), so the effective bound is timeout_s plus one
        inter-push gap.
        """
        if not self._pending:
            self._deadline = time.perf_counter() + self.timeout_s
        self._pending.append(item)
        full = len(self._pending) == self.batch_size
        if full or time.perf_counter() >= self._deadline:
            batch, self._pending = self._pending, []
            self.stats._count(len(batch), "size" if full else "timeout")
            return batch
        return None

    def flush(self) -> list | None:
        """End of stream: hand back any partial push()ed batch."""
        if not self._pending:
            return None
        batch, self._pending = self._pending, []
        self.stats._count(len(batch), "stop")
        return batch

    # ---- pull interface ---------------------------------------------------

    def next_batch(self, max_wait: float | None = None) -> list | None:
        """One batch, or None once the stop sentinel has been consumed.

        ``max_wait`` bounds the blocking wait for the batch's FIRST
        item; when it expires with nothing queued the call returns an
        empty list (distinct from the ``None`` end-of-stream signal).
        Cluster replicas poll several partition queues from one thread,
        so an idle partition must hand control back instead of parking
        the consumer forever.
        """
        if self.source is None:
            raise ValueError("pull interface needs a source queue; "
                             "this Batcher is push-fed")
        if self._stopped:
            return None
        try:
            first = self.source.get(timeout=max_wait)
        except queue.Empty:
            return []
        if self.stop is not None and first is self.stop:
            self._stopped = True  # lint: waive race-check -- monotonic stop latch; flips one way, any observer order is safe
            return None
        batch = [first]
        deadline = time.perf_counter() + self.timeout_s
        reason = "size"
        while len(batch) < self.batch_size:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                reason = "timeout"
                break
            try:
                item = self.source.get(timeout=remaining)
            except queue.Empty:
                reason = "timeout"
                break
            if self.stop is not None and item is self.stop:
                self._stopped = True  # lint: waive race-check -- monotonic stop latch; flips one way, any observer order is safe
                reason = "stop"
                break
            batch.append(item)
        self.stats._count(len(batch), reason)
        return batch

    def poll(self, max_items: int | None = None) -> list:
        """Non-blocking drain of up to max_items (default batch_size)."""
        if self.source is None:
            raise ValueError("pull interface needs a source queue; "
                             "this Batcher is push-fed")
        limit = self.batch_size if max_items is None else max_items
        batch: list = []
        while len(batch) < limit and not self._stopped:
            try:
                item = self.source.get_nowait()
            except queue.Empty:
                break
            if self.stop is not None and item is self.stop:
                self._stopped = True  # lint: waive race-check -- monotonic stop latch; flips one way, any observer order is safe
                break
            batch.append(item)
        if batch:
            # "size" only when the batch genuinely filled; a drain cut
            # short by the caller's limit or an empty queue is "drain"
            self.stats._count(len(batch), "size" if len(batch) ==
                              self.batch_size else "drain")
        return batch

    def __iter__(self):
        if self.stop is None:
            raise ValueError("iterating a Batcher needs a stop sentinel "
                             "(nothing could ever end the loop); use "
                             "poll() or push() for sentinel-free feeds")
        return iter(self.next_batch, None)
