"""TCO model: homogeneous vs purpose-built edge data center (paper §7).

Reproduces Tables 3 and 4 item-for-item, plus the power/cooling model and
3-year amortization, yielding the paper's headline: the purpose-built,
AI-tax-aware design supports 32x accelerated AI at ~16.6% lower TCO.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Item:
    name: str
    unit_price: float
    quantity: int

    @property
    def cost(self) -> float:
        return self.unit_price * self.quantity


@dataclass
class DataCenterDesign:
    name: str
    items: tuple
    server_count: int
    switch_count: int
    server_watts: float = 750.0
    switch_watts: float = 398.0          # Mellanox SN2700 max
    cooling_overhead: float = 1.0        # cooling ~= IT power (paper cites)
    kwh_price: float = 0.10
    amortization_years: float = 3.0

    @property
    def equipment_cost(self) -> float:
        return sum(i.cost for i in self.items)

    @property
    def power_kw(self) -> float:
        it = (self.server_count * self.server_watts
              + self.switch_count * self.switch_watts) / 1000.0
        return it * (1.0 + self.cooling_overhead)

    @property
    def yearly_power_cost(self) -> float:
        return self.power_kw * self.kwh_price * 24 * 365

    @property
    def yearly_tco(self) -> float:
        return (self.equipment_cost / self.amortization_years
                + self.yearly_power_cost)


def homogeneous_design(n_nodes: int = 1024,
                       drives_per_node: int = 1) -> DataCenterDesign:
    """Table 3: every node identical (plus optional extra NVMe per node,
    the 'maintain homogeneity' option for 32x support — +US$1.23M)."""
    n_switches = 160
    items = (
        Item("Dell PowerEdge R740xd (base server, 2x Xeon 8176, 384GB)",
             28_731, n_nodes),
        Item("Intel SSD DC P4510 1TB", 399, n_nodes * drives_per_node),
        Item("Mellanox MCX415A 100GbE adapter", 660, n_nodes),
        Item("Mellanox MSN2700-CS2F 100GbE switch", 17_285, n_switches),
        Item("Mellanox MCP1600 100GbE cable", 100, 3 * n_nodes),
    )
    return DataCenterDesign("homogeneous", items, n_nodes, n_switches)


def purpose_built_design() -> DataCenterDesign:
    """Table 4: 867 compute nodes (10GbE, no NVMe) + 157 broker nodes
    (cheap CPUs, 4x NVMe, 50GbE) + tiered fat-tree of 28x100GbE +
    14x40GbE switches with splitter cables."""
    items = (
        Item("Dell PowerEdge R740xd (compute, 2x Xeon 8176)", 28_731, 867),
        Item("Mellanox MCX411A 10GbE adapter", 180, 867),
        Item("Dell PowerEdge R740xd (broker, 2x Xeon Bronze 3104)", 11_016, 157),
        Item("Mellanox MCX413A 50GbE adapter", 395, 157),
        Item("Intel SSD DC P4510 1TB (4 per broker)", 399, 157 * 4),
        Item("Mellanox MSN2700-CS2F 100GbE switch", 17_285, 28),
        Item("Mellanox MSN2700-BS2F 40GbE switch", 10_635, 14),
        Item("Mellanox MFA7A20-C010 optical splitter 100->2x50", 1_165, 7),
        Item("Mellanox MC2609130-003 copper splitter 40->4x10", 90, 217),
        Item("Mellanox MCP7H00-G002R copper splitter 100->2x50", 140, 79),
        Item("Mellanox MFA1A00-C030 optical 100GbE interconnect", 515, 192),
    )
    return DataCenterDesign("purpose_built", items, 867 + 157, 28 + 14)


@dataclass
class TCOComparison:
    homogeneous: DataCenterDesign
    purpose_built: DataCenterDesign

    @property
    def saving_fraction(self) -> float:
        h, p = self.homogeneous.yearly_tco, self.purpose_built.yearly_tco
        return (h - p) / h

    def summary(self) -> dict:
        def row(d: DataCenterDesign) -> dict:
            return {"equipment": d.equipment_cost,
                    "yearly_power": d.yearly_power_cost,
                    "power_kw": d.power_kw,
                    "yearly_tco": d.yearly_tco}
        return {"homogeneous": row(self.homogeneous),
                "purpose_built": row(self.purpose_built),
                "tco_saving_fraction": self.saving_fraction}


def paper_comparison(support_32x: bool = True) -> TCOComparison:
    """The paper's comparison: homogeneous needs 4 drives/node (or 2.7x
    brokers) to survive 32x acceleration; purpose-built handles it by
    design."""
    return TCOComparison(
        homogeneous=homogeneous_design(drives_per_node=4 if support_32x else 1),
        purpose_built=purpose_built_design())


def provision_drives(target_speedup: float,
                     knee_by_drives: dict[int, float],
                     tolerance: float = 0.0) -> int:
    """Smallest drives/node whose MEASURED knee supports the target S.

    ``knee_by_drives`` maps drive count -> destabilization S observed by
    an executed run (DES sweep or the live cluster,
    ``repro.cluster.crossval``) — not a paper constant. ``tolerance``
    admits a knee within that relative margin below the target
    (measured knees carry finite bisection resolution; the paper's
    "4 drives supports 32x" sits exactly ON the modeled knee, so a
    resolution-sized margin is part of reading the measurement).
    Raises if no measured configuration reaches the target, rather
    than silently under-provisioning.
    """
    floor = target_speedup * (1.0 - tolerance)
    ok = [d for d, knee in sorted(knee_by_drives.items()) if knee >= floor]
    if not ok:
        raise ValueError(
            f"no measured configuration sustains S={target_speedup}: "
            f"{knee_by_drives}")
    return ok[0]


def measured_comparison(target_speedup: float,
                        knee_by_drives: dict[int, float],
                        n_nodes: int = 1024,
                        tolerance: float = 0.0) -> TCOComparison:
    """Tables 3/4 driven by executed measurements.

    The homogeneous design's per-node drive count is chosen by
    :func:`provision_drives` from measured knees instead of the paper's
    "4 drives for 32x" constant; the purpose-built design already
    carries 4 drives per broker node by construction. When the
    measurements agree with the paper (they do — see
    ``benchmarks/fig_cluster_scaling.py``) this reproduces
    ``paper_comparison`` from first principles.
    """
    d = provision_drives(target_speedup, knee_by_drives, tolerance)
    return TCOComparison(
        homogeneous=homogeneous_design(n_nodes=n_nodes, drives_per_node=d),
        purpose_built=purpose_built_design())
