"""Event-level instrumentation (the paper's measurement technique, §4.1).

Application progress is a sequence of "events" — high-level steps a request
passes through (ingestion, detection, broker wait, identification...). Each
event records wall-time span, payload size and metadata; aggregation
produces the paper's Fig-6-style latency breakdowns and Fig-8-style cycle
breakdowns without perturbing the application (logging is O(1) appends).
"""
from __future__ import annotations

import json
import math
import time
from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class Event:
    request_id: int
    stage: str
    t_start: float
    t_end: float
    payload_bytes: int = 0
    meta: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


class EventLog:
    """Append-only event store + aggregations."""

    def __init__(self):
        self.events: list[Event] = []

    def log(self, request_id: int, stage: str, t_start: float, t_end: float,
            payload_bytes: int = 0, **meta) -> Event:
        ev = Event(request_id, stage, t_start, t_end, payload_bytes, meta)
        self.events.append(ev)
        return ev

    def log_transfer(self, request_id: int, direction: str, nbytes: int,
                     boundary: str, t_start: float | None = None,
                     t_end: float | None = None,
                     stage: str = "transfer") -> Event:
        """A host<->device boundary crossing (the paper's transfer tax).

        ``direction`` is ``"h2d"`` or ``"d2h"``; ``boundary`` names the
        crossing (e.g. ``"crop_resize"``, ``"identify_fused"``) so
        per-boundary byte accounting survives aggregation. Transfers
        that happen inside a jitted program aren't separately timeable
        — callers may log them as zero-duration point events; the bytes
        are the quantity of record (`transfer_bytes()`), while timed
        crossings (e.g. TaxedStep's explicit device_put/get) carry real
        spans and show up in the time split too.
        """
        t0 = time.perf_counter() if t_start is None else t_start
        return self.log(request_id, stage, t0, t0 if t_end is None else t_end,
                        payload_bytes=nbytes, kind="transfer",
                        direction=direction, boundary=boundary)

    def transfer_bytes(self, boundary: str | None = None) -> dict[str, int]:
        """Total transferred bytes by direction (optionally one boundary)."""
        out = {"h2d": 0, "d2h": 0}
        for ev in self.events:
            if ev.meta.get("kind") != "transfer":
                continue
            if boundary is not None and ev.meta.get("boundary") != boundary:
                continue
            out[ev.meta.get("direction", "h2d")] = \
                out.get(ev.meta.get("direction", "h2d"), 0) + ev.payload_bytes
        out["total"] = sum(out.values())
        return out

    # ---- aggregations -----------------------------------------------------

    def stage_latencies(self) -> dict[str, list[float]]:
        out: dict[str, list[float]] = defaultdict(list)
        for ev in self.events:
            out[ev.stage].append(ev.duration)
        return dict(out)

    def breakdown(self, percentile: float | None = None) -> dict[str, float]:
        """Mean (or percentile) latency per stage."""
        out = {}
        for stage, ds in self.stage_latencies().items():
            ds = sorted(ds)
            if percentile is None:
                out[stage] = sum(ds) / len(ds)
            else:
                out[stage] = ds[min(len(ds) - 1,
                                    int(math.ceil(percentile * len(ds))) - 1)]
        return out

    def end_to_end(self, stages: list[str] | None = None) -> list[float]:
        """Per-request total latency (first start -> last end)."""
        spans: dict[int, list[Event]] = defaultdict(list)
        for ev in self.events:
            if stages is None or ev.stage in stages:
                spans[ev.request_id].append(ev)
        return [max(e.t_end for e in evs) - min(e.t_start for e in evs)
                for evs in spans.values() if evs]

    def tail(self, q: float = 0.99) -> float:
        return self.percentiles((q,))[q]

    def percentiles(self, qs=(0.5, 0.95, 0.99),
                    stages: list[str] | None = None) -> dict[float, float]:
        """Per-request e2e latency percentiles (tail-SLO quantities).

        Delegates to :func:`repro.core.metrics.percentile` so EventLog
        tails and LatencyStats can never drift onto different
        conventions.
        """
        from repro.core.metrics import percentile
        e2e = self.end_to_end(stages)
        return {q: percentile(e2e, q) for q in qs}

    def mean_e2e(self) -> float:
        e2e = self.end_to_end()
        return sum(e2e) / len(e2e) if e2e else 0.0

    def ai_tax(self, ai_stages: set[str]) -> dict[str, float]:
        """Fraction of total time in AI vs supporting stages (the AI tax).

        The tax side is further split: stages whose events carry
        ``kind="transfer"`` meta (host<->device crossings) are reported
        as ``transfer_fraction`` (a subset of ``tax_fraction``), and
        the boundary bytes they moved as ``transfer_bytes`` — so the
        breakdown reads AI vs pre/post-processing vs data movement.
        """
        by_stage = self.breakdown()
        transfer_set = {ev.stage for ev in self.events
                        if ev.meta.get("kind") == "transfer"}
        ai = sum(v for s, v in by_stage.items() if s in ai_stages)
        transfer = sum(v for s, v in by_stage.items() if s in transfer_set)
        total = sum(by_stage.values())
        return {"ai_fraction": ai / total if total else 0.0,
                "tax_fraction": 1.0 - (ai / total if total else 0.0),
                "transfer_fraction": transfer / total if total else 0.0,
                "transfer_bytes": self.transfer_bytes(),
                "total_latency": total,
                "per_stage": by_stage}

    def throughput(self) -> float:
        """Completed requests per second over the observed span."""
        if not self.events:
            return 0.0
        t0 = min(e.t_start for e in self.events)
        t1 = max(e.t_end for e in self.events)
        n = len({e.request_id for e in self.events})
        return n / (t1 - t0) if t1 > t0 else 0.0

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps({
                    "request_id": ev.request_id, "stage": ev.stage,
                    "t_start": ev.t_start, "t_end": ev.t_end,
                    "payload_bytes": ev.payload_bytes, **ev.meta}) + "\n")


class Timer:
    """Context manager that logs an event on exit (live pipelines)."""

    def __init__(self, log: EventLog, request_id: int, stage: str,
                 payload_bytes: int = 0, clock=time.perf_counter, **meta):
        self.log, self.request_id, self.stage = log, request_id, stage
        self.payload_bytes, self.meta, self.clock = payload_bytes, meta, clock

    def __enter__(self):
        self.t0 = self.clock()
        return self

    def __exit__(self, *exc):
        self.log.log(self.request_id, self.stage, self.t0, self.clock(),
                     self.payload_bytes, **self.meta)
        return False
