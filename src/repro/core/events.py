"""Event-level instrumentation (the paper's measurement technique, §4.1).

Application progress is a sequence of "events" — high-level steps a request
passes through (ingestion, detection, broker wait, identification...). Each
event records wall-time span, payload size and metadata; aggregation
produces the paper's Fig-6-style latency breakdowns and Fig-8-style cycle
breakdowns without perturbing the application (logging is O(1) appends).
"""
from __future__ import annotations

import json
import math
import time
from collections import defaultdict
from dataclasses import dataclass, field


FIVE_WAY = ("pre", "ai", "post", "transfer", "queue")

# Canonical stage -> bucket table: THE single source of truth for the
# five-way attribution. Every categorizer in the repo resolves through
# :func:`categorize` (``facerec.stage_category``,
# ``taxmeter.taxed_stage_category``, the preprocess stage's log guard),
# and the tax-stage static lint (``repro.analysis``) parses this very
# assignment — a stage name that is neither listed here nor matched by
# the prefix/suffix conventions below cannot silently leak into the
# residual "pre" bucket.
STAGE_CATEGORIES = {
    "ingest": "pre",
    "detect": "ai", "identify": "ai",
    "prefill": "ai", "decode": "ai",        # serving-engine AI stages
    "wait": "queue", "wait_frames": "queue", "reject": "queue",
    "requeue": "queue",   # fault rebalance: in-flight work re-enqueued
    # reliability layer (retry/hedge/deadline lifecycle): duplicated or
    # abandoned attempts are time the request spent fighting the
    # infrastructure, not being processed — queue tax. ``degrade`` marks
    # a request served in a reduced-accuracy mode; the saved work was
    # post-processing (NMS re-rank / resolution), so the marker lands in
    # the post bucket.
    "retry": "queue", "hedge": "queue", "hedge_cancel": "queue",
    "hedge_waste": "queue", "deadline_miss": "queue",
    "degrade": "post",
    # trace replay: zero-duration window marker on the trace's time
    # axis (the digital-twin comparison grid); contributes no time, the
    # bucket only keeps the canonical-table lint airtight
    "heartbeat": "queue",
    "transfer": "transfer",
}

# prefix-typed stages (the preprocess stage self-classifies its spans)
STAGE_PREFIXES = {"pre_": "pre", "post_": "post"}

# suffix-typed stages (TaxedStep's ``<name>/<phase>`` convention)
STAGE_SUFFIXES = {"/pre": "pre", "/post": "post", "/compute": "ai",
                  "/h2d": "transfer", "/d2h": "transfer",
                  "/wait": "queue"}


def categorize(stage: str, default: str | None = "pre") -> str | None:
    """Canonical stage name -> {pre, ai, post, transfer, queue}.

    Resolution order: exact :data:`STAGE_CATEGORIES` entry, then the
    suffix convention (TaxedStep's ``<name>/<phase>``), then the prefix
    convention (``pre_*``/``post_*``), then any stage containing
    ``wait`` lands in ``queue``. Anything else gets ``default`` — the
    paper's residual-tax convention is ``"pre"`` (work around the AI
    that isn't a queue or a crossing is pre/post-processing); pass
    ``default=None`` to get ``None`` back instead, which is how the
    tax-stage lint detects stage names that do not resolve through the
    canonical table at all.
    """
    if stage in STAGE_CATEGORIES:
        return STAGE_CATEGORIES[stage]
    for suffix, cat in STAGE_SUFFIXES.items():
        if stage.endswith(suffix):
            return cat
    for prefix, cat in STAGE_PREFIXES.items():
        if stage.startswith(prefix):
            return cat
    if "wait" in stage:
        return "queue"
    return default


def five_way_fractions(per_stage: dict[str, float], category_of,
                       ) -> dict[str, float]:
    """Attribute a per-stage time breakdown into the five tax buckets.

    ``category_of`` maps a stage name to one of :data:`FIVE_WAY`
    (e.g. :func:`repro.core.facerec.stage_category`, or
    :func:`repro.core.taxmeter.taxed_stage_category` for TaxedStep
    logs). Every stage lands in exactly one bucket, so the returned
    fractions sum to 1 whenever any time was recorded — the paper's
    "every microsecond is somebody's tax" discipline. Shared by the
    live pipeline, the DES breakdown (``fig06``) and the TaxedStep
    harness, so the figures and the runtime can never drift onto
    different stage lists.
    """
    totals = dict.fromkeys(FIVE_WAY, 0.0)
    for stage, t in per_stage.items():
        cat = category_of(stage)
        if cat not in totals:
            raise ValueError(f"category {cat!r} for stage {stage!r} not in "
                             f"{FIVE_WAY}")
        totals[cat] += t
    grand = sum(totals.values())
    if not grand:
        return totals
    return {k: v / grand for k, v in totals.items()}


@dataclass
class Event:
    request_id: int
    stage: str
    t_start: float
    t_end: float
    payload_bytes: int = 0
    meta: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


class EventLog:
    """Append-only event store + aggregations."""

    def __init__(self):
        self.events: list[Event] = []

    def log(self, request_id: int, stage: str, t_start: float, t_end: float,
            payload_bytes: int = 0, **meta) -> Event:
        ev = Event(request_id, stage, t_start, t_end, payload_bytes, meta)
        self.events.append(ev)
        return ev

    def log_transfer(self, request_id: int, direction: str, nbytes: int,
                     boundary: str, t_start: float | None = None,
                     t_end: float | None = None,
                     stage: str = "transfer") -> Event:
        """A host<->device boundary crossing (the paper's transfer tax).

        ``direction`` is ``"h2d"`` or ``"d2h"``; ``boundary`` names the
        crossing (e.g. ``"crop_resize"``, ``"identify_fused"``) so
        per-boundary byte accounting survives aggregation. Transfers
        that happen inside a jitted program aren't separately timeable
        — callers may log them as zero-duration point events; the bytes
        are the quantity of record (`transfer_bytes()`), while timed
        crossings (e.g. TaxedStep's explicit device_put/get) carry real
        spans and show up in the time split too.
        """
        t0 = time.perf_counter() if t_start is None else t_start
        return self.log(request_id, stage, t0, t0 if t_end is None else t_end,
                        payload_bytes=nbytes, kind="transfer",
                        direction=direction, boundary=boundary)

    def log_batch_span(self, rids, stage: str, t_start: float, t_end: float,
                       payload_bytes: int = 0, split_payload: bool = False,
                       **meta) -> None:
        """Amortize one batched span into per-request events.

        The batch's wall span is partitioned into ``len(rids)`` equal
        slices (``duration = span / B``), each tagged
        ``batch_size=B`` — the discipline docs/ai_tax_accounting.md
        describes, shared by the pipeline's AI stages, the preprocess
        stage, and the benchmarks. ``payload_bytes`` is per-item by
        default; with ``split_payload`` it is a batch total, divided
        across items with the remainder on the first so the batch sum
        stays exact.
        """
        B = max(len(rids), 1)
        dt = (t_end - t_start) / B
        for i, rid in enumerate(rids):
            per = (payload_bytes // B + (payload_bytes % B if i == 0 else 0)
                   if split_payload else payload_bytes)
            self.log(rid, stage, t_start + i * dt, t_start + (i + 1) * dt,
                     payload_bytes=per, batch_size=B, **meta)

    def log_batch_transfers(self, rids, boundary: str, h2d: int, d2h: int,
                            t: float | None = None) -> None:
        """Per-item transfer events for one batched boundary crossing.

        The batch's boundary bytes (padding included — padded rows
        cross too) are split across its items, remainder on the first,
        so per-request accounting and batch totals both stay exact.
        Shared by the streaming pipeline's AI stages and the
        preprocess stage's device placement.
        """
        t = time.perf_counter() if t is None else t
        B = max(len(rids), 1)
        for j, rid in enumerate(rids):
            extra_up, extra_dn = (h2d % B, d2h % B) if j == 0 else (0, 0)
            self.log_transfer(rid, "h2d", h2d // B + extra_up, boundary, t)
            self.log_transfer(rid, "d2h", d2h // B + extra_dn, boundary, t)

    def transfer_bytes(self, boundary: str | None = None) -> dict[str, int]:
        """Total transferred bytes by direction (optionally one boundary)."""
        out = {"h2d": 0, "d2h": 0}
        for ev in self.events:
            if ev.meta.get("kind") != "transfer":
                continue
            if boundary is not None and ev.meta.get("boundary") != boundary:
                continue
            out[ev.meta.get("direction", "h2d")] = \
                out.get(ev.meta.get("direction", "h2d"), 0) + ev.payload_bytes
        out["total"] = sum(out.values())
        return out

    # ---- aggregations -----------------------------------------------------

    def stage_latencies(self) -> dict[str, list[float]]:
        out: dict[str, list[float]] = defaultdict(list)
        for ev in self.events:
            out[ev.stage].append(ev.duration)
        return dict(out)

    def breakdown(self, percentile: float | None = None) -> dict[str, float]:
        """Mean (or percentile) latency per stage."""
        out = {}
        for stage, ds in self.stage_latencies().items():
            ds = sorted(ds)
            if percentile is None:
                out[stage] = sum(ds) / len(ds)
            else:
                out[stage] = ds[min(len(ds) - 1,
                                    int(math.ceil(percentile * len(ds))) - 1)]
        return out

    def end_to_end(self, stages: list[str] | None = None) -> list[float]:
        """Per-request total latency (first start -> last end)."""
        spans: dict[int, list[Event]] = defaultdict(list)
        for ev in self.events:
            if stages is None or ev.stage in stages:
                spans[ev.request_id].append(ev)
        return [max(e.t_end for e in evs) - min(e.t_start for e in evs)
                for evs in spans.values() if evs]

    def tail(self, q: float = 0.99) -> float:
        return self.percentiles((q,))[q]

    def percentiles(self, qs=(0.5, 0.95, 0.99),
                    stages: list[str] | None = None) -> dict[float, float]:
        """Per-request e2e latency percentiles (tail-SLO quantities).

        Delegates to :func:`repro.core.metrics.percentile` so EventLog
        tails and LatencyStats can never drift onto different
        conventions.
        """
        from repro.core.metrics import percentile
        e2e = self.end_to_end(stages)
        return {q: percentile(e2e, q) for q in qs}

    def mean_e2e(self) -> float:
        e2e = self.end_to_end()
        return sum(e2e) / len(e2e) if e2e else 0.0

    def _kind_aware(self, category_of):
        """Wrap a stage->bucket map with the authoritative-kind rule:
        stages whose events carry ``kind="transfer"`` meta are forced
        into the ``transfer`` bucket regardless of name."""
        transfer_set = {ev.stage for ev in self.events
                        if ev.meta.get("kind") == "transfer"}
        return lambda s: "transfer" if s in transfer_set else category_of(s)

    def five_way(self, category_of) -> dict[str, float]:
        """Five-way mean-latency attribution: {pre, ai, post, transfer,
        queue}, summing to 1 (see :func:`five_way_fractions`)."""
        return five_way_fractions(self.breakdown(),
                                  self._kind_aware(category_of))

    def five_way_seconds(self, category_of) -> dict[str, float]:
        """Total busy seconds per five-way bucket (sums, not means).

        The same attribution as :meth:`five_way` over summed event
        durations — what the offload benchmarks scale under emulated
        acceleration. One implementation of the kind-override rule for
        both aggregations, so they cannot drift.
        """
        cat = self._kind_aware(category_of)
        out = dict.fromkeys(FIVE_WAY, 0.0)
        for ev in self.events:
            out[cat(ev.stage)] += ev.duration
        return out

    def windowed_five_way(self, category_of, window_s: float,
                          fractions: bool = True) -> dict[int, dict]:
        """Per-tumbling-window five-way attribution, keyed by window.

        Events land in window ``int(t_end // window_s)`` (the heartbeat
        grid the digital-twin comparison runs on — same t=0 alignment
        as ``metrics.windowed_percentile``). With ``fractions=True``
        each window's buckets sum to 1 when any time was recorded
        (all-zero otherwise, e.g. a window holding only zero-duration
        markers); with ``fractions=False`` raw busy seconds per bucket
        are returned — what the flash-crowd signature check thresholds.
        """
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        cat = self._kind_aware(category_of)
        acc: dict[int, dict] = {}
        for ev in self.events:
            d = acc.setdefault(int(ev.t_end // window_s),
                               dict.fromkeys(FIVE_WAY, 0.0))
            d[cat(ev.stage)] += ev.duration
        if not fractions:
            return dict(sorted(acc.items()))
        out = {}
        for w, d in sorted(acc.items()):
            grand = sum(d.values())
            out[w] = ({k: v / grand for k, v in d.items()} if grand
                      else d)
        return out

    def ai_tax(self, ai_stages: set[str],
               category_of=None) -> dict[str, float]:
        """Fraction of total time in AI vs supporting stages (the AI tax).

        The tax side is further split: stages whose events carry
        ``kind="transfer"`` meta (host<->device crossings) are reported
        as ``transfer_fraction`` (a subset of ``tax_fraction``), and
        the boundary bytes they moved as ``transfer_bytes`` — so the
        breakdown reads AI vs pre/post-processing vs data movement.

        With ``category_of`` (a stage-name -> :data:`FIVE_WAY` bucket
        map), the report gains the full five-way attribution:
        ``fractions`` (summing to 1) plus ``pre_fraction`` /
        ``post_fraction`` — the pre/post-processing tax split the
        offload benchmarks sweep.
        """
        by_stage = self.breakdown()
        transfer_set = {ev.stage for ev in self.events
                        if ev.meta.get("kind") == "transfer"}
        ai = sum(v for s, v in by_stage.items() if s in ai_stages)
        transfer = sum(v for s, v in by_stage.items() if s in transfer_set)
        total = sum(by_stage.values())
        out = {"ai_fraction": ai / total if total else 0.0,
               "tax_fraction": 1.0 - (ai / total if total else 0.0),
               "transfer_fraction": transfer / total if total else 0.0,
               "transfer_bytes": self.transfer_bytes(),
               "total_latency": total,
               "per_stage": by_stage}
        if category_of is not None:
            fr = self.five_way(category_of)
            out["fractions"] = fr
            out["pre_fraction"] = fr["pre"]
            out["post_fraction"] = fr["post"]
        return out

    def throughput(self) -> float:
        """Completed requests per second over the observed span."""
        if not self.events:
            return 0.0
        t0 = min(e.t_start for e in self.events)
        t1 = max(e.t_end for e in self.events)
        n = len({e.request_id for e in self.events})
        return n / (t1 - t0) if t1 > t0 else 0.0

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps({
                    "request_id": ev.request_id, "stage": ev.stage,
                    "t_start": ev.t_start, "t_end": ev.t_end,
                    "payload_bytes": ev.payload_bytes, **ev.meta}) + "\n")


class Timer:
    """Context manager that logs an event on exit (live pipelines)."""

    def __init__(self, log: EventLog, request_id: int, stage: str,
                 payload_bytes: int = 0, clock=time.perf_counter, **meta):
        self.log, self.request_id, self.stage = log, request_id, stage
        self.payload_bytes, self.meta, self.clock = payload_bytes, meta, clock

    def __enter__(self):
        self.t0 = self.clock()
        return self

    def __exit__(self, *exc):
        self.log.log(self.request_id, self.stage, self.t0, self.clock(),
                     self.payload_bytes, **self.meta)
        return False
