"""Token data pipeline: deterministic, seekable, shardable.

A real deployment streams tokenized shards from blob storage; here the
source is a deterministic PRNG mixture (n-gram-ish structure so tiny LMs
can actually learn), but the *pipeline* properties are production-grade:
  * seekable by step (restart replay — the trainer seeks after restore);
  * per-host sharding (each host materializes only its batch rows);
  * next-token labels produced by the loader, not the model.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


class SyntheticLM:
    """Deterministic structured token stream: a random Markov chain."""

    def __init__(self, vocab_size: int, seed: int = 0, order_states: int = 64):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.n_states = order_states
        # sparse-ish transition: each state strongly prefers a few tokens
        probs = rng.dirichlet(np.full(min(vocab_size, 32), 0.3),
                              size=order_states)
        toks = rng.integers(0, vocab_size,
                            size=(order_states, probs.shape[1]))
        self.state_tokens = toks
        self.state_probs = probs / probs.sum(-1, keepdims=True)
        self.state_next = rng.integers(0, order_states,
                                       size=(order_states, probs.shape[1]))

    def sequence(self, seq_len: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        s = int(rng.integers(self.n_states))
        out = np.empty(seq_len + 1, np.int32)
        for i in range(seq_len + 1):
            j = rng.choice(self.state_probs.shape[1], p=self.state_probs[s])
            out[i] = self.state_tokens[s, j]
            s = self.state_next[s, j]
        return out


class TokenLoader:
    """Seekable batch loader with host-sharded materialization."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 sharding=None):
        assert batch % host_count == 0
        self.src = SyntheticLM(vocab_size, seed)
        self.batch = batch
        self.local_batch = batch // host_count
        self.seq_len = seq_len
        self.host_index = host_index
        self.host_count = host_count
        self.sharding = sharding
        self._step = 0

    def seek(self, step: int) -> None:
        self._step = step

    def next_batch(self) -> dict:
        rows = []
        base = self._step * self.batch + self.host_index * self.local_batch
        for r in range(self.local_batch):
            rows.append(self.src.sequence(self.seq_len, seed=base + r))
        self._step += 1
        arr = np.stack(rows)
        tokens = jnp.asarray(arr[:, :-1])
        labels = jnp.asarray(arr[:, 1:])
        if self.sharding is not None:
            tokens = jax.device_put(tokens, self.sharding)
            labels = jax.device_put(labels, self.sharding)
        return {"tokens": tokens, "labels": labels}
