"""Synthetic video stream for the Face Recognition example.

Generates frames with a known number of rendered "faces" (bright gaussian
blobs) at known positions, so the live pipeline's detector can be
validated end-to-end (found boxes vs ground truth) without any real video
assets. Frame statistics mirror the paper: 1920x1080 source resized to
960x540 for detection, 0-5 faces per frame averaging ~0.64.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Frame:
    index: int
    pixels: np.ndarray          # (H, W, 3) uint8
    true_boxes: list            # [(y, x, size), ...]


class VideoStream:
    def __init__(self, height: int = 216, width: int = 384,
                 avg_faces: float = 0.64, seed: int = 0):
        """Default resolution is a 5x-reduced stand-in for 1080p so the
        CPU example runs fast; ratios match the paper's pipeline."""
        self.h, self.w = height, width
        self.avg = avg_faces
        self.rng = np.random.default_rng(seed)
        self._i = 0

    def _render_face(self, img, y, x, size):
        yy, xx = np.mgrid[0:self.h, 0:self.w]
        blob = np.exp(-(((yy - y) / size) ** 2 + ((xx - x) / size) ** 2))
        img += (blob[..., None] * np.array([220.0, 180.0, 150.0]))

    def next_frame(self) -> Frame:
        img = self.rng.normal(30.0, 8.0, (self.h, self.w, 3))
        # face-count distribution: mean ~0.64, spiky (0..5)
        r = self.rng.random()
        n = 0 if r < 0.55 else 1 if r < 0.80 else 2 if r < 0.92 \
            else int(self.rng.integers(3, 6))
        boxes = []
        for _ in range(n):
            size = float(self.rng.uniform(8, 16))
            y = float(self.rng.uniform(2 * size, self.h - 2 * size))
            x = float(self.rng.uniform(2 * size, self.w - 2 * size))
            self._render_face(img, y, x, size)
            boxes.append((y, x, size))
        f = Frame(self._i, np.clip(img, 0, 255).astype(np.uint8), boxes)
        self._i += 1
        return f
