"""Open- and closed-loop load generators for the serving cluster.

Open loop models the paper's deployment: cameras tick at a fixed frame
period regardless of downstream health, so offered load is insensitive
to latency and an under-provisioned cluster diverges — this is the mode
the stability knee is measured in. Arrivals are periodic (the paper's
emulation) or Poisson (rate-matched, for tail studies).

Closed loop models K clients that wait for each response before
submitting again (plus think time): offered load self-throttles, the
system cannot diverge, and throughput saturates at capacity instead —
the contrast the tail-latency docs discuss.

Every random choice flows from one seeded ``random.Random`` per
producer/client (seed derived deterministically from the generator
seed and the index) — no module-level RNG anywhere, so schedules are
reproducible run to run.
"""
from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass


def _rng(seed: int, stream: int, salt: str = "") -> random.Random:
    """Distinct, deterministic stream per (salt, seed, stream).

    ``salt`` partitions the stream space per generator kind (open-loop
    producers, closed-loop clients, the diurnal profile, each scenario
    builder). Without it any two generators handed the same
    ``(seed, stream)`` pair share one underlying sequence — exactly the
    coupling the seeding-audit test pins down: ``diurnal_profile``'s
    jitter used to ride producer 0's stream, so a diurnal experiment
    silently correlated its rate noise with one producer's phase.
    The unsalted legacy formula remains for callers that pass no salt.
    """
    if salt:
        digest = hashlib.sha256(f"{salt}:{seed}:{stream}".encode()).digest()
        return random.Random(int.from_bytes(digest[:8], "big"))
    return random.Random((seed * 1_000_003 + stream) & 0x7FFFFFFF)


def rng_fingerprint(seed: int, stream: int, salt: str = "",
                    k: int = 8) -> tuple:
    """First ``k`` draws of a stream — the audit's identity check.

    Two streams are treated as the SAME underlying sequence iff their
    fingerprints collide; the seeding-audit test asserts pairwise
    distinctness across every (generator kind x producer index x
    scenario) combination the library can instantiate.
    """
    rng = _rng(seed, stream, salt)
    return tuple(rng.random() for _ in range(k))


@dataclass
class OpenLoopLoadGen:
    """Per-producer arrival schedules at a fixed mean period.

    ``period_s`` is the mean inter-arrival time in MODEL seconds
    (``frame_period / S`` for the accelerated FaceRec producer).
    """
    n_producers: int
    period_s: float
    process: str = "periodic"          # periodic | poisson
    seed: int = 0

    def __post_init__(self):
        if self.process not in ("periodic", "poisson"):
            raise ValueError(f"unknown arrival process: {self.process}")

    def schedule(self, producer: int, horizon_s: float) -> list[float]:
        """Absolute arrival times in [0, horizon_s) for one producer.

        Deterministic in (seed, producer): periodic producers get a
        seeded phase offset (like the DES's randomized first tick),
        Poisson producers exponential gaps.
        """
        rng = _rng(self.seed, producer, "open-loop")
        out: list[float] = []
        t = rng.random() * self.period_s
        while t < horizon_s:
            out.append(t)
            if self.process == "periodic":
                t += self.period_s
            else:
                t += rng.expovariate(1.0 / self.period_s)
        return out

    @property
    def offered_rate(self) -> float:
        """Aggregate arrivals/s (model time)."""
        return self.n_producers / self.period_s


def diurnal_profile(horizon_s: float, base_rate: float, peak_rate: float,
                    period_s: float, seed: int = 0,
                    dt: float | None = None) -> list[tuple[float, float]]:
    """Seeded diurnal offered-load trace: ``(t, rate)`` samples.

    One sinusoidal day–night cycle per ``period_s`` between
    ``base_rate`` (trough) and ``peak_rate`` (peak), plus ±5% seeded
    jitter per sample — the golden trace the autoscaler's
    scale-down-never-violates-SLO test replays through the fluid-queue
    harness. Deterministic in its arguments (one ``random.Random``,
    no module RNG), like every generator in this module.
    """
    import math
    if peak_rate < base_rate:
        raise ValueError("peak_rate must be >= base_rate")
    rng = _rng(seed, 0, "diurnal-profile")
    dt = period_s / 48 if dt is None else dt
    mid = 0.5 * (base_rate + peak_rate)
    amp = 0.5 * (peak_rate - base_rate)
    out: list[tuple[float, float]] = []
    t = 0.0
    while t < horizon_s:
        rate = mid - amp * math.cos(2 * math.pi * t / period_s)
        rate *= 1.0 + 0.05 * (2 * rng.random() - 1)
        out.append((t, max(0.0, rate)))
        t += dt
    return out


@dataclass
class ClosedLoopLoadGen:
    """K clients, each: submit -> await completion -> think -> repeat.

    ``think_s`` is the mean think time in model seconds (exponential
    when ``process="poisson"``, fixed otherwise). Offered load adapts
    to latency, so the cluster saturates instead of diverging.
    """
    n_clients: int
    think_s: float = 0.0
    process: str = "periodic"
    seed: int = 0

    def think_sampler(self, client: int):
        """Seeded think-time sampler for one client."""
        rng = _rng(self.seed, client, "closed-loop")

        def sample() -> float:
            if self.think_s <= 0:
                return 0.0
            if self.process == "poisson":
                return rng.expovariate(1.0 / self.think_s)
            return self.think_s
        return sample
