"""Multi-replica serving cluster runtime (the live measured system).

``ServingCluster`` runs the paper's deployment shape as real threads on
a real clock: open- or closed-loop producers publish face messages into
a ``LiveTopic`` whose broker write channels are paced at the modeled
storage capacity, and N replica consumers — partition-aware members of
a ``ConsumerGroup`` — drain their assigned partitions through the same
``Batcher`` the streaming pipeline uses, then serve each message with
the identification stage.

Two service modes:
  * ``service="paced"`` — the identify span is the workload's measured
    constant divided by the AI-acceleration factor S (the paper's
    sleep-based emulation, §5.2). Every demand/capacity ratio matches
    the DES and the closed-form queueing model, so the S at which the
    live cluster destabilizes is directly cross-validatable
    (``repro.cluster.crossval``).
  * ``service="real"`` — messages carry codec-encoded crops (planar
    YUV, the wire format) and the replica runs the SAME stack as
    ``StreamingPipeline`` (``facerec.build_identify_stack``): decode
    through the stack's preprocess stage — ``ClusterSpec.placement``
    moves that decode between host NumPy and the device program —
    then the device-resident fused identify. Real compute, real
    host<->device boundary, hardware-dependent latency.

Time compression: all modeled durations are divided by
``time_compression`` so a 6-model-second experiment takes ~1.5 wall
seconds; results are reported back in model seconds. Demand/capacity
ratios — and therefore the knee — are invariant under this scaling.

Everything is logged through one ``EventLog`` (model-time stamps):
``wait`` (partition queue time), ``identify`` (service), ``reject``
(admission drops), so ``ClusterResult.ai_tax()`` splits AI vs
tax exactly like the single-replica pipeline.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field, replace

from repro.core.batching import Batcher, BatchStats
from repro.core.broker import BrokerConfig, Message
from repro.core.events import EventLog
from repro.core.queueing import stability_knee, utilizations
from repro.core.simulator import ClusterSim, FaceRecWorkload
from repro.cluster.loadgen import ClosedLoopLoadGen, OpenLoopLoadGen
from repro.cluster.metrics import LatencyStats, SLOReport, TailSLO
from repro.cluster.scheduler import ConsumerGroup
from repro.cluster.topic import LiveTopic


@dataclass
class ClusterSpec:
    """One deployment configuration, shared by all three models.

    The spec is the single source of truth for the cross-validation:
    ``closed_form_knee`` prices it analytically, ``des_sim`` builds the
    equivalent discrete-event simulation, and ``ServingCluster`` runs
    it live. ``n_producers`` scales the full workload down
    (``eff = n_producers / wl.n_producers``) and the broker bandwidth
    with it, preserving utilizations — the same trick as
    ``ClusterSim(scale=...)``.
    """
    wl: FaceRecWorkload = field(default_factory=FaceRecWorkload)
    bk: BrokerConfig = field(default_factory=BrokerConfig)
    n_replicas: int = 8
    n_producers: int = 4
    n_partitions: int | None = None      # default: one per replica
    speedup: float = 1.0
    time_compression: float = 4.0
    sim_time: float = 6.0                # model seconds
    warmup: float = 1.5
    seed: int = 0
    service: str = "paced"               # paced | real
    arrival: str = "periodic"            # periodic | poisson
    loop: str = "open"                   # open | closed
    n_clients: int = 8                   # closed loop population
    think_s: float = 0.0                 # closed loop think time (model s)
    admission: str = "none"              # none | drop | block
    partition_capacity: int = 64         # in-flight bound for drop/block
    fetch_max_wait_s: float | None = None   # default: bk.fetch_max_wait_s
    placement: str = "host"              # real mode: where the replica's
    #                                      crop decode runs (host|device)
    fault_plan: object = None            # FaultPlan; one timeline drives
    #                                      BOTH engines (live + DES)
    autoscale: object = None             # AutoscalerConfig; elastic
    #                                      replica count in both engines
    retry: object = None                 # RetryPolicy; deadline + retry +
    #                                      hedge lifecycle in both engines
    breaker: object = None               # BreakerConfig; per-partition
    #                                      circuit breakers in both engines
    degrade: object = None               # DegradePolicy; graceful quality
    #                                      ladder in both engines
    trace: object = None                 # WorkloadTrace; ONE recorded
    #                                      arrival timeline replayed by
    #                                      BOTH engines (replaces loadgen)
    scenario: str | None = None          # library scenario name; resolved
    #                                      to a trace at sim_time horizon
    trace_speed: float = 1.0             # replay speed factor (the trace
    #                                      is rescaled for both engines)

    @property
    def eff(self) -> float:
        return self.n_producers / self.wl.n_producers

    @property
    def partitions(self) -> int:
        return self.n_partitions or self.n_replicas

    @property
    def period_s(self) -> float:
        """Per-producer inter-arrival time at this S (model seconds)."""
        div = self.speedup if self.wl.accelerate_ingest else 1.0
        return self.wl.frame_period / div

    def scaled_broker(self) -> BrokerConfig:
        return self.bk.scaled(self.eff)

    def scaled_workload(self) -> FaceRecWorkload:
        return replace(self.wl, n_producers=self.n_producers,
                       n_consumers=self.n_replicas)

    def closed_form_knee(self) -> float:
        return stability_knee(self.scaled_workload(), self.scaled_broker())

    def predicted_rho(self) -> dict[str, float]:
        us = utilizations(self.scaled_workload(), self.scaled_broker(),
                          self.speedup)
        return {name: u.rho for name, u in us.items()}

    def resolve_trace(self):
        """The replay-ready trace both engines consume, or ``None``.

        An explicit ``trace`` wins; otherwise a ``scenario`` name is
        built at the spec's own horizon and seed (deterministic, so
        repeated resolution yields hash-identical traces). The
        ``trace_speed`` rescale is applied HERE, once, so the live
        replayer and the DES see the identical compressed timeline.
        """
        tr = self.trace
        if tr is None and self.scenario is not None:
            from repro.cluster.scenarios import build_trace
            tr = build_trace(self.scenario, horizon_s=self.sim_time,
                             seed=self.seed)
        if tr is None or self.trace_speed == 1.0:
            return tr
        return tr.rescale(self.trace_speed)

    def des_sim(self, speedup: float | None = None, *, sim_time: float = 20.0,
                warmup: float = 4.0, seed: int | None = None) -> ClusterSim:
        """The equivalent DES run (scale pre-applied, so scale=1).

        A spec with a ``fault_plan``, ``autoscale``, explicit
        ``n_partitions``, or a ``trace``/``scenario`` hands them to the
        DES (duck-typed — ``repro.core`` never imports the cluster
        package), switching it onto the dynamic-membership path so both
        engines replay one timeline over one topology. Default specs
        keep the legacy static path (pinned by the golden fixtures)
        byte-identical."""
        resolved = self.resolve_trace()
        kw: dict = {}
        if (self.fault_plan is not None or self.autoscale is not None
                or self.n_partitions is not None or self.retry is not None
                or self.breaker is not None or self.degrade is not None
                or resolved is not None):
            kw = dict(fault_plan=self.fault_plan, autoscale=self.autoscale,
                      n_partitions=self.partitions, retry=self.retry,
                      breaker=self.breaker, degrade=self.degrade,
                      trace=resolved)
        return ClusterSim(self.scaled_workload(), self.scaled_broker(),
                          speedup=self.speedup if speedup is None else speedup,
                          scale=1.0, sim_time=sim_time, warmup=warmup,
                          seed=self.seed if seed is None else seed, **kw)


@dataclass
class ClusterResult:
    spec_speedup: float
    n_replicas: int
    produced: int
    completed: int
    dropped: int
    backlog: int
    diverged: bool
    latency: LatencyStats
    throughput: float                  # completions/s, model time
    utilization: dict                  # measured busy fractions
    predicted_rho: dict                # closed-form rho at this S
    producer_lag_mean: float           # model seconds behind schedule
    rebalances: int
    fetch_stats: BatchStats
    log: EventLog
    slo: SLOReport | None = None
    inflight_growth: float = 0.0       # second-half minus first-half mean
    requeues: int = 0                  # in-flight work re-enqueued on kills
    faults: list = field(default_factory=list)        # AppliedFault records
    scale_actions: list = field(default_factory=list)  # ScaleAction records
    samples: list = field(default_factory=list)       # (t_complete, latency)
    inflight_samples: list = field(default_factory=list)  # (t, in-flight)
    reliability: dict | None = None    # ReliabilityReport.to_dict(), when
    #                                    a retry/breaker/degrade policy ran
    heartbeats: list = field(default_factory=list)  # (window, t) trace
    #                                    replay markers (trace runs only)

    @property
    def drop_fraction(self) -> float:
        offered = self.produced + self.dropped
        return self.dropped / offered if offered else 0.0

    def ai_tax(self) -> dict:
        from repro.core import facerec
        return self.log.ai_tax(ai_stages={"identify"},
                               category_of=facerec.stage_category)

    def to_dict(self) -> dict:
        d = dict(self.__dict__)
        d["latency"] = self.latency.to_dict()
        d["faults"] = [(f.t, f.action, f.target) for f in self.faults]
        d["scale_actions"] = [(a.t, a.delta, a.n_before, a.reason)
                              for a in self.scale_actions]
        d.pop("log")
        d.pop("samples")
        d.pop("inflight_samples")
        return d


class _ReplicaState:
    """Per-replica accumulators; merged single-threaded at result time."""

    def __init__(self, name: str):
        self.name = name
        self.latencies: list[tuple[float, float]] = []  # (t_submit, latency)
        self.busy_model = 0.0
        self.served = 0       # unique wins (client-visible completions)
        self.consumed = 0     # everything drained, incl. cancelled/wasted
        #                       duplicates — the backlog-accounting count
        self.acc_sum = 0.0    # accuracy proxy over wins (degradation cost)
        self.acc_n = 0
        self.stats = BatchStats()


class ServingCluster:
    def __init__(self, spec: ClusterSpec, slo: TailSLO | None = None):
        self.spec = spec
        self.slo = slo
        self.log = EventLog()
        self.group = ConsumerGroup(spec.partitions)
        self._lock = threading.Lock()          # producer-side counters
        self.produced = 0
        self.dropped = 0
        self._lag_sum = 0.0
        self._replica_states: dict[str, _ReplicaState] = {}
        self._replica_threads: list[threading.Thread] = []
        self._removed: set[str] = set()
        self._killed: set[str] = set()
        self._feeder_threads: list[threading.Thread] = []
        self._done_events: dict[int, threading.Event] = {}
        self._identify = None                  # lazy, real mode only
        self._n_spawned = 0
        self._inflight_samples: list[tuple[float, int]] = []
        self.heartbeats: list[tuple[float, float]] = []  # trace replay
        self.fault_engine = None
        self.autoscaler = None
        # ---- reliability lifecycle (retry / hedge / breaker / degrade) ----
        # the retry+breaker path reroutes _produce_one through
        # _produce_rel; degrade alone only scales service in _serve
        self._rel_routed = (spec.retry is not None
                            or spec.breaker is not None)
        self._breakers: dict[int, object] = {}   # pi -> CircuitBreaker
        self._rel_state: dict[int, dict] = {}    # rid -> attempt ledger
        self._rel_completed: dict[int, float] = {}  # rid -> t_win (dedupe)
        self._rel_inservice: dict[int, float | None] = {}  # rid -> planned
        #                                      t_fin (None until known)
        self._rel_offered = 0
        self._rel_attempts = 0
        self._rel_retries = 0
        self._rel_hedges = 0
        self._rel_hedge_cancels = 0
        self._rel_hedge_wastes = 0
        self._rel_deadline_misses = 0
        self._rel_sheds = 0
        # model-time timer wheel for rcheck/republish/hedge/dlcheck —
        # one daemon thread sleeps on this condition (its OWN lock, the
        # sanctioned wait-under-lock pattern) until the next due event
        self._rel_cv = threading.Condition()
        self._rel_heap: list = []                # (t_model, seq, kind, pl)
        self._rel_seq = itertools.count()
        self._deg_depth = 0
        self.degrade_timeline: list[tuple[float, int, str]] = []

    # ---- time -------------------------------------------------------------

    def _now_model(self) -> float:
        return (time.perf_counter() - self.t0) * self.spec.time_compression

    # ---- lifecycle --------------------------------------------------------

    def start(self) -> None:
        sp = self.spec
        if sp.service == "real":
            import numpy as np
            from repro.core import facerec
            # same shared factory as StreamingPipeline (the replica IS
            # the pipeline's identify stage): the replica decodes the
            # wire-format YUV crops through the stack's preprocess
            # stage (sp.placement moves that work host<->device) and
            # identifies with the fused device program. The stage logs
            # nowhere here — its clock is wall time, this log is model
            # time; the decode cost lands inside the measured service
            # span instead.
            stack = facerec.build_identify_stack(
                seed=sp.seed, fast_path=True, placement=sp.placement)
            # warm every power-of-two batch bucket the drain-all fetch
            # can produce BEFORE the clock starts: a mid-run jit
            # compile (~100ms+) would otherwise masquerade as queueing
            # collapse and poison the divergence signal
            for b in (1, 2, 4, 8, 16, 32, 64):
                stack.fused.identify_crops(stack.preprocess.decode(
                    np.zeros((b, 3, 48, 48), np.uint8)))
            self._identify = stack.fused
            self._preprocess = stack.preprocess
        self.t0 = time.perf_counter()
        self.wall_deadline = self.t0 + sp.sim_time / sp.time_compression
        self.topic = LiveTopic("faces", sp.partitions, sp.scaled_broker(),
                               sp.time_compression, self.wall_deadline)
        self.topic.start()
        if sp.breaker is not None:
            self._breakers = {pi: sp.breaker.make(pi)
                              for pi in range(sp.partitions)}
        if sp.retry is not None:
            # timeout/backoff/hedge/deadline events fire in model time;
            # without a retry policy nothing schedules, so no thread
            rt = threading.Thread(target=self._reliability_loop,
                                  daemon=True)
            self._feeder_threads.append(rt)
            rt.start()
        for _ in range(sp.n_replicas):
            self.add_replica()
        trace = sp.resolve_trace()
        if trace is not None:
            # trace replay owns the arrival process (loadgen idle): one
            # producer thread paces the recorded timeline with the
            # BrokerWriter chunk discipline
            tt = threading.Thread(target=self._trace_producer,
                                  daemon=True, args=(trace,))
            self._feeder_threads.append(tt)
            tt.start()
        elif sp.loop == "closed":
            gen = ClosedLoopLoadGen(sp.n_clients, sp.think_s,
                                    process=sp.arrival, seed=sp.seed)
            for i in range(gen.n_clients):
                t = threading.Thread(target=self._client, daemon=True,
                                     args=(i, gen.think_sampler(i)))
                self._feeder_threads.append(t)
                t.start()
        else:
            gen = OpenLoopLoadGen(sp.n_producers, sp.period_s,
                                  process=sp.arrival, seed=sp.seed)
            for i in range(gen.n_producers):
                t = threading.Thread(
                    target=self._producer, daemon=True,
                    args=(i, gen.schedule(i, sp.sim_time)))
                self._feeder_threads.append(t)
                t.start()
        mon = threading.Thread(target=self._monitor, daemon=True)
        self._feeder_threads.append(mon)
        mon.start()
        if sp.fault_plan is not None:
            from repro.cluster.faults import FaultEngine
            self.fault_engine = FaultEngine(sp.fault_plan)
            ft = threading.Thread(target=self.fault_engine.run_live,
                                  args=(self,), daemon=True)
            self._feeder_threads.append(ft)
            ft.start()
        if sp.autoscale is not None:
            # build the controller BEFORE the thread exists: attaching
            # it from inside the loop published self.autoscaler across
            # threads unlocked (_result() reads it at shutdown)
            self.autoscaler = sp.autoscale.controller()
            at = threading.Thread(target=self._autoscale_loop, daemon=True)
            self._feeder_threads.append(at)
            at.start()

    def _monitor(self) -> None:
        """Samples the in-flight population for the divergence signal.

        A stable system near the knee legitimately carries a large
        steady-state in-flight population (Little's law: rate x
        latency), so an absolute end-of-run backlog can't separate
        "high but flat" from "growing". The monitor records
        (t_model, produced - completed) every ~50 ms wall; divergence
        compares the two post-warmup half-window means.
        """
        sp = self.spec
        while time.perf_counter() < self.wall_deadline:
            # snapshot: add_replica() may insert mid-iteration; consumed
            # (not served) so a drained hedge duplicate leaves the
            # in-flight population like any other record
            states = list(self._replica_states.values())
            done = sum(st.consumed for st in states)
            t = self._now_model()
            backlog = self.produced - done
            self._inflight_samples.append((t, backlog))
            if sp.degrade is not None:
                # degradation controller rides the monitor cadence:
                # per-replica backlog + breaker-open fraction in, ladder
                # depth out — same decide() as the DES sample event
                per = backlog / max(len(states), 1)
                bs = list(self._breakers.values())
                of = (sum(1 for b in bs if b.state != "closed")
                      / len(bs)) if bs else 0.0
                nd = sp.degrade.decide(per, of, self._deg_depth)
                if nd != self._deg_depth:
                    with self._lock:
                        self._deg_depth = nd
                    self.degrade_timeline.append(
                        (t, nd, sp.degrade.level(nd).name))
            time.sleep(0.05)

    def add_replica(self) -> str:
        # under _lock: the autoscaler thread and the fault engine can
        # both add replicas while the monitor iterates the states
        with self._lock:
            name = f"replica-{self._n_spawned}"
            self._n_spawned += 1
            st = _ReplicaState(name)
            self._replica_states[name] = st
        # join the group HERE, not in the replica thread: membership is
        # then synchronous with add/remove calls, so remove_replica()
        # can never race an in-flight join and leave a ghost member
        # owning partitions no thread serves
        self.group.join(name)
        t = threading.Thread(target=self._replica, daemon=True,
                             args=(name, st))
        self._replica_threads.append(t)
        t.start()
        return name

    def remove_replica(self, name: str) -> None:
        """Revoke the replica's partitions; the group rebalances onto
        the survivors and the thread exits at its next ownership check."""
        self._removed.add(name)
        self.group.leave(name)

    def kill_replica(self, name: str) -> None:
        """Abrupt failure (fault engine): same membership transition as
        a graceful leave — the group just sees a member vanish — but
        tracked separately so results can attribute the rebalance to a
        fault. The victim's held-back records are requeued (with a
        logged ``requeue`` event) on its way out, never dropped."""
        self._killed.add(name)
        self.group.leave(name)

    def _autoscale_loop(self) -> None:
        """Samples backlog + recent tail every interval and applies the
        controller's delta through the ordinary join/leave path — the
        group code never learns that elasticity exists (same zero-
        awareness contract as the fault engine)."""
        sp = self.spec
        ctl = self.autoscaler
        from repro.cluster.metrics import percentile
        interval_wall = sp.autoscale.interval_s / sp.time_compression
        horizon = 4 * sp.autoscale.interval_s
        while True:
            time.sleep(min(interval_wall, max(
                0.0, self.wall_deadline - time.perf_counter())) or 0.001)
            if time.perf_counter() >= self.wall_deadline:
                return
            t = self._now_model()
            states = list(self._replica_states.values())
            backlog = self.produced - sum(st.consumed for st in states)
            recent = [lat for st in states
                      for t_sub, lat in st.latencies[-256:]
                      if t_sub + lat > t - horizon]
            p99 = percentile(recent, 0.99) if recent else None
            members = self.group.members
            delta = ctl.decide(t, backlog, len(members), p99)
            for _ in range(delta):
                self.add_replica()
            if delta < 0:
                # shrink newest-first: replica names carry their spawn
                # index, so "newest" is well-defined and deterministic
                for name in sorted(
                        members,
                        key=lambda n: -int(n.rsplit("-", 1)[1]))[:-delta]:
                    if len(self.group.members) > 1:
                        self.remove_replica(name)

    def run(self) -> ClusterResult:
        self.start()
        for t in self._feeder_threads:
            t.join()
        for t in self._replica_threads:
            t.join()
        self.topic.join()
        return self._result()

    # ---- producers (open loop) --------------------------------------------

    def _crop_rng(self, stream: int):
        """Per-feeder-thread crop generator (real mode): seeding a fresh
        Generator per message would tax the very path being timed."""
        import numpy as np
        return np.random.default_rng(self.spec.seed * 7919 + stream)

    def _produce_one(self, rid: int, scheduled_model: float,
                     crop_rng=None, part=None, size=None) -> bool:
        """Admit + publish one message; False if dropped/rejected.

        ``part``/``size`` carry a trace event's pinned partition (keyed
        traffic) and recorded payload; loadgen callers leave both None
        (round-robin pick, workload payload) — unchanged behavior.
        """
        sp = self.spec
        if self._rel_routed:
            return self._produce_rel(rid, scheduled_model, crop_rng,
                                     part=part, size=size)
        if part is None:
            part = self.topic.pick_partition()
        bounded = sp.admission in ("drop", "block")
        while True:            # check-and-admit atomically across producers
            with self._lock:
                if not bounded or part.in_flight < sp.partition_capacity:
                    part.accepted += 1
                    self.produced += 1
                    admitted = True
                    break
                if sp.admission == "drop":
                    self.dropped += 1
                    admitted = False
                    break
            # block: wait for capacity, then RE-check under the lock
            if time.perf_counter() >= self.wall_deadline:
                return False
            time.sleep(0.002)
        now = self._now_model()
        if not admitted:
            self.log.log(rid, "reject", now, now,
                         payload_bytes=int(sp.wl.face_bytes))
            return False
        msg = Message(key=rid,
                      size=sp.wl.face_bytes if size is None else size,
                      t_produced=now)
        msg.meta["scheduled"] = scheduled_model
        if sp.service == "real":
            import numpy as np
            from repro.preprocess import host as pre_host
            crop = crop_rng.integers(0, 256, (48, 48, 3), dtype=np.uint8)
            # the wire format: codec-encoded planar YUV (the encode
            # stands for the camera/codec, like the pipeline's ingest)
            msg.meta["crop_yuv"] = pre_host.rgb_to_yuv(crop)
            msg.size = float(crop.nbytes)
        with self._lock:
            self._lag_sum += max(0.0, now - scheduled_model)
        self.topic.publish(msg, part)
        return True

    # ---- reliability lifecycle (mirrors the DES rel_send/rcheck path) -----

    def _produce_rel(self, rid: int, scheduled_model: float,
                     crop_rng=None, part=None, size=None) -> bool:
        """Register one request and issue its first attempt.

        The reliability path replaces bounded admission with breaker
        shedding: an attempt whose round-robin partition refuses it is
        rejected instantly (and retried after backoff, if the policy
        allows), never blocked — a client with a deadline cannot wait on
        the producer side. A trace event's pinned ``part`` sticks for
        the request's whole retry chain (keyed traffic is
        partition-affine — same rule as the DES ``rel_send``).
        """
        sp = self.spec
        now = self._now_model()
        size = sp.wl.face_bytes if size is None else size
        crop_yuv = None
        if sp.service == "real":
            import numpy as np
            from repro.preprocess import host as pre_host
            crop = crop_rng.integers(0, 256, (48, 48, 3), dtype=np.uint8)
            crop_yuv = pre_host.rgb_to_yuv(crop)
            size = float(crop.nbytes)
        with self._lock:
            # attempt ledger: retries re-publish from this template so a
            # re-sent message carries the ORIGINAL payload + t_produced
            # (client-perceived latency spans all attempts)
            self._rel_state[rid] = {"n": 0, "t0": now, "size": size,
                                    "crop": crop_yuv,
                                    "pin": part.index if part is not None
                                    else None}
            self._rel_offered += 1
            self._lag_sum += max(0.0, now - scheduled_model)
        if sp.retry is not None:
            t_dl = now + sp.retry.deadline_s
            self._rel_schedule(t_dl, "dlcheck", (rid, t_dl))
            if sp.retry.hedge_delay_s is not None:
                t_h = now + sp.retry.hedge_delay_s
                self._rel_schedule(t_h, "hedge", (rid, t_h))
        return self._rel_attempt(rid, "attempt")

    def _rel_attempt(self, rid: int, origin: str) -> bool:
        """One publish attempt (first / retry / hedge) for a known rid."""
        sp, retry = self.spec, self.spec.retry
        now = self._now_model()
        with self._lock:
            st = self._rel_state.get(rid)
            if st is None:
                return False
            st["n"] += 1
            n = st["n"]
            self._rel_attempts += 1
        retryable = retry is not None and origin != "hedge"
        # one round-robin candidate per attempt: its breaker admits or
        # the attempt is shed and retried against the NEXT partition
        # after backoff (scanning for any willing partition would
        # compound per-partition probe rates into near-certain
        # admission — same rule as the DES pick_part_allowed). A
        # pinned (keyed-trace) request always faces its own partition.
        pin = st.get("pin")
        part = (self.topic.partitions[pin] if pin is not None
                else self.topic.pick_partition())
        b = self._breakers.get(part.index)
        if b is not None and not b.allow(now):
            with self._lock:
                self._rel_sheds += 1
            self.log.log(rid, "reject", now, now, int(st["size"]),
                         reason="breaker_open")
            if retryable and retry.retry_allowed(now, st["t0"], n):
                t_r = now + retry.backoff_s(rid, n)
                self._rel_schedule(t_r, "republish", (rid, t_r))
            return False
        msg = Message(key=rid, size=st["size"], t_produced=st["t0"])
        msg.meta["rel_pub"] = now       # late-completion gate in _serve
        if st["crop"] is not None:
            msg.meta["crop_yuv"] = st["crop"]
        with self._lock:
            part.accepted += 1
            self.produced += 1
        self.topic.publish(msg, part)
        if retry is not None:
            t_due = now + retry.attempt_timeout_s
            self._rel_schedule(t_due, "rcheck",
                               (rid, part.index, retryable, t_due))
        return True

    def _rel_schedule(self, t_model: float, kind: str, payload) -> None:
        with self._rel_cv:
            heapq.heappush(self._rel_heap,
                           (t_model, next(self._rel_seq), kind, payload))
            self._rel_cv.notify()

    def _reliability_loop(self) -> None:
        """Model-time timer wheel for the request lifecycle.

        Pops rcheck/republish/hedge/dlcheck events as they come due,
        firing each OUTSIDE the condition (handlers publish and take
        other locks). Waiting happens on the condition's own lock —
        the wheel never sleeps holding anyone else's.
        """
        sp = self.spec
        while True:
            with self._rel_cv:
                now = self._now_model()
                while not self._rel_heap or self._rel_heap[0][0] > now:
                    if time.perf_counter() >= self.wall_deadline:
                        return
                    gap_wall = ((self._rel_heap[0][0] - now)
                                / sp.time_compression
                                if self._rel_heap else 0.05)
                    self._rel_cv.wait(timeout=min(max(gap_wall, 0.0005),
                                                  0.05))
                    now = self._now_model()
                t, _, kind, pl = heapq.heappop(self._rel_heap)
            self._rel_fire(kind, pl)

    def _rel_verdict(self, rid: int, t_due: float):
        """Model-time completion verdict for a timer due at ``t_due``.

        The DES processes completions and timers in strict model-time
        order, so an rcheck/dlcheck "sees" a completion iff its model
        finish time precedes the timer. The live replica backdates each
        item's ``t_fin`` inside the batch span but records it only when
        the batch's service SLEEP ends — wall time runs ahead of the
        books, and a membership test here would book false failures for
        items that completed (in model time) mid-batch. So: defer the
        verdict while the rid is mid-service, then compare recorded
        ``t_fin`` against ``t_due`` — the same ordering the DES gets
        for free. Returns ``("done"|"pending"|"defer", st)``.
        """
        with self._lock:
            st = self._rel_state.get(rid)
            t_fin = self._rel_completed.get(rid)
            inserv = rid in self._rel_inservice
            eta = self._rel_inservice.get(rid)
        if st is None:
            return "done", None
        if t_fin is not None and t_fin <= t_due + 1e-12:
            return "done", st
        if t_fin is None and inserv:
            if eta is None:
                # real-service batch: no pacing plan, wait for the books
                return "defer", st
            # paced batch: rule punctually on the planned finish time
            return ("done" if eta <= t_due + 1e-12 else "pending"), st
        return "pending", st

    def _rel_fire(self, kind: str, pl) -> None:
        retry = self.spec.retry
        now = self._now_model()
        if kind == "rcheck":
            # attempt timeout: presumed lost -> breaker failure, and
            # (for the primary chain) a backed-off re-publish
            rid, pi, retryable, t_due = pl
            verdict, st = self._rel_verdict(rid, t_due)
            if verdict == "done":
                return
            if verdict == "defer":
                self._rel_schedule(now + 0.02, kind, pl)
                return
            b = self._breakers.get(pi)
            if b is not None:
                b.record(t_due, False)
            if retryable and retry.retry_allowed(t_due, st["t0"], st["n"]):
                t_r = t_due + retry.backoff_s(rid, st["n"])
                self._rel_schedule(t_r, "republish", (rid, t_r))
        elif kind in ("republish", "hedge"):
            rid, t_due = pl
            verdict, st = self._rel_verdict(rid, t_due)
            if verdict == "done":
                return
            if verdict == "defer":
                self._rel_schedule(now + 0.02, kind, pl)
                return
            with self._lock:
                if kind == "republish":
                    self._rel_retries += 1
                else:
                    self._rel_hedges += 1
            self.log.log(rid, "retry" if kind == "republish" else "hedge",
                         now, now, int(st["size"]))
            self._rel_attempt(rid, "retry" if kind == "republish"
                              else "hedge")
        elif kind == "dlcheck":
            rid, t_due = pl
            verdict, _ = self._rel_verdict(rid, t_due)
            if verdict == "defer":
                self._rel_schedule(now + 0.02, kind, pl)
                return
            if verdict == "pending":
                with self._lock:
                    self._rel_deadline_misses += 1
                self.log.log(rid, "deadline_miss", t_due, t_due)

    def _trace_producer(self, trace) -> None:
        """Replay the resolved trace into the live topic.

        One thread paces every recorded arrival (the trace is already
        rescaled, so the replayer runs at 1x): publishes go through the
        ordinary ``_produce_one`` path with the event's pinned
        partition and payload, and each completed heartbeat window is
        recorded + logged as a zero-duration marker at its grid time —
        the same (window, t) pairs the DES emits, so the twin loop
        compares like against like.
        """
        from repro.cluster.trace import TraceReplayProducer
        sp = self.spec
        rng = self._crop_rng(0) if sp.service == "real" else None
        rp = TraceReplayProducer(trace)

        def publish(ev, t_rep):
            part = (self.topic.partitions[ev.partition_key % sp.partitions]
                    if ev.partition_key is not None else None)
            self._produce_one(ev.rid, t_rep, rng, part=part,
                              size=float(ev.payload_bytes))

        def heartbeat(k, t_mark):
            self.heartbeats.append((k, t_mark))
            self.log.log(-1, "heartbeat", t_mark, t_mark, window=k)

        rp.run_live(self.t0, self.wall_deadline, sp.time_compression,
                    publish, heartbeat)

    def _producer(self, i: int, schedule: list[float]) -> None:
        sp = self.spec
        rng = self._crop_rng(i) if sp.service == "real" else None
        for k, arrival in enumerate(schedule):
            wall = self.t0 + arrival / sp.time_compression
            delay = wall - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            if time.perf_counter() >= self.wall_deadline:
                return
            self._produce_one(i + k * sp.n_producers, arrival, rng)

    # ---- clients (closed loop) --------------------------------------------

    def _client(self, i: int, think) -> None:
        sp = self.spec
        rng = self._crop_rng(i) if sp.service == "real" else None
        k = 0
        while time.perf_counter() < self.wall_deadline:
            rid = i + k * sp.n_clients
            k += 1
            evt = threading.Event()
            # each client thread touches only its own rid keys; the
            # replica side reads through dict.get on a different key
            # space per client, and CPython dict setitem is atomic
            self._done_events[rid] = evt  # lint: waive race-check -- per-client key space, atomic dict setitem, reader uses .get
            if self._produce_one(rid, self._now_model(), rng):
                evt.wait(timeout=max(
                    0.0, self.wall_deadline - time.perf_counter()))
            self._done_events.pop(rid, None)
            pause = think() / sp.time_compression
            if pause > 0:
                time.sleep(min(
                    pause,
                    max(0.0, self.wall_deadline - time.perf_counter())))

    # ---- replicas ---------------------------------------------------------

    def _replica(self, name: str, st: _ReplicaState) -> None:
        """Partition-aware consumer loop, one thread per replica.

        Fetch semantics mirror the DES (and Kafka): drain everything a
        partition has, serve it if it clears ``fetch_min_bytes`` or the
        oldest record has aged past ``fetch_max_wait_s``, otherwise
        hold it pending and sweep on — messages keep accumulating WHILE
        the replica serves other partitions, so fetch batching never
        eats service capacity. Ownership is re-read every sweep; on
        revocation, pending records are requeued for the new owner.
        """
        sp = self.spec
        fetch_wait_wall = (sp.bk.fetch_max_wait_s
                           if sp.fetch_max_wait_s is None
                           else sp.fetch_max_wait_s) / sp.time_compression
        batch_cap = max(1, int(sp.bk.fetch_min_bytes // max(
            sp.wl.face_bytes, 1.0)))
        batchers: dict[int, Batcher] = {}
        pending: dict[int, list] = {}
        while time.perf_counter() < self.wall_deadline:
            if name in self._removed or name in self._killed:
                break
            asg = self.group.assignment(name)
            # revoked partitions: hand any held-back records straight
            # back to the partition queue so the NEW owner serves them
            # (not at thread exit — a rebalance survivor keeps running)
            for pi in list(pending):
                if pi not in asg.partitions and pending[pi]:
                    self._requeue(pi, pending.pop(pi))
            if not asg.partitions:
                time.sleep(0.004)
                continue
            served_any = False
            for pi in asg.partitions:
                if time.perf_counter() >= self.wall_deadline:
                    break
                # generation fence: if membership changed since this
                # sweep's assignment was read, restart with a fresh
                # view instead of fetching from a possibly-revoked
                # partition (shrinks the rebalance overlap to a serve
                # already in flight — Kafka's cooperative window)
                if self.group.assignment(name).generation != asg.generation:
                    break
                part = self.topic.partitions[pi]
                b = batchers.get(pi)
                if b is None:
                    b = batchers[pi] = Batcher(
                        part.queue, batch_size=batch_cap, timeout_s=0.0)
                buf = pending.setdefault(pi, [])
                buf.extend(b.poll(1 << 30))
                if not buf:
                    continue
                ready = sum(m.size for m in buf)
                age = time.perf_counter() - buf[0].t_written
                if (ready < sp.bk.fetch_min_bytes
                        and age < fetch_wait_wall):
                    continue
                pending[pi] = []
                self._serve(st, part, buf)
                served_any = True
            if not served_any:
                time.sleep(0.002)
        # fold per-partition fetch stats once, on the way out (results
        # are read only after the thread joins)
        st.stats = BatchStats()
        for b in batchers.values():
            st.stats = st.stats.merge(b.stats)
        # hand anything still pending back to the partition queue: the
        # rebalanced owner (or final backlog accounting) picks it up
        for pi, buf in pending.items():
            self._requeue(pi, buf)

    def _requeue(self, pi: int, msgs: list) -> None:
        """Give held-back records back to their partition for the new
        owner, each with a logged ``requeue`` event — a fault or
        rebalance relocates work, it never drops it, and the event
        keeps the five-way tax attribution summing to 1 (the relocated
        wait lands in the queue bucket)."""
        now = self._now_model()
        for m in msgs:
            self.log.log(m.key, "requeue", now, now,
                         payload_bytes=int(m.size))
            self.topic.partitions[pi].queue.put(m)

    def _serve(self, st: _ReplicaState, part, batch: list[Message]) -> None:
        sp = self.spec
        rel_on = sp.retry is not None
        t_deq = self._now_model()
        if rel_on:
            # request-id dedupe at dequeue: a duplicate whose twin
            # already won is cancelled before costing any service time
            # (the cheap hedge outcome)
            fresh = []
            for msg in batch:
                with self._lock:
                    dup = msg.key in self._rel_completed
                    if dup:
                        self._rel_hedge_cancels += 1
                        part.consumed += 1
                    else:
                        # mid-service marker: timer verdicts defer until
                        # this item's planned t_fin is known (set once
                        # the batch's pacing plan is computed below)
                        self._rel_inservice[msg.key] = None
                if dup:
                    self.log.log(msg.key, "hedge_cancel", t_deq, t_deq,
                                 int(msg.size))
                    st.consumed += 1  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
                else:
                    fresh.append(msg)
            batch = fresh
            if not batch:
                return
        lvl = (sp.degrade.level(self._deg_depth)
               if sp.degrade is not None else None)
        low_res = False
        if sp.service == "real":
            import numpy as np
            from repro.core import facerec
            yuv = np.stack([m.meta["crop_yuv"] for m in batch])
            w0 = time.perf_counter()
            low_res = (lvl is not None and lvl.letterbox_scale < 1.0
                       and self._preprocess.placement == "host")
            # decode (host or device per spec.placement), then the
            # fused identify; only the jitted device path pads to pow2
            # (aligning with the pre-warmed buckets) — host NumPy has
            # no compile cache, so padding would just be wasted work
            # inside the measured service span
            if low_res:
                # degraded decode: subsample the wire YUV down to the
                # letterboxed resolution (a fraction of the codec
                # work), then nearest-neighbour upsample the decoded
                # RGB back to the stack's native crop size. Host
                # placement only — the jitted device decode is
                # shape-specialized to the pre-warmed buckets, and a
                # mid-run recompile would masquerade as collapse.
                step = max(1, round(1.0 / lvl.letterbox_scale))
                rgb = self._preprocess.decode(yuv[:, :, ::step, ::step])
                rgb = rgb.repeat(step, axis=1).repeat(step, axis=2)
            elif self._preprocess.placement == "device":
                rgb = self._preprocess.decode(
                    facerec._pad_rows_pow2(yuv))[:len(batch)]
            else:
                rgb = self._preprocess.decode(yuv)
            self._identify.identify_crops(rgb)
            dur_model = ((time.perf_counter() - w0)
                         * sp.time_compression)
        else:
            # paced mode prices the whole ladder: the degrade level's
            # service_factor scales the emulated identify span
            dur_model = (sp.wl.t_identify / sp.speedup * len(batch)
                         * (lvl.service_factor if lvl is not None else 1.0))
            if not self._rel_routed:
                time.sleep(dur_model / sp.time_compression)
        st.busy_model += dur_model  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
        # real mode books accuracy cost only for the rung it actually
        # implements (the letterbox decode); paced mode emulates every
        # rung, so the ladder's proxy always applies
        applied = sp.service != "real" or low_res
        acc = (lvl.accuracy_proxy
               if (lvl is not None and applied) else 1.0)
        if sp.service != "real" and self._rel_routed:
            # item-by-item pacing at absolute wall deadlines: each
            # completion goes on the books AT its model finish time, so
            # breaker outcomes and timer-wheel verdicts observe
            # completions in the model-time order the DES processes
            # them in. Recording at batch end would let punctual
            # timeout failures overtake backdated successes and
            # scramble the breaker's windowed error fraction.
            dt = dur_model / len(batch)
            with self._lock:
                # publish the pacing plan: timer verdicts can now rule
                # punctually on mid-service items by planned t_fin
                for j, m in enumerate(batch):
                    if m.key in self._rel_inservice:
                        self._rel_inservice[m.key] = t_deq + (j + 1) * dt
            w0 = time.perf_counter()
            for j, msg in enumerate(batch):
                delay = (w0 + (j + 1) * dt / sp.time_compression
                         - time.perf_counter())
                if delay > 0:
                    time.sleep(delay)
                self._finish_item(st, part, msg, t_deq + j * dt,
                                  t_deq + (j + 1) * dt, len(batch), acc)
            return
        t_end = self._now_model()
        dt = (t_end - t_deq) / len(batch)
        for j, msg in enumerate(batch):
            self._finish_item(st, part, msg, t_deq + j * dt,
                              t_deq + (j + 1) * dt, len(batch), acc)

    def _finish_item(self, st: _ReplicaState, part, msg: Message,
                     t_start: float, t_fin: float, n_batch: int,
                     acc: float) -> None:
        """Book one served item's completion at model time ``t_fin``."""
        sp = self.spec
        rel_on = sp.retry is not None
        # consumed feeds part.in_flight, which _produce_one's
        # admission check reads under _lock — keep the pair of
        # counters consistent for bounded admission
        if rel_on:
            with self._lock:
                win = msg.key not in self._rel_completed
                if win:
                    self._rel_completed[msg.key] = t_fin
                else:
                    self._rel_hedge_wastes += 1
                part.consumed += 1
                self._rel_inservice.pop(msg.key, None)
            if not win:
                # both attempts were in service at once: the
                # loser's span is wasted work, not a completion
                self.log.log(msg.key, "hedge_waste", t_start,
                             t_fin, int(msg.size))
                st.consumed += 1  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
                return
        else:
            with self._lock:
                part.consumed += 1
        b = self._breakers.get(part.index)
        if b is not None and not (
                rel_on and t_fin - msg.meta.get("rel_pub", t_fin)
                > sp.retry.attempt_timeout_s + 1e-12):
            # a late completion is not a success signal: its rcheck
            # already recorded the timeout as the outcome
            b.record(t_fin, True)
        # the wait runs to THIS item's service start (like the DES's
        # per-item t_consumed), not the batch dequeue — the hold inside
        # a fetched batch is queue tax and must be on the books
        self.log.log(msg.key, "wait", msg.t_produced, t_start,
                     payload_bytes=int(msg.size))
        self.log.log(msg.key, "identify", t_start, t_fin,
                     payload_bytes=int(msg.size), batch_size=n_batch)
        if acc < 1.0:
            name = next((l.name for l in sp.degrade.levels
                         if l.accuracy_proxy == acc), "degraded")
            self.log.log(msg.key, "degrade", t_fin, t_fin,
                         int(msg.size), accuracy_proxy=acc, level=name)
        st.served += 1  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
        st.consumed += 1  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
        st.acc_sum += acc  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
        st.acc_n += 1  # lint: waive race-check -- per-replica state; only this replica thread writes it, merged after join
        st.latencies.append(
            (msg.t_produced, t_fin - msg.t_produced))
        evt = self._done_events.get(msg.key)
        if evt is not None:
            evt.set()

    # ---- results ----------------------------------------------------------

    def _result(self) -> ClusterResult:
        sp = self.spec
        span_wall = time.perf_counter() - self.t0
        span_model = span_wall * sp.time_compression
        states = list(self._replica_states.values())
        completed = sum(st.served for st in states)
        # backlog counts what was published and never drained; a hedge
        # duplicate that WAS drained (cancelled or wasted) is not backlog
        backlog = self.produced - sum(st.consumed for st in states)
        samples = [lat for st in states for t_sub, lat in st.latencies
                   if t_sub >= sp.warmup]
        steady_span = max(span_model - sp.warmup, 1e-9)
        lag_mean = self._lag_sum / max(self.produced, 1)
        mid = sp.warmup + 0.5 * (sp.sim_time - sp.warmup)
        first = [n for t, n in self._inflight_samples
                 if sp.warmup <= t < mid]
        second = [n for t, n in self._inflight_samples if t >= mid]
        growth = ((sum(second) / len(second)) - (sum(first) / len(first))
                  if first and second else 0.0)
        diverged = (growth > max(0.04 * max(self.produced, 1), 25)
                    or lag_mean > 5 * sp.period_s)
        stats = LatencyStats.from_samples(samples)
        fetch = BatchStats()
        for st in states:
            fetch = fetch.merge(st.stats)
        util = {
            "broker_storage_write": self.topic.write_utilization(span_wall),
            "consumers": sum(st.busy_model for st in states)
            / (span_model * max(len(states), 1)),
        }
        completions = sorted((t_sub + lat, lat)
                             for st in states
                             for t_sub, lat in st.latencies)
        result = ClusterResult(
            spec_speedup=sp.speedup, n_replicas=len(states),
            produced=self.produced, completed=completed,
            dropped=self.dropped, backlog=backlog, diverged=diverged,
            latency=stats, throughput=len(samples) / steady_span,
            utilization=util, predicted_rho=sp.predicted_rho(),
            producer_lag_mean=lag_mean, rebalances=self.group.rebalances,
            fetch_stats=fetch, log=self.log, inflight_growth=growth,
            requeues=sum(1 for e in self.log.events
                         if e.stage == "requeue"),
            faults=(list(self.fault_engine.applied)
                    if self.fault_engine else []),
            scale_actions=(list(self.autoscaler.actions)
                           if self.autoscaler else []),
            samples=completions,
            inflight_samples=list(self._inflight_samples),
            reliability=self._reliability_dict(span_model, completions,
                                               states),
            heartbeats=list(self.heartbeats))
        if self.slo is not None:
            result.slo = self.slo.check(stats, result.drop_fraction)
        return result

    def _reliability_dict(self, span_model: float, completions: list,
                          states: list) -> dict | None:
        sp = self.spec
        if (sp.retry is None and sp.breaker is None
                and sp.degrade is None):
            return None
        from repro.cluster.metrics import reliability_report
        timeline = sorted((t, pi, s)
                          for pi, b in sorted(self._breakers.items())
                          for t, s in b.timeline)
        # without the rerouted producer path every publish is its own
        # sole attempt (degrade-only runs)
        offered = self._rel_offered if self._rel_routed else self.produced
        attempts = self._rel_attempts if self._rel_routed else self.produced
        deadline = (sp.retry.deadline_s if sp.retry is not None
                    else float("inf"))
        acc_n = sum(st.acc_n for st in states)
        acc_sum = sum(st.acc_sum for st in states)
        return reliability_report(
            completions, deadline, max(span_model, 1e-9),
            offered=offered, attempts=attempts,
            deadline_misses=self._rel_deadline_misses,
            retries=self._rel_retries, hedges=self._rel_hedges,
            hedge_cancels=self._rel_hedge_cancels,
            hedge_wastes=self._rel_hedge_wastes,
            breaker_sheds=self._rel_sheds,
            accuracy_proxy_mean=(acc_sum / acc_n if acc_n else 1.0),
            breaker_timeline=timeline,
            degrade_timeline=self.degrade_timeline).to_dict()
