"""Workload traces: recorded request timelines both engines can replay.

A :class:`WorkloadTrace` is a versioned, validated sequence of
timestamped requests — each with a request id, an optional partition
key (keyed traffic pins a partition, like a camera id hashing to one
Kafka partition) and a payload size. One trace drives BOTH execution
engines: ``ClusterSpec.trace`` hands it to the DES as a mirrored event
path (``ClusterSim(trace=...)``) and to the live ``ServingCluster``
through :class:`TraceReplayProducer`, which paces real publishes with
the same chunked absolute-deadline discipline as ``BrokerWriter`` (one
sleep paces a chunk of due events; the absolute wall deadline
self-corrects sleep overshoot instead of letting it accumulate).

Heartbeat windows (the OpenDT dc-mock idiom) mark the trace's time axis
every ``heartbeat_s``: both engines log a zero-duration ``heartbeat``
marker per window, and the digital-twin loop (``crossval.twin_compare``)
compares windowed tail latency and five-way tax per heartbeat window.

On-disk format (JSONL, one object per line):

  header  ``{"format": "repro-trace", "version": 1, "name": ...,
             "horizon_s": ..., "heartbeat_s": ..., "n_events": N}``
  events  ``{"t": ..., "rid": ..., "key": ... | null, "bytes": ...}``
          in non-decreasing ``t`` order, exactly N of them.

Anything else — bad JSON, missing header, unsupported version,
out-of-order timestamps, truncation — raises :class:`TraceError` with
the offending line number, never a silent partial load.

Trace timestamps are post-client wire arrivals: replay publishes each
request straight into the broker at its timestamp (no client send cost,
no linger) in BOTH engines, so a recorded trace replays the arrival
process it observed rather than re-taxing it.
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field, replace

TRACE_FORMAT = "repro-trace"
TRACE_VERSION = 1

# default payload: the FaceRec wire crop (FaceRecWorkload.face_bytes)
DEFAULT_PAYLOAD_BYTES = 37_300.0


class TraceError(ValueError):
    """A trace file or trace construction violated the format contract."""


@dataclass(frozen=True)
class TraceEvent:
    """One request arrival: time, id, optional partition key, payload."""
    t: float
    rid: int
    partition_key: int | None = None
    payload_bytes: float = DEFAULT_PAYLOAD_BYTES

    def __post_init__(self):
        if self.t < 0:
            raise TraceError(f"event t must be >= 0, got {self.t}")
        if self.payload_bytes <= 0:
            raise TraceError(
                f"event payload_bytes must be > 0, got {self.payload_bytes}")


@dataclass(frozen=True)
class WorkloadTrace:
    """A validated, immutable request timeline (see module docstring)."""
    name: str
    horizon_s: float
    heartbeat_s: float
    events: tuple = ()
    version: int = TRACE_VERSION

    def __post_init__(self):
        if self.version != TRACE_VERSION:
            raise TraceError(
                f"unsupported trace version {self.version} "
                f"(supported: {TRACE_VERSION})")
        if self.horizon_s <= 0:
            raise TraceError(f"horizon_s must be > 0, got {self.horizon_s}")
        if self.heartbeat_s <= 0:
            raise TraceError(
                f"heartbeat_s must be > 0, got {self.heartbeat_s}")
        evs = tuple(self.events)
        object.__setattr__(self, "events", evs)
        last = 0.0
        rids = set()
        for i, ev in enumerate(evs):
            if not isinstance(ev, TraceEvent):
                raise TraceError(f"events[{i}] is not a TraceEvent")
            if ev.t < last:
                raise TraceError(
                    f"events[{i}] out of order: t={ev.t} after t={last}")
            if ev.t > self.horizon_s:
                raise TraceError(
                    f"events[{i}] t={ev.t} beyond horizon_s={self.horizon_s}")
            if ev.rid in rids:
                raise TraceError(f"events[{i}] duplicate rid {ev.rid}")
            rids.add(ev.rid)
            last = ev.t

    # ---- derived ----------------------------------------------------------

    @property
    def n_events(self) -> int:
        return len(self.events)

    @property
    def offered_rate(self) -> float:
        """Mean arrivals/s over the horizon."""
        return len(self.events) / self.horizon_s

    @property
    def n_windows(self) -> int:
        """Heartbeat windows covering the horizon (last may be partial)."""
        import math
        return max(1, math.ceil(self.horizon_s / self.heartbeat_s - 1e-9))

    def rescale(self, speed_factor: float) -> "WorkloadTrace":
        """The same trace compressed ``speed_factor``x in simulated time.

        Timestamps, the horizon AND the heartbeat window all divide by
        the factor, so windows keep covering the same slices of the
        workload — replaying at speed s is identical to replaying the
        rescaled trace at 1x (the invariant the trace tests pin).
        """
        if speed_factor <= 0:
            raise TraceError(
                f"speed_factor must be > 0, got {speed_factor}")
        if speed_factor == 1.0:
            return self
        s = speed_factor
        return replace(
            self, horizon_s=self.horizon_s / s,
            heartbeat_s=self.heartbeat_s / s,
            events=tuple(replace(ev, t=ev.t / s) for ev in self.events))

    def partition_counts(self, n_partitions: int) -> dict[int, int]:
        """Events per partition under the engines' shared routing rule.

        Keyed events pin ``key % n_partitions``; unkeyed events take a
        round-robin counter that starts at 0 and advances ONLY on
        unkeyed events — exactly what both engines do when replaying a
        trace single-threaded in event order, so a recorded trace's
        expected per-partition counts can be asserted without a run.
        """
        counts = dict.fromkeys(range(n_partitions), 0)
        rr = 0
        for ev in self.events:
            if ev.partition_key is not None:
                counts[ev.partition_key % n_partitions] += 1
            else:
                counts[rr % n_partitions] += 1
                rr += 1
        return counts

    # ---- serialization ----------------------------------------------------

    def _header(self) -> dict:
        return {"format": TRACE_FORMAT, "version": self.version,
                "name": self.name, "horizon_s": self.horizon_s,
                "heartbeat_s": self.heartbeat_s,
                "n_events": len(self.events)}

    def to_jsonl(self, path) -> None:
        with open(path, "w") as f:
            f.write(json.dumps(self._header(), sort_keys=True) + "\n")
            for ev in self.events:
                f.write(json.dumps(
                    {"t": ev.t, "rid": ev.rid, "key": ev.partition_key,
                     "bytes": ev.payload_bytes}, sort_keys=True) + "\n")

    @classmethod
    def from_jsonl(cls, path) -> "WorkloadTrace":
        def bad(lineno: int, why: str) -> TraceError:
            return TraceError(f"{path}:{lineno}: {why}")

        with open(path) as f:
            lines = [ln for ln in (raw.strip() for raw in f) if ln]
        if not lines:
            raise TraceError(f"{path}: empty trace file (no header line)")
        try:
            head = json.loads(lines[0])
        except json.JSONDecodeError as e:
            raise bad(1, f"header is not valid JSON: {e}") from e
        if not isinstance(head, dict) or head.get("format") != TRACE_FORMAT:
            raise bad(1, f"missing {TRACE_FORMAT!r} header "
                         f"(got {head!r:.80})")
        if head.get("version") != TRACE_VERSION:
            raise bad(1, f"unsupported trace version "
                         f"{head.get('version')!r} "
                         f"(supported: {TRACE_VERSION})")
        for key in ("name", "horizon_s", "heartbeat_s", "n_events"):
            if key not in head:
                raise bad(1, f"header missing required field {key!r}")
        events = []
        for lineno, ln in enumerate(lines[1:], start=2):
            try:
                obj = json.loads(ln)
            except json.JSONDecodeError as e:
                raise bad(lineno, f"event is not valid JSON: {e}") from e
            try:
                ev = TraceEvent(
                    t=float(obj["t"]), rid=int(obj["rid"]),
                    partition_key=(None if obj.get("key") is None
                                   else int(obj["key"])),
                    payload_bytes=float(obj.get(
                        "bytes", DEFAULT_PAYLOAD_BYTES)))
            except (KeyError, TypeError, ValueError, TraceError) as e:
                raise bad(lineno, f"bad event: {e}") from e
            if events and ev.t < events[-1].t:
                raise bad(lineno, f"out-of-order event: t={ev.t} after "
                                  f"t={events[-1].t}")
            events.append(ev)
        if len(events) != head["n_events"]:
            raise TraceError(
                f"{path}: truncated or padded trace: header promises "
                f"{head['n_events']} events, file has {len(events)}")
        try:
            return cls(name=str(head["name"]),
                       horizon_s=float(head["horizon_s"]),
                       heartbeat_s=float(head["heartbeat_s"]),
                       events=tuple(events))
        except TraceError as e:
            raise TraceError(f"{path}: {e}") from e

    def trace_hash(self) -> str:
        """Stable content hash (the DES-twin cache key component).

        Canonical-JSON sha256 over the header and every event, so the
        hash survives process restarts and file round-trips — two
        traces hash equal iff they replay identically.
        """
        h = hashlib.sha256()
        h.update(json.dumps(self._header(), sort_keys=True).encode())
        for ev in self.events:
            h.update(json.dumps(
                [ev.t, ev.rid, ev.partition_key, ev.payload_bytes]).encode())
        return h.hexdigest()[:16]


def record_loadgen(gen, horizon_s: float, *, name: str | None = None,
                   heartbeat_s: float | None = None,
                   payload_bytes: float = DEFAULT_PAYLOAD_BYTES,
                   ) -> WorkloadTrace:
    """Snapshot an ``OpenLoopLoadGen`` run into a replayable trace.

    Uses the generator's own per-producer seeded schedules and the live
    cluster's rid convention (``rid = producer + k * n_producers``), so
    the recorded trace carries exactly the arrivals a live run with
    this generator would have produced — the recorder round-trip test
    replays it and checks order, per-partition counts and the five-way
    sum.
    """
    arrivals: list[tuple[float, int]] = []
    for p in range(gen.n_producers):
        for k, t in enumerate(gen.schedule(p, horizon_s)):
            arrivals.append((t, p + k * gen.n_producers))
    arrivals.sort()
    events = tuple(TraceEvent(t=t, rid=rid, payload_bytes=payload_bytes)
                   for t, rid in arrivals)
    return WorkloadTrace(
        name=name or f"loadgen-{gen.process}-{gen.n_producers}x",
        horizon_s=horizon_s,
        heartbeat_s=heartbeat_s or horizon_s / 8,
        events=events)


class TraceReplayProducer:
    """Replays a trace into live broker topics under a speed factor.

    ``timeline()`` is the pure replay schedule — ``(t_replay, event)``
    with ``t_replay = event.t / speed_factor`` — shared by the pacing
    loop and the rescale-invariant property tests. ``run_live`` paces
    real publishes against the wall clock with the ``BrokerWriter``
    chunk discipline: sleep once to the next event's absolute wall
    deadline, then publish EVERY event already due, so ~1 ms sleep
    overshoot on a busy container is amortized across the chunk instead
    of taxing (and serially delaying) every record.
    """

    def __init__(self, trace: WorkloadTrace, speed_factor: float = 1.0):
        if speed_factor <= 0:
            raise TraceError(
                f"speed_factor must be > 0, got {speed_factor}")
        self.trace = trace
        self.speed_factor = speed_factor
        self.heartbeats: list[tuple[int, float]] = []   # (window, t_replay)

    @property
    def window_s(self) -> float:
        """Heartbeat window length in replay time."""
        return self.trace.heartbeat_s / self.speed_factor

    @property
    def horizon_replay_s(self) -> float:
        return self.trace.horizon_s / self.speed_factor

    def timeline(self) -> list[tuple[float, "TraceEvent"]]:
        return [(ev.t / self.speed_factor, ev) for ev in self.trace.events]

    def run_live(self, t0: float, wall_deadline: float,
                 time_compression: float, publish, heartbeat=None,
                 now=time.perf_counter, sleep=time.sleep) -> int:
        """Pace ``publish(event, t_replay)`` against the wall clock.

        ``t0`` anchors replay time 0 at a ``now()`` reading; replay
        seconds map to wall seconds through ``time_compression`` (the
        cluster's model-time contract). ``heartbeat(window, t_replay)``
        fires once per completed heartbeat window, in order, including
        trailing windows after the last event. ``now``/``sleep`` are
        injectable for the deterministic pacing tests. Returns the
        number of events published.
        """
        hb = self.window_s
        next_hb = 1

        def mark_to(t_replay: float) -> None:
            nonlocal next_hb
            while next_hb * hb <= t_replay + 1e-12:
                self.heartbeats.append((next_hb, next_hb * hb))
                if heartbeat is not None:
                    heartbeat(next_hb, next_hb * hb)
                next_hb += 1

        evs = self.timeline()
        published = 0
        i = 0
        while i < len(evs):
            t_rep = evs[i][0]
            wall = t0 + t_rep / time_compression
            while True:
                n = now()
                if n >= wall:
                    break
                if n >= wall_deadline:
                    return published
                sleep(min(0.01, wall - n))
            if now() >= wall_deadline:
                return published
            mark_to(t_rep)
            # chunk: everything already due goes out behind one sleep
            due = (now() - t0) * time_compression
            while i < len(evs) and evs[i][0] <= due:
                publish(evs[i][1], evs[i][0])
                published += 1
                i += 1
        mark_to(self.horizon_replay_s)
        return published
