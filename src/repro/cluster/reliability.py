"""Request-lifecycle reliability: retries, hedging, breakers, degradation.

The paper measures the tail of a fire-and-forget cluster; no operator
runs one. Production stacks wrap every request in a deadline + retry
policy, hedge the stragglers, trip a circuit breaker around failing
replicas, and degrade quality before they shed load. Each of those
mechanisms costs something — duplicated work, retry-amplified load,
accuracy — and that cost is an AI tax the five-way accounting must see.

This module is the policy vocabulary, shared verbatim by the live
cluster (``repro.cluster.cluster``) and the DES
(``repro.core.simulator``): pure-stdlib dataclasses plus one small
state machine, so ``repro.core`` can consume instances duck-typed
without importing this package (the same layering rule as ``FaultPlan``
and ``AutoscalerConfig``).

Determinism discipline: every random draw (backoff jitter, probe
admission) is seeded per (policy seed, request id, attempt) or per
(config seed, breaker key), never from global state — same seed, same
storm, in both execution engines.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field


# ---- retry / hedge policy ---------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Deadline-aware retry + optional tail-latency hedging.

    An attempt that hasn't completed ``attempt_timeout_s`` after publish
    is presumed lost: the client re-publishes after a backoff (this is
    the retry-storm mechanism — under a capacity dip every queued
    request times out and doubles the offered load). Backoff is
    exponential with seeded *full jitter*: the delay before attempt
    ``k+1`` is uniform in ``[base, min(cap, base * 2**(k-1))]`` —
    deterministic per ``(seed, request_id, attempt)``.

    ``hedge_delay_s`` (off by ``None``) duplicates a still-incomplete
    request once, ``hedge_delay_s`` after first publish; the first
    completion wins and the loser is cancelled by request-id dedupe at
    dequeue (or accounted as wasted work if a replica already picked it
    up). Retries are never issued past the point where they could not
    possibly complete before ``deadline_s``.
    """
    deadline_s: float = 1.0
    attempt_timeout_s: float = 0.3
    max_attempts: int = 3
    backoff_base_s: float = 0.02
    backoff_cap_s: float = 0.25
    hedge_delay_s: float | None = None
    seed: int = 0

    def __post_init__(self):
        if self.deadline_s <= 0 or self.attempt_timeout_s <= 0:
            raise ValueError("deadline_s and attempt_timeout_s must be > 0")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not (0 < self.backoff_base_s <= self.backoff_cap_s):
            raise ValueError("need 0 < backoff_base_s <= backoff_cap_s")
        if self.hedge_delay_s is not None and self.hedge_delay_s <= 0:
            raise ValueError("hedge_delay_s must be > 0 when set")

    def backoff_s(self, request_id: int, attempt: int) -> float:
        """Jittered delay before attempt ``attempt + 1`` (attempt >= 1).

        Full jitter over ``[base, min(cap, base * 2**(attempt-1))]``;
        the low end is the base (never zero) so a storm can't
        resynchronize into lockstep, and the high end is capped so late
        attempts still fit under the deadline.
        """
        if attempt < 1:
            raise ValueError("attempt counts from 1")
        hi = min(self.backoff_cap_s,
                 self.backoff_base_s * (2.0 ** (attempt - 1)))
        rng = random.Random(
            (self.seed * 1_000_003 + request_id * 7_919 + attempt)
            & 0x7FFF_FFFF)
        return self.backoff_base_s + rng.random() * (hi - self.backoff_base_s)

    def retry_allowed(self, t_now: float, t_first: float,
                      attempts: int) -> bool:
        """May a fresh attempt be issued at ``t_now``?

        Attempts are capped and a retry must still stand a chance: its
        publish time (after the minimum backoff) has to precede the
        deadline.
        """
        if attempts >= self.max_attempts:
            return False
        return t_now + self.backoff_base_s < t_first + self.deadline_s


# ---- circuit breaker --------------------------------------------------------


CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclass(frozen=True)
class BreakerConfig:
    """Windowed error-rate circuit breaker configuration.

    One ``CircuitBreaker`` is instantiated per publish target (broker
    partition, which maps 1:1 onto a consumer at the default replica
    count) via :meth:`make`. The breaker trips OPEN when, over the last
    ``window_s`` of outcomes with at least ``min_volume`` of them, the
    failure (error + attempt-timeout) fraction reaches
    ``failure_threshold``. After ``open_s`` it goes HALF_OPEN and
    admits a seeded ``probe_rate`` fraction of attempts;
    ``close_after`` consecutive probe successes close it, any probe
    failure re-opens it.
    """
    window_s: float = 1.0
    failure_threshold: float = 0.5
    min_volume: int = 5
    open_s: float = 1.0
    probe_rate: float = 0.2
    close_after: int = 3
    seed: int = 0

    def __post_init__(self):
        if not (0 < self.failure_threshold <= 1):
            raise ValueError("failure_threshold must be in (0, 1]")
        if not (0 < self.probe_rate <= 1):
            raise ValueError("probe_rate must be in (0, 1]")
        if self.window_s <= 0 or self.open_s <= 0:
            raise ValueError("window_s and open_s must be > 0")
        if self.min_volume < 1 or self.close_after < 1:
            raise ValueError("min_volume and close_after must be >= 1")

    def make(self, key: int = 0) -> "CircuitBreaker":
        """A fresh breaker for one target; ``key`` diversifies probes."""
        return CircuitBreaker(self, key)


class CircuitBreaker:
    """closed -> open -> half-open state machine over windowed outcomes.

    Thread-safe (the live cluster calls ``allow`` from producer threads
    and ``record`` from replica threads); the DES drives it
    single-threaded, where the lock is uncontended. Never blocks or
    sleeps under its lock. ``timeline`` records every state transition
    as ``(t, state)`` for the reliability report.
    """

    def __init__(self, cfg: BreakerConfig, key: int = 0):
        self.cfg = cfg
        self.key = key
        self.state = CLOSED
        self.timeline: list[tuple[float, str]] = [(0.0, CLOSED)]
        self._outcomes: list[tuple[float, bool]] = []  # (t, ok) window
        self._t_opened = 0.0
        self._probe_streak = 0
        self._rng = random.Random((cfg.seed * 9_176_531 + key * 65_537)
                                  & 0x7FFF_FFFF)
        self._lock = threading.Lock()

    def _transition(self, t: float, state: str) -> None:
        self.state = state
        self.timeline.append((t, state))

    def _step(self, t: float) -> None:
        # time-driven OPEN -> HALF_OPEN; caller holds the lock
        if self.state == OPEN and t - self._t_opened >= self.cfg.open_s:
            self._probe_streak = 0
            self._transition(t, HALF_OPEN)

    def _prune(self, t: float) -> None:
        w = self.cfg.window_s
        self._outcomes = [(tt, ok) for tt, ok in self._outcomes
                          if t - tt <= w]

    def allow(self, t: float) -> bool:
        """Admission decision for an attempt at model time ``t``."""
        with self._lock:
            self._step(t)
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                return False
            return self._rng.random() < self.cfg.probe_rate

    def record(self, t: float, ok: bool) -> None:
        """Outcome of an attempt: completion (ok) or error/timeout."""
        with self._lock:
            self._step(t)
            self._prune(t)
            self._outcomes.append((t, ok))
            if self.state == HALF_OPEN:
                if ok:
                    self._probe_streak += 1
                    if self._probe_streak >= self.cfg.close_after:
                        self._outcomes.clear()
                        self._transition(t, CLOSED)
                else:
                    self._t_opened = t
                    self._transition(t, OPEN)
                return
            if self.state == CLOSED:
                n = len(self._outcomes)
                bad = sum(1 for _, okk in self._outcomes if not okk)
                if (n >= self.cfg.min_volume
                        and bad / n >= self.cfg.failure_threshold):
                    self._t_opened = t
                    self._transition(t, OPEN)

    def snapshot(self) -> tuple[str, int]:
        """(state, windowed outcome count) without mutating time state."""
        with self._lock:
            return self.state, len(self._outcomes)


def open_fraction(breakers) -> float:
    """Fraction of breakers currently not CLOSED (degradation input)."""
    bs = list(breakers)
    if not bs:
        return 0.0
    return sum(1 for b in bs if b.state != CLOSED) / len(bs)


# ---- graceful degradation ---------------------------------------------------


@dataclass(frozen=True)
class DegradeLevel:
    """One rung of the quality ladder.

    ``service_factor`` scales per-item service time (the work actually
    saved); ``accuracy_proxy`` is the fraction of full-fidelity quality
    retained, logged with every degraded completion so the accuracy
    cost is on the books; ``post_nms``/``letterbox_scale`` say *how*
    the work is saved, consumed by the preprocess stage (skip the NMS
    re-rank, decode at reduced resolution).
    """
    name: str = "full"
    service_factor: float = 1.0
    accuracy_proxy: float = 1.0
    post_nms: bool = True
    letterbox_scale: float = 1.0

    def __post_init__(self):
        if not (0 < self.service_factor <= 1):
            raise ValueError("service_factor must be in (0, 1]")
        if not (0 < self.accuracy_proxy <= 1):
            raise ValueError("accuracy_proxy must be in (0, 1]")
        if not (0 < self.letterbox_scale <= 1):
            raise ValueError("letterbox_scale must be in (0, 1]")


FULL_FIDELITY = DegradeLevel()

DEFAULT_LADDER = (
    # skip the post-NMS re-rank: modest service saving, small accuracy hit
    DegradeLevel("skip_rerank", service_factor=0.75, accuracy_proxy=0.96,
                 post_nms=False),
    # half-resolution letterbox + no re-rank: big saving, visible hit
    DegradeLevel("low_res", service_factor=0.5, accuracy_proxy=0.88,
                 post_nms=False, letterbox_scale=0.5),
)


@dataclass(frozen=True)
class DegradePolicy:
    """When to walk down (and back up) the quality ladder.

    Depth 0 is full fidelity; depth ``k`` is ``levels[k-1]``. The
    ladder engages one rung per ``enter_backlog`` of per-replica
    backlog, jumps straight to the deepest rung when at least
    ``open_fraction`` of circuit breakers are open (the cluster is
    actively failing), and — hysteresis — only climbs back one rung at
    a time, and only once backlog has fallen to ``exit_backlog``, so
    the quality level doesn't flap at the threshold.
    """
    levels: tuple[DegradeLevel, ...] = DEFAULT_LADDER
    enter_backlog: float = 16.0
    exit_backlog: float = 4.0
    open_fraction: float = 0.5

    def __post_init__(self):
        if not self.levels:
            raise ValueError("need at least one degrade level")
        if not (0 < self.exit_backlog < self.enter_backlog):
            raise ValueError("need 0 < exit_backlog < enter_backlog")
        if not (0 < self.open_fraction <= 1):
            raise ValueError("open_fraction must be in (0, 1]")

    def level(self, depth: int) -> DegradeLevel:
        if depth <= 0:
            return FULL_FIDELITY
        return self.levels[min(depth, len(self.levels)) - 1]

    def decide(self, backlog_per_replica: float, breaker_open_fraction: float,
               current_depth: int) -> int:
        """Next ladder depth given pressure and the current depth."""
        if breaker_open_fraction >= self.open_fraction:
            return len(self.levels)
        target = min(len(self.levels),
                     int(backlog_per_replica // self.enter_backlog))
        if target >= current_depth:
            return target
        if backlog_per_replica <= self.exit_backlog:
            return max(target, current_depth - 1)
        return current_depth
