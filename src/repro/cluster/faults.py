"""Central fault-injection engine for the serving cluster (live + DES).

AsyncFlow's server-event-injection design, transplanted: ONE
deterministic timeline of planned outages owned by a central engine,
each transition an O(1) mutation of the single shared membership /
capacity map — the ``ConsumerGroup`` table in the live cluster, the
partition→owner map in the DES. Consumer-group and load-balancing code
carries ZERO outage awareness: replicas only ever see the current
membership, broker writers only their current pacing config, and
nobody asks "am I down?".

Fault kinds (``FaultEvent.action``):
  * ``kill`` / ``revive``       — replica consumers. Kill is abrupt:
    the victim's partitions rebalance onto the survivors and every
    record it held in flight is re-enqueued for the new owner with a
    logged ``requeue`` event (never dropped — five-way tax attribution
    must keep summing to 1 through a fault). Revive joins a FRESH
    member through the normal generation-stamped join path.
  * ``stall`` / ``restore``     — broker write channels. A stalled
    channel stops draining its inbox; restore replays the deferred
    writes at the modeled pacing.
  * ``drive_drop`` / ``drive_restore`` — remove/return one drive from
    a broker's ``BrokerConfig``, shifting its storage write capacity
    (and therefore the stability knee) mid-run.

The same ``FaultPlan`` drives both execution engines from one
``ClusterSpec.fault_plan``: ``FaultEngine.run_live`` applies it to a
``ServingCluster`` on the wall clock (model-time event stamps divided
by ``time_compression``), while ``ClusterSim`` pushes the events into
its heap and applies them in simulated time. Timelines are plain data
(seeded when generated via :meth:`FaultPlan.random`), so same-seed
runs are bit-identical — the determinism the golden fixtures pin.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass

ACTIONS = ("kill", "revive", "stall", "restore",
           "drive_drop", "drive_restore")

# paired down/up actions (used by plan generation + validation)
_PAIRS = {"kill": "revive", "stall": "restore",
          "drive_drop": "drive_restore"}


@dataclass(frozen=True)
class FaultEvent:
    """One planned transition at model time ``t``.

    ``target`` selects the victim: for ``kill`` it is a RANK into the
    sorted list of currently-alive members (not a name — names differ
    between runtimes; rank is stable and deterministic in both), for
    broker actions it is a broker id (``None`` = every broker).
    """
    t: float
    action: str
    target: int | None = None

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action: {self.action!r}")
        if self.t < 0:
            raise ValueError("fault time must be >= 0")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, time-sorted fault timeline."""
    events: tuple = ()

    def __post_init__(self):
        evs = tuple(self.events)
        if any(not isinstance(e, FaultEvent) for e in evs):
            raise TypeError("FaultPlan takes FaultEvent entries")
        if any(b.t < a.t for a, b in zip(evs, evs[1:])):
            raise ValueError("fault events must be time-sorted")
        object.__setattr__(self, "events", evs)

    def __bool__(self) -> bool:
        return bool(self.events)

    @property
    def horizon(self) -> float:
        return self.events[-1].t if self.events else 0.0

    # ---- canned scenarios ---------------------------------------------------

    @classmethod
    def kill_revive(cls, t_kill: float, t_revive: float,
                    n: int = 1, rank: int = 0) -> "FaultPlan":
        """Kill ``n`` replicas at ``t_kill``, revive ``n`` at ``t_revive``.

        Kills apply sequentially, each picking the current rank-th
        alive member — killing rank 0 ``n`` times removes the n lowest
        members deterministically.
        """
        if t_revive <= t_kill:
            raise ValueError("revive must follow kill")
        return cls(tuple(FaultEvent(t_kill, "kill", rank)
                         for _ in range(n))
                   + tuple(FaultEvent(t_revive, "revive")
                           for _ in range(n)))

    @classmethod
    def drive_drop(cls, t_drop: float, t_restore: float | None = None,
                   broker: int | None = None) -> "FaultPlan":
        """Drop one drive (all brokers by default); optionally restore."""
        evs = [FaultEvent(t_drop, "drive_drop", broker)]
        if t_restore is not None:
            if t_restore <= t_drop:
                raise ValueError("restore must follow drop")
            evs.append(FaultEvent(t_restore, "drive_restore", broker))
        return cls(tuple(evs))

    @classmethod
    def stall(cls, t_stall: float, t_restore: float,
              broker: int | None = 0) -> "FaultPlan":
        """Stall a broker's write channel for a window."""
        if t_restore <= t_stall:
            raise ValueError("restore must follow stall")
        return cls((FaultEvent(t_stall, "stall", broker),
                    FaultEvent(t_restore, "restore", broker)))

    @classmethod
    def random(cls, seed: int, horizon: float, n_faults: int = 3,
               kinds: tuple = ("kill", "stall", "drive_drop"),
               n_brokers: int = 3) -> "FaultPlan":
        """A seeded random timeline of paired down/up windows.

        Deterministic in its arguments (one ``random.Random(seed)``,
        no module-level RNG): same seed → bit-identical timeline, the
        property the determinism tests pin. Outage windows start in
        the middle 60% of the horizon and last 5–20% of it, so every
        fault leaves room to recover inside the run.
        """
        rng = random.Random(seed)
        evs: list[FaultEvent] = []
        for _ in range(n_faults):
            kind = kinds[rng.randrange(len(kinds))]
            t0 = (0.2 + 0.6 * rng.random()) * horizon
            t1 = min(horizon, t0 + (0.05 + 0.15 * rng.random()) * horizon)
            target = (rng.randrange(4) if kind == "kill"
                      else rng.randrange(n_brokers))
            evs.append(FaultEvent(t0, kind, target))
            evs.append(FaultEvent(t1, _PAIRS[kind],
                                  None if kind == "kill" else target))
        evs.sort(key=lambda e: (e.t, ACTIONS.index(e.action),
                                -1 if e.target is None else e.target))
        return cls(tuple(evs))


# single victim-selection rule, shared with the DES (which lives in
# repro.core and cannot import this package)
from repro.core.broker import pick_victim  # noqa: E402  (re-export)


@dataclass
class AppliedFault:
    """One transition as it actually landed (model time + victim)."""
    t: float
    action: str
    target: object = None


class FaultEngine:
    """Owns one timeline and applies it to a live ``ServingCluster``.

    The engine is the ONLY code that knows outages exist: it mutates
    membership through the group's ordinary ``join``/``leave`` path and
    flips broker-writer state, then gets out of the way — replicas and
    producers keep reading the same shared maps they always read.
    ``applied`` records each transition at the model time it landed,
    which is what the recovery metrics window on.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.applied: list[AppliedFault] = []
        self._base_drives: dict[int, int] = {}

    # ---- live runtime -------------------------------------------------------

    def run_live(self, cluster) -> None:
        """Blocking runner (spawn in a thread): sleep to each event's
        wall time, apply, repeat. Exits at the cluster deadline."""
        sp = cluster.spec
        for ev in self.plan.events:
            wall = cluster.t0 + ev.t / sp.time_compression
            while True:
                now = time.perf_counter()
                if now >= cluster.wall_deadline:
                    return
                if now >= wall:
                    break
                time.sleep(min(0.005, wall - now))
            self.apply_live(cluster, ev)

    def apply_live(self, cluster, ev: FaultEvent) -> None:
        t = cluster._now_model()
        if ev.action == "kill":
            victim = pick_victim(cluster.group.members, ev.target)
            if victim is not None:
                cluster.kill_replica(victim)
            self.applied.append(AppliedFault(t, "kill", victim))
        elif ev.action == "revive":
            self.applied.append(
                AppliedFault(t, "revive", cluster.add_replica()))
        elif ev.action in ("stall", "restore"):
            for b, w in self._writers(cluster, ev.target):
                (w.stalled.set if ev.action == "stall"
                 else w.stalled.clear)()
            self.applied.append(AppliedFault(t, ev.action, ev.target))
        elif ev.action in ("drive_drop", "drive_restore"):
            delta = -1 if ev.action == "drive_drop" else 1
            for b, w in self._writers(cluster, ev.target):
                base = self._base_drives.setdefault(
                    b, w.cfg.drives_per_broker)
                w.set_drives(min(base, max(
                    1, w.cfg.drives_per_broker + delta)))
            self.applied.append(AppliedFault(t, ev.action, ev.target))

    @staticmethod
    def _writers(cluster, target: int | None):
        ws = cluster.topic.writers
        if target is None:
            return list(enumerate(ws))
        return [(target % len(ws), ws[target % len(ws)])]
