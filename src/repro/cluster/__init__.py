"""Multi-replica serving cluster (paper §5 at deployment scale).

The live analogue of the DES: N replica consumers behind the
Kafka-model ``Topic``/``BrokerConfig`` substrate, partition-aware (max
one consumer per partition, rebalance on replica add/remove), fed by
open- or closed-loop load generators, with per-request tail-latency
percentiles, per-resource utilization, and admission/backpressure — all
instrumented through the same ``EventLog``/``ai_tax`` machinery as the
single-replica pipeline.

Modules:
  * ``scheduler`` — consumer-group partition assignment + rebalance;
  * ``topic``     — live partitions + paced broker write channels;
  * ``loadgen``   — open-loop (periodic/Poisson) and closed-loop load;
  * ``metrics``   — percentiles, tail-latency SLOs, recovery windows;
  * ``cluster``   — the ServingCluster runtime tying them together;
  * ``faults``    — central fault-injection engine: one deterministic
    timeline (kill/revive, stall/restore, drive drop) driving both the
    live cluster and the DES;
  * ``autoscaler`` — queue-depth/SLO-driven elastic replica count
    (hysteresis + cooldown) through the same join/leave path;
  * ``reliability`` — deadline-aware request lifecycle: retry/hedge
    policies, per-target circuit breakers, graceful-degradation ladder
    (shared with the DES, duck-typed through the spec);
  * ``trace``     — versioned JSONL workload traces + the replay
    producer that paces them into live broker topics;
  * ``scenarios`` — the trace library (diurnal, flash crowd, skewed
    camera fleet, burst/drain) with per-shape stress-signature checks;
  * ``crossval``  — measured-vs-modeled knee comparison (live / DES /
    closed-form) and the digital-twin loop: windowed live-vs-DES
    tail/tax agreement per scenario, DES results cached per
    (spec, trace) — ``benchmarks/fig_scenarios.py`` gates it.
"""
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig, ScaleAction
from repro.cluster.cluster import ClusterResult, ClusterSpec, ServingCluster
from repro.cluster.crossval import (KneeComparison, ReliabilityAgreement,
                                    TwinCache, TwinReport, WindowComparison,
                                    des_twin_summary, knee_comparison,
                                    live_twin_summary, reliability_agreement,
                                    scenario_knee, spec_key, twin_compare)
from repro.cluster.faults import FaultEngine, FaultEvent, FaultPlan
from repro.cluster.loadgen import (ClosedLoopLoadGen, OpenLoopLoadGen,
                                   rng_fingerprint)
from repro.cluster.metrics import (LatencyStats, RecoveryReport,
                                   ReliabilityReport, SLOReport, TailSLO,
                                   recovery_report, reliability_report)
from repro.cluster.reliability import (BreakerConfig, CircuitBreaker,
                                       DegradeLevel, DegradePolicy,
                                       RetryPolicy)
from repro.cluster.scenarios import (SCENARIOS, Scenario, build_trace,
                                     scenario_spec)
from repro.cluster.scheduler import ConsumerGroup
from repro.cluster.trace import (DEFAULT_PAYLOAD_BYTES, TraceError,
                                 TraceEvent, TraceReplayProducer,
                                 WorkloadTrace, record_loadgen)

__all__ = [
    "ClusterResult", "ClusterSpec", "ServingCluster",
    "KneeComparison", "knee_comparison",
    "ReliabilityAgreement", "reliability_agreement",
    "TwinCache", "TwinReport", "WindowComparison", "twin_compare",
    "des_twin_summary", "live_twin_summary", "scenario_knee", "spec_key",
    "SCENARIOS", "Scenario", "build_trace", "scenario_spec",
    "DEFAULT_PAYLOAD_BYTES", "TraceError", "TraceEvent",
    "TraceReplayProducer", "WorkloadTrace", "record_loadgen",
    "rng_fingerprint",
    "FaultEngine", "FaultEvent", "FaultPlan",
    "Autoscaler", "AutoscalerConfig", "ScaleAction",
    "BreakerConfig", "CircuitBreaker", "DegradeLevel", "DegradePolicy",
    "RetryPolicy",
    "ClosedLoopLoadGen", "OpenLoopLoadGen",
    "LatencyStats", "RecoveryReport", "ReliabilityReport", "SLOReport",
    "TailSLO", "recovery_report", "reliability_report",
    "ConsumerGroup",
]
