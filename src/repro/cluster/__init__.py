"""Multi-replica serving cluster (paper §5 at deployment scale).

The live analogue of the DES: N replica consumers behind the
Kafka-model ``Topic``/``BrokerConfig`` substrate, partition-aware (max
one consumer per partition, rebalance on replica add/remove), fed by
open- or closed-loop load generators, with per-request tail-latency
percentiles, per-resource utilization, and admission/backpressure — all
instrumented through the same ``EventLog``/``ai_tax`` machinery as the
single-replica pipeline.

Modules:
  * ``scheduler`` — consumer-group partition assignment + rebalance;
  * ``topic``     — live partitions + paced broker write channels;
  * ``loadgen``   — open-loop (periodic/Poisson) and closed-loop load;
  * ``metrics``   — percentiles, tail-latency SLOs, recovery windows;
  * ``cluster``   — the ServingCluster runtime tying them together;
  * ``faults``    — central fault-injection engine: one deterministic
    timeline (kill/revive, stall/restore, drive drop) driving both the
    live cluster and the DES;
  * ``autoscaler`` — queue-depth/SLO-driven elastic replica count
    (hysteresis + cooldown) through the same join/leave path;
  * ``reliability`` — deadline-aware request lifecycle: retry/hedge
    policies, per-target circuit breakers, graceful-degradation ladder
    (shared with the DES, duck-typed through the spec);
  * ``crossval``  — measured-vs-modeled knee comparison (live / DES /
    closed-form), the loop ``benchmarks/fig_cluster_scaling.py`` plots.
"""
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig, ScaleAction
from repro.cluster.cluster import ClusterResult, ClusterSpec, ServingCluster
from repro.cluster.crossval import (KneeComparison, ReliabilityAgreement,
                                    knee_comparison, reliability_agreement)
from repro.cluster.faults import FaultEngine, FaultEvent, FaultPlan
from repro.cluster.loadgen import ClosedLoopLoadGen, OpenLoopLoadGen
from repro.cluster.metrics import (LatencyStats, RecoveryReport,
                                   ReliabilityReport, SLOReport, TailSLO,
                                   recovery_report, reliability_report)
from repro.cluster.reliability import (BreakerConfig, CircuitBreaker,
                                       DegradeLevel, DegradePolicy,
                                       RetryPolicy)
from repro.cluster.scheduler import ConsumerGroup

__all__ = [
    "ClusterResult", "ClusterSpec", "ServingCluster",
    "KneeComparison", "knee_comparison",
    "ReliabilityAgreement", "reliability_agreement",
    "FaultEngine", "FaultEvent", "FaultPlan",
    "Autoscaler", "AutoscalerConfig", "ScaleAction",
    "BreakerConfig", "CircuitBreaker", "DegradeLevel", "DegradePolicy",
    "RetryPolicy",
    "ClosedLoopLoadGen", "OpenLoopLoadGen",
    "LatencyStats", "RecoveryReport", "ReliabilityReport", "SLOReport",
    "TailSLO", "recovery_report", "reliability_report",
    "ConsumerGroup",
]
