"""Live partitions + paced broker write channels.

The in-process realization of ``repro.core.broker``'s Kafka model: a
``LiveTopic`` holds one thread-safe queue per partition, and one writer
thread per broker that paces leader writes at the configured storage
capacity (``BrokerConfig.write_time``). Pacing uses absolute deadlines
(``free_at``), so sleep overshoot does not accumulate — a saturated
channel delivers at exactly the modeled bandwidth, which is what lets
the live knee line up with the DES and the closed form.

All modeled durations are divided by the cluster's ``time_compression``
factor: one model second takes ``1/c`` wall seconds, shrinking a 10 s
experiment to a test-sized run while preserving every demand/capacity
ratio (and therefore the stability knee).
"""
from __future__ import annotations

import queue
import threading
import time

from repro.core.broker import BrokerConfig, Message


class LivePartition:
    """One partition: FIFO queue + counters.

    ``produced``/``bytes_in`` are written only by the leader broker's
    writer thread, ``consumed`` only by the partition's (single, per
    the group invariant) consumer — so the counters need no locks.
    """

    def __init__(self, topic: str, index: int, leader: int):
        self.topic = topic
        self.index = index
        self.leader = leader
        self.queue: queue.Queue = queue.Queue()
        self.accepted = 0       # admitted at publish (incl. unwritten)
        self.produced = 0       # leader write finished
        self.consumed = 0
        self.bytes_in = 0.0

    def deliver(self, msg: Message) -> None:
        # single-writer invariant: only the partition's leader
        # BrokerWriter thread calls deliver
        self.produced += 1   # lint: waive race-check -- leader BrokerWriter is the only writer; readers tolerate staleness
        self.bytes_in += msg.size  # lint: waive race-check -- same single-leader-writer invariant as produced
        self.queue.put(msg)

    @property
    def in_flight(self) -> int:
        """Admitted but unconsumed — the quantity admission bounds.
        Counts messages still sitting in the broker write channel, so
        backpressure engages when STORAGE (not just the consumer) is
        the backlog point."""
        return self.accepted - self.consumed


class BrokerWriter(threading.Thread):
    """Leader write channel for one broker, paced at storage capacity."""

    def __init__(self, broker_id: int, cfg: BrokerConfig, compress: float,
                 deadline: float):
        super().__init__(daemon=True, name=f"broker-{broker_id}")
        self.broker_id = broker_id
        self.cfg = cfg
        self.compress = compress
        self.deadline = deadline          # wall perf_counter time
        self.inbox: queue.Queue = queue.Queue()
        self.free_at = 0.0
        self.busy = 0.0                   # wall seconds the channel served
        self.bytes = 0.0
        # fault-engine hooks: a stalled channel stops draining its inbox
        # (records pile up and replay at pacing once cleared); set_drives
        # swaps the pacing config mid-run. The serve loop below reads
        # self.cfg fresh per chunk, so neither needs its cooperation.
        self.stalled = threading.Event()
        self._base_drives = cfg.drives_per_broker

    CHUNK = 128

    def set_drives(self, n: int) -> None:
        """Repace the channel at ``n`` drives (fault engine only)."""
        from dataclasses import replace
        n = max(1, min(n, self._base_drives))
        # atomic reference swap by design: run() re-reads self.cfg per
        # chunk, so a degraded channel takes effect at the next write
        self.cfg = replace(self.cfg, drives_per_broker=n)  # lint: waive race-check -- immutable-config swap; run() reads cfg fresh each chunk

    def drop_drive(self) -> None:
        self.set_drives(self.cfg.drives_per_broker - 1)

    def restore_drive(self) -> None:
        self.set_drives(self.cfg.drives_per_broker + 1)

    def run(self) -> None:
        while True:
            now = time.perf_counter()
            if now >= self.deadline:
                return
            if self.stalled.is_set():
                time.sleep(0.002)
                continue
            try:
                chunk = [self.inbox.get(
                    timeout=min(0.02, self.deadline - now))]
            except queue.Empty:
                continue
            # drain whatever else is queued: one sleep paces the whole
            # chunk, so the ~1 ms sleep-overshoot on this container is
            # amortized instead of taxing every record (a per-record
            # sleep silently halves effective write bandwidth). The
            # absolute free_at deadline self-corrects residual drift.
            while len(chunk) < self.CHUNK:
                try:
                    chunk.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            dur = sum(self.cfg.write_time(m.size)
                      for _, m in chunk) / self.compress
            start = max(time.perf_counter(), self.free_at)
            # run() is the writer thread itself; these are its private
            # pacing/throughput counters, read only after join()
            self.free_at = start + dur  # lint: waive race-check -- owned by this writer thread; read after join
            self.busy += dur  # lint: waive race-check -- owned by this writer thread; read after join
            self.bytes += sum(  # lint: waive race-check -- owned by this writer thread; read after join
                m.size + self.cfg.write_overhead_bytes for _, m in chunk)
            delay = self.free_at - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            tw = time.perf_counter()
            for part, msg in chunk:
                msg.t_written = tw
                part.deliver(msg)


class LiveTopic:
    """Partitioned topic over per-broker paced write channels."""

    def __init__(self, name: str, n_partitions: int, cfg: BrokerConfig,
                 compress: float, deadline: float):
        self.name = name
        self.cfg = cfg
        self.partitions = [
            LivePartition(name, i, cfg.leader_for(i))
            for i in range(n_partitions)]
        self.writers = [BrokerWriter(b, cfg, compress, deadline)
                        for b in range(cfg.n_brokers)]
        self._rr = 0
        self._rr_lock = threading.Lock()

    def start(self) -> None:
        for w in self.writers:
            w.start()

    def join(self) -> None:
        for w in self.writers:
            w.join()

    def pick_partition(self) -> LivePartition:
        with self._rr_lock:
            p = self.partitions[self._rr % len(self.partitions)]
            self._rr += 1
            return p

    def publish(self, msg: Message, part: LivePartition | None = None) -> None:
        """Hand the message to its leader's write channel (async write)."""
        if part is None:
            part = self.pick_partition()
        self.writers[part.leader].inbox.put((part, msg))

    def backlog(self) -> int:
        """Messages accepted but not yet consumed (incl. unwritten)."""
        unwritten = sum(w.inbox.qsize() for w in self.writers)
        return unwritten + sum(p.queue.qsize() for p in self.partitions)

    def write_utilization(self, span_wall: float) -> float:
        """Mean busy fraction of the broker write channels."""
        if span_wall <= 0 or not self.writers:
            return 0.0
        return sum(w.busy for w in self.writers) / (
            len(self.writers) * span_wall)
