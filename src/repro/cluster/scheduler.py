"""Consumer-group partition scheduler (Kafka's group protocol, in-process).

Invariants (the ones the broker model in ``repro.core.broker`` states
and the DES assumes):
  * at most ONE consumer owns a partition at any generation;
  * every partition is owned whenever the group is non-empty;
  * ownership is range-assigned over the sorted member list, so
    assignment is deterministic in (members, n_partitions) — no RNG.

A rebalance bumps the ``generation``; replicas read their assignment
at the top of each sweep and re-check the generation before every
partition fetch, restarting the sweep when it moved — so the overlap
window during a rebalance shrinks to a serve already in flight (the
same cooperative-rebalance window a Kafka consumer group has), and
held-back records for revoked partitions are requeued for the new
owner.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.core.broker import range_assignment


@dataclass
class Assignment:
    generation: int
    partitions: tuple


class ConsumerGroup:
    """Thread-safe membership + range partition assignment."""

    def __init__(self, n_partitions: int):
        if n_partitions < 1:
            raise ValueError("need at least one partition")
        self.n_partitions = n_partitions
        self._members: list[str] = []
        self._lock = threading.Lock()
        self.generation = 0
        self.rebalances = 0
        self._table: dict[str, tuple] = {}

    # ---- membership --------------------------------------------------------

    def join(self, member: str) -> Assignment:
        with self._lock:
            if member not in self._members:
                self._members.append(member)
                self._rebalance()
            return self._assignment(member)

    def leave(self, member: str) -> None:
        with self._lock:
            if member in self._members:
                self._members.remove(member)
                self._rebalance()

    @property
    def members(self) -> list[str]:
        with self._lock:
            return list(self._members)

    # ---- assignment --------------------------------------------------------

    def _rebalance(self) -> None:
        """Range assignment over the sorted member list (lock held)."""
        self.generation += 1
        self.rebalances += 1
        self._table = range_assignment(self._members, self.n_partitions)

    def _assignment(self, member: str) -> Assignment:
        return Assignment(self.generation, self._table.get(member, ()))

    def assignment(self, member: str) -> Assignment:
        """The member's current partitions, stamped with the generation."""
        with self._lock:
            return self._assignment(member)

    def check_fence(self, member: str, partition: int,
                    generation: int) -> bool:
        """Generation fence for a write/commit attempt.

        True only when ``generation`` is the CURRENT generation and
        ``member`` owns ``partition`` in it — a write stamped with any
        older generation is rejected, so a zombie consumer that was
        rebalanced away (or killed by the fault engine) can never
        commit against a partition it no longer owns.
        """
        with self._lock:
            return (generation == self.generation
                    and partition in self._table.get(member, ()))

    def owner_of(self, partition: int) -> str | None:
        with self._lock:
            for member, parts in self._table.items():
                if partition in parts:
                    return member
        return None

    def table(self) -> dict[str, tuple]:
        with self._lock:
            return dict(self._table)
