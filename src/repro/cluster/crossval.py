"""Measured-vs-modeled stability knee (the §5.3-5.4 closed loop).

Three independent estimates of the acceleration factor S at which one
deployment configuration destabilizes:

  * closed form — smallest S with any resource rho >= 1
    (``queueing.stability_knee``; exact, instantaneous);
  * DES — bisection on ``SimResult.diverged``, the *measured-only*
    queue-growth signal (no analytic escape hatch, or the agreement
    with the closed form would be circular);
  * live — bisection on ``ClusterResult.diverged`` from real
    ``ServingCluster`` runs (real threads, real clock).

Tolerances (documented here, asserted in ``tests/test_cluster.py`` and
printed by ``benchmarks/fig_cluster_scaling.py``): divergence detectors
need a finite observation window, so a run at rho barely above 1 can
look stable — both measured knees land ON OR ABOVE the closed form's
and within ``DES_TOL`` / ``LIVE_TOL`` relative error of it. The live
bound is looser because sleep-granularity jitter adds real noise on a
busy container.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

DES_TOL = 0.25
LIVE_TOL = 0.35


def find_knee(diverged, lo: float, hi: float, iters: int = 6) -> float:
    """Bisection for the smallest diverging S; assumes monotonicity.

    ``diverged(s) -> bool`` runs one experiment. Returns the bracket
    midpoint after ``iters`` refinements (resolution (hi-lo)/2^iters).
    Endpoint returns are BOUNDS, not located knees: ``lo`` back means
    the knee is at or below the bracket (already diverging at lo),
    ``hi`` back means divergence was never observed (knee >= hi).
    Consumers comparing a knee against a model must sanity-check it
    against that model (the benchmark and tests gate on
    DES_TOL/LIVE_TOL) rather than trust an endpoint as a measurement.
    """
    if diverged(lo):
        return lo
    if not diverged(hi):
        return hi
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if diverged(mid):
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


def des_knee(spec, lo: float | None = None, hi: float | None = None,
             iters: int = 6, sim_time: float = 20.0,
             warmup: float = 4.0) -> float:
    """DES-measured knee for a ClusterSpec (divergence = queue growth)."""
    closed = spec.closed_form_knee()
    lo = 0.4 * closed if lo is None else lo
    hi = 2.0 * closed if hi is None else hi

    def diverged(s: float) -> bool:
        return spec.des_sim(speedup=s, sim_time=sim_time,
                            warmup=warmup).run().diverged

    return find_knee(diverged, lo, hi, iters)


def live_knee(spec, lo: float | None = None, hi: float | None = None,
              iters: int = 4) -> float:
    """Live-cluster-measured knee (each probe is a real timed run).

    A "diverged" verdict is confirmed by a second run before the
    bisection trusts it: transient CPU contention on a shared box can
    make one stable run look saturated, and a single false positive
    would drag the whole bracket down. (False "stable" needs no
    confirmation — contention only ever pushes toward divergence.)
    """
    from repro.cluster.cluster import ServingCluster
    closed = spec.closed_form_knee()
    lo = 0.4 * closed if lo is None else lo
    hi = 2.0 * closed if hi is None else hi

    def diverged(s: float) -> bool:
        first = ServingCluster(replace(spec, speedup=s)).run().diverged
        if not first:
            return False
        return ServingCluster(replace(spec, speedup=s)).run().diverged

    return find_knee(diverged, lo, hi, iters)


@dataclass
class KneeComparison:
    n_replicas: int
    drives_per_broker: int
    closed_form: float
    des: float
    live: float | None = None

    def rel_err(self, measured: float) -> float:
        return abs(measured - self.closed_form) / self.closed_form

    @property
    def agree(self) -> bool:
        ok = self.rel_err(self.des) <= DES_TOL
        if self.live is not None:
            ok = ok and self.rel_err(self.live) <= LIVE_TOL
        return ok

    def row(self) -> str:
        live = "-" if self.live is None else f"{self.live:.1f}"
        return (f"R{self.n_replicas}_d{self.drives_per_broker}:"
                f"closed={self.closed_form:.1f};des={self.des:.1f};"
                f"live={live};agree={self.agree}")


def knee_comparison(spec, include_live: bool = True,
                    des_iters: int = 6, live_iters: int = 4,
                    ) -> KneeComparison:
    """All three knees for one deployment configuration."""
    return KneeComparison(
        n_replicas=spec.n_replicas,
        drives_per_broker=spec.bk.drives_per_broker,
        closed_form=spec.closed_form_knee(),
        des=des_knee(spec, iters=des_iters),
        live=live_knee(spec, iters=live_iters) if include_live else None)


@dataclass
class FaultKnees:
    """Knee movement under a persistent degradation.

    ``closed_healthy``/``closed_degraded`` price the spec analytically
    before and after the fault (e.g. one fewer drive per broker);
    ``des_degraded`` measures the degraded knee by bisection on DES
    runs that carry the fault plan for their WHOLE horizon — the
    cross-validation the fig_fault_recovery benchmark gates on.
    """
    closed_healthy: float
    closed_degraded: float
    des_degraded: float

    @property
    def agree(self) -> bool:
        return (abs(self.des_degraded - self.closed_degraded)
                / self.closed_degraded) <= DES_TOL

    def row(self) -> str:
        return (f"closed={self.closed_healthy:.1f}"
                f"->degraded={self.closed_degraded:.1f};"
                f"des={self.des_degraded:.1f};agree={self.agree}")


@dataclass
class ReliabilityAgreement:
    """Live-vs-DES agreement on the reliability-tax quantities.

    One spec — same fault plan, same retry/breaker/degrade policies —
    runs through both execution engines; agreement is gated on the two
    quantities the reliability layer exists to control: goodput
    (client-visible value rate) and retry amplification (cluster-
    carried load per offered request). Both are gated at ``DES_TOL``
    relative error — unlike the knee comparison there is no analytic
    third referee here, so the DES tolerance IS the contract between
    the engines.
    """
    des_goodput: float
    live_goodput: float
    des_amplification: float
    live_amplification: float

    @staticmethod
    def _err(live: float, des: float) -> float:
        return abs(live - des) / max(abs(des), 1e-9)

    @property
    def goodput_err(self) -> float:
        return self._err(self.live_goodput, self.des_goodput)

    @property
    def amplification_err(self) -> float:
        return self._err(self.live_amplification, self.des_amplification)

    @property
    def agree(self) -> bool:
        return (self.goodput_err <= DES_TOL
                and self.amplification_err <= DES_TOL)

    def row(self) -> str:
        return (f"goodput:des={self.des_goodput:.1f};"
                f"live={self.live_goodput:.1f};"
                f"err={self.goodput_err:.2f}|"
                f"amp:des={self.des_amplification:.2f};"
                f"live={self.live_amplification:.2f};"
                f"err={self.amplification_err:.2f}|agree={self.agree}")


def reliability_agreement(spec) -> ReliabilityAgreement:
    """Run one reliability spec through both engines and compare.

    ``spec`` must carry a retry policy (else neither engine produces a
    reliability report); the fault plan and breaker/degrade policies
    ride along identically. The DES run uses the spec's own
    sim_time/warmup so both engines observe the same horizon.
    """
    from repro.cluster.cluster import ServingCluster
    if spec.retry is None:
        raise ValueError("reliability_agreement needs spec.retry set")
    live = ServingCluster(spec).run().reliability
    des = spec.des_sim(sim_time=spec.sim_time,
                       warmup=spec.warmup).run().reliability
    return ReliabilityAgreement(
        des_goodput=des["goodput"], live_goodput=live["goodput"],
        des_amplification=des["amplification"],
        live_amplification=live["amplification"])


def fault_knees(spec, fault_plan, degraded_spec,
                iters: int = 5, sim_time: float = 20.0,
                warmup: float = 4.0) -> FaultKnees:
    """Where the stability knee sits while a fault persists.

    ``degraded_spec`` is the healthy spec with the fault's effect
    applied statically (drives removed, replicas reduced) — its closed
    form is the analytic target. The DES probe runs the healthy spec
    WITH ``fault_plan`` (fault applied early, never repaired), so the
    measured knee comes from the dynamic fault machinery, not from a
    statically reconfigured sim — that non-circularity is the point.
    """
    closed_h = spec.closed_form_knee()
    closed_d = degraded_spec.closed_form_knee()
    probe = replace(spec, fault_plan=fault_plan)

    def diverged(s: float) -> bool:
        return probe.des_sim(speedup=s, sim_time=sim_time,
                             warmup=warmup).run().diverged

    des_d = find_knee(diverged, 0.4 * closed_d, 2.0 * closed_d, iters)
    return FaultKnees(closed_healthy=closed_h, closed_degraded=closed_d,
                      des_degraded=des_d)
