"""Measured-vs-modeled stability knee (the §5.3-5.4 closed loop).

Three independent estimates of the acceleration factor S at which one
deployment configuration destabilizes:

  * closed form — smallest S with any resource rho >= 1
    (``queueing.stability_knee``; exact, instantaneous);
  * DES — bisection on ``SimResult.diverged``, the *measured-only*
    queue-growth signal (no analytic escape hatch, or the agreement
    with the closed form would be circular);
  * live — bisection on ``ClusterResult.diverged`` from real
    ``ServingCluster`` runs (real threads, real clock).

Tolerances (documented here, asserted in ``tests/test_cluster.py`` and
printed by ``benchmarks/fig_cluster_scaling.py``): divergence detectors
need a finite observation window, so a run at rho barely above 1 can
look stable — both measured knees land ON OR ABOVE the closed form's
and within ``DES_TOL`` / ``LIVE_TOL`` relative error of it. The live
bound is looser because sleep-granularity jitter adds real noise on a
busy container.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field, fields, is_dataclass, replace

DES_TOL = 0.25
LIVE_TOL = 0.35


def find_knee(diverged, lo: float, hi: float, iters: int = 6) -> float:
    """Bisection for the smallest diverging S; assumes monotonicity.

    ``diverged(s) -> bool`` runs one experiment. Returns the bracket
    midpoint after ``iters`` refinements (resolution (hi-lo)/2^iters).
    Endpoint returns are BOUNDS, not located knees: ``lo`` back means
    the knee is at or below the bracket (already diverging at lo),
    ``hi`` back means divergence was never observed (knee >= hi).
    Consumers comparing a knee against a model must sanity-check it
    against that model (the benchmark and tests gate on
    DES_TOL/LIVE_TOL) rather than trust an endpoint as a measurement.
    """
    if diverged(lo):
        return lo
    if not diverged(hi):
        return hi
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if diverged(mid):
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


def des_knee(spec, lo: float | None = None, hi: float | None = None,
             iters: int = 6, sim_time: float = 20.0,
             warmup: float = 4.0) -> float:
    """DES-measured knee for a ClusterSpec (divergence = queue growth)."""
    closed = spec.closed_form_knee()
    lo = 0.4 * closed if lo is None else lo
    hi = 2.0 * closed if hi is None else hi

    def diverged(s: float) -> bool:
        return spec.des_sim(speedup=s, sim_time=sim_time,
                            warmup=warmup).run().diverged

    return find_knee(diverged, lo, hi, iters)


def live_knee(spec, lo: float | None = None, hi: float | None = None,
              iters: int = 4) -> float:
    """Live-cluster-measured knee (each probe is a real timed run).

    A "diverged" verdict is confirmed by a second run before the
    bisection trusts it: transient CPU contention on a shared box can
    make one stable run look saturated, and a single false positive
    would drag the whole bracket down. (False "stable" needs no
    confirmation — contention only ever pushes toward divergence.)
    """
    from repro.cluster.cluster import ServingCluster
    closed = spec.closed_form_knee()
    lo = 0.4 * closed if lo is None else lo
    hi = 2.0 * closed if hi is None else hi

    def diverged(s: float) -> bool:
        first = ServingCluster(replace(spec, speedup=s)).run().diverged
        if not first:
            return False
        return ServingCluster(replace(spec, speedup=s)).run().diverged

    return find_knee(diverged, lo, hi, iters)


@dataclass
class KneeComparison:
    n_replicas: int
    drives_per_broker: int
    closed_form: float
    des: float
    live: float | None = None

    def rel_err(self, measured: float) -> float:
        return abs(measured - self.closed_form) / self.closed_form

    @property
    def agree(self) -> bool:
        ok = self.rel_err(self.des) <= DES_TOL
        if self.live is not None:
            ok = ok and self.rel_err(self.live) <= LIVE_TOL
        return ok

    def row(self) -> str:
        live = "-" if self.live is None else f"{self.live:.1f}"
        return (f"R{self.n_replicas}_d{self.drives_per_broker}:"
                f"closed={self.closed_form:.1f};des={self.des:.1f};"
                f"live={live};agree={self.agree}")


def knee_comparison(spec, include_live: bool = True,
                    des_iters: int = 6, live_iters: int = 4,
                    ) -> KneeComparison:
    """All three knees for one deployment configuration."""
    return KneeComparison(
        n_replicas=spec.n_replicas,
        drives_per_broker=spec.bk.drives_per_broker,
        closed_form=spec.closed_form_knee(),
        des=des_knee(spec, iters=des_iters),
        live=live_knee(spec, iters=live_iters) if include_live else None)


@dataclass
class FaultKnees:
    """Knee movement under a persistent degradation.

    ``closed_healthy``/``closed_degraded`` price the spec analytically
    before and after the fault (e.g. one fewer drive per broker);
    ``des_degraded`` measures the degraded knee by bisection on DES
    runs that carry the fault plan for their WHOLE horizon — the
    cross-validation the fig_fault_recovery benchmark gates on.
    """
    closed_healthy: float
    closed_degraded: float
    des_degraded: float

    @property
    def agree(self) -> bool:
        return (abs(self.des_degraded - self.closed_degraded)
                / self.closed_degraded) <= DES_TOL

    def row(self) -> str:
        return (f"closed={self.closed_healthy:.1f}"
                f"->degraded={self.closed_degraded:.1f};"
                f"des={self.des_degraded:.1f};agree={self.agree}")


@dataclass
class ReliabilityAgreement:
    """Live-vs-DES agreement on the reliability-tax quantities.

    One spec — same fault plan, same retry/breaker/degrade policies —
    runs through both execution engines; agreement is gated on the two
    quantities the reliability layer exists to control: goodput
    (client-visible value rate) and retry amplification (cluster-
    carried load per offered request). Both are gated at ``DES_TOL``
    relative error — unlike the knee comparison there is no analytic
    third referee here, so the DES tolerance IS the contract between
    the engines.
    """
    des_goodput: float
    live_goodput: float
    des_amplification: float
    live_amplification: float

    @staticmethod
    def _err(live: float, des: float) -> float:
        return abs(live - des) / max(abs(des), 1e-9)

    @property
    def goodput_err(self) -> float:
        return self._err(self.live_goodput, self.des_goodput)

    @property
    def amplification_err(self) -> float:
        return self._err(self.live_amplification, self.des_amplification)

    @property
    def agree(self) -> bool:
        return (self.goodput_err <= DES_TOL
                and self.amplification_err <= DES_TOL)

    def row(self) -> str:
        return (f"goodput:des={self.des_goodput:.1f};"
                f"live={self.live_goodput:.1f};"
                f"err={self.goodput_err:.2f}|"
                f"amp:des={self.des_amplification:.2f};"
                f"live={self.live_amplification:.2f};"
                f"err={self.amplification_err:.2f}|agree={self.agree}")


def reliability_agreement(spec) -> ReliabilityAgreement:
    """Run one reliability spec through both engines and compare.

    ``spec`` must carry a retry policy (else neither engine produces a
    reliability report); the fault plan and breaker/degrade policies
    ride along identically. The DES run uses the spec's own
    sim_time/warmup so both engines observe the same horizon.
    """
    from repro.cluster.cluster import ServingCluster
    if spec.retry is None:
        raise ValueError("reliability_agreement needs spec.retry set")
    live = ServingCluster(spec).run().reliability
    des = spec.des_sim(sim_time=spec.sim_time,
                       warmup=spec.warmup).run().reliability
    return ReliabilityAgreement(
        des_goodput=des["goodput"], live_goodput=live["goodput"],
        des_amplification=des["amplification"],
        live_amplification=live["amplification"])


def fault_knees(spec, fault_plan, degraded_spec,
                iters: int = 5, sim_time: float = 20.0,
                warmup: float = 4.0) -> FaultKnees:
    """Where the stability knee sits while a fault persists.

    ``degraded_spec`` is the healthy spec with the fault's effect
    applied statically (drives removed, replicas reduced) — its closed
    form is the analytic target. The DES probe runs the healthy spec
    WITH ``fault_plan`` (fault applied early, never repaired), so the
    measured knee comes from the dynamic fault machinery, not from a
    statically reconfigured sim — that non-circularity is the point.
    """
    closed_h = spec.closed_form_knee()
    closed_d = degraded_spec.closed_form_knee()
    probe = replace(spec, fault_plan=fault_plan)

    def diverged(s: float) -> bool:
        return probe.des_sim(speedup=s, sim_time=sim_time,
                             warmup=warmup).run().diverged

    des_d = find_knee(diverged, 0.4 * closed_d, 2.0 * closed_d, iters)
    return FaultKnees(closed_healthy=closed_h, closed_degraded=closed_d,
                      des_degraded=des_d)


# ---- digital-twin loop over a workload trace -------------------------------
#
# One ClusterSpec, ONE resolved trace, BOTH execution engines: the DES
# replays the trace event-by-event, the live cluster replays it through
# real threads on a compressed wall clock. The twin gate compares the
# two runs per heartbeat window — windowed tail latency AND five-way
# tax fractions — at DES_TOL. DES summaries are cached keyed on
# (spec hash, trace hash): a scenario's modeled half runs once per
# spec revision, so the recurring cost of the gate is one live run.


def _canon(obj):
    """Canonical JSON-able form of a spec tree for hashing.

    Dataclasses become sorted field dicts, tuples become lists; any
    leftover object falls back to its repr — stable for the frozen
    policy/config vocabulary the specs are built from.
    """
    if is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _canon(getattr(obj, f.name)) for f in fields(obj)}
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def spec_key(spec) -> str:
    """Stable 16-hex digest of a ClusterSpec, EXCLUDING its trace.

    The trace is priced separately (``WorkloadTrace.trace_hash``) so a
    cache entry key is ``spec_key(spec) + ':' + trace_hash`` — editing
    either the deployment or the workload invalidates the entry, and
    nothing else does.
    """
    d = _canon(spec)
    if isinstance(d, dict):
        d.pop("trace", None)
    blob = json.dumps(d, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class TwinCache:
    """DES-summary cache for the twin loop, keyed (spec hash, trace hash).

    In-memory dict with optional JSON write-through (``path``), so a
    benchmark re-run — same spec, same trace — skips the modeled half
    entirely. ``hits``/``misses`` are exposed so the scenario gate can
    assert the cache actually engaged on the second pass.
    """

    def __init__(self, path=None):
        self.path = path
        self.hits = 0
        self.misses = 0
        self._mem: dict[str, dict] = {}
        if path is not None:
            try:
                with open(path, encoding="utf-8") as fh:
                    self._mem.update(json.load(fh))
            except (OSError, ValueError):
                pass

    def get(self, key: str):
        hit = self._mem.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        return hit

    def put(self, key: str, value: dict) -> None:
        self._mem[key] = value
        if self.path is not None:
            with open(self.path, "w", encoding="utf-8") as fh:
                json.dump(self._mem, fh, sort_keys=True)


def des_twin_summary(spec, q: float = 0.99) -> dict:
    """Run the modeled half of the twin and reduce it to a JSON dict.

    The DES runs at the spec's OWN horizon (``sim_time = horizon``,
    warmup 0) so neither engine gets drain time the other lacks. The
    summary carries exactly what the gate compares: per-window tail
    latency, per-window five-way tax fractions, heartbeat markers, and
    the divergence flag.
    """
    from repro.core import facerec
    from repro.core.metrics import windowed_percentile
    trace = spec.resolve_trace()
    if trace is None:
        raise ValueError("des_twin_summary needs a spec with a trace "
                         "or scenario")
    sim = spec.des_sim(speedup=1.0, sim_time=spec.sim_time, warmup=0.0)
    res = sim.run()
    hb = trace.heartbeat_s
    five = sim.log.windowed_five_way(facerec.stage_category, hb)
    rel = res.reliability or {}
    return {
        "q": q,
        "heartbeat_s": hb,
        "horizon_s": trace.horizon_s,
        "diverged": bool(res.diverged),
        "heartbeats": [[int(k), float(t)] for k, t in sim.heartbeats],
        "windows": [[float(t), float(p), int(n)] for t, p, n in
                    windowed_percentile(sim.completions, q, hb)],
        "five_way": {str(k): {c: float(v) for c, v in row.items()}
                     for k, row in five.items()},
        "reliability": {k: rel[k] for k in
                        ("attempts", "retries", "breaker_sheds",
                         "deadline_misses") if k in rel},
    }


def live_twin_summary(spec, q: float = 0.99) -> dict:
    """Run the physical half of the twin and reduce it the same way."""
    from repro.core import facerec
    from repro.core.metrics import windowed_percentile
    from repro.cluster.cluster import ServingCluster
    trace = spec.resolve_trace()
    if trace is None:
        raise ValueError("live_twin_summary needs a spec with a trace "
                         "or scenario")
    cl = ServingCluster(spec)
    res = cl.run()
    hb = trace.heartbeat_s
    # completion-keyed samples, like the DES's completions list
    comp = []
    for st in cl._replica_states.values():
        comp.extend((tp + lat, lat) for tp, lat in st.latencies)
    comp.sort()
    five = cl.log.windowed_five_way(facerec.stage_category, hb)
    rel = res.reliability or {}
    return {
        "q": q,
        "heartbeat_s": hb,
        "horizon_s": trace.horizon_s,
        "diverged": bool(res.diverged),
        "heartbeats": [[int(k), float(t)] for k, t in cl.heartbeats],
        "windows": [[float(t), float(p), int(n)] for t, p, n in
                    windowed_percentile(comp, q, hb)],
        "five_way": {str(k): {c: float(v) for c, v in row.items()}
                     for k, row in five.items()},
        "reliability": {k: rel[k] for k in
                        ("attempts", "retries", "breaker_sheds",
                         "deadline_misses") if k in rel},
    }


@dataclass
class WindowComparison:
    """Live vs DES over one heartbeat window."""
    t_end: float
    des_p: float
    live_p: float
    des_n: int
    live_n: int
    tax_diff: float          # max abs five-way fraction difference

    @property
    def p_err(self) -> float:
        return abs(self.live_p - self.des_p) / max(abs(self.des_p), 1e-9)

    @property
    def agree(self) -> bool:
        return self.p_err <= DES_TOL and self.tax_diff <= DES_TOL

    def row(self) -> str:
        return (f"t={self.t_end:.2f}:des={self.des_p:.3f};"
                f"live={self.live_p:.3f};err={self.p_err:.2f};"
                f"tax_diff={self.tax_diff:.2f};agree={self.agree}")


@dataclass
class TwinReport:
    """The twin gate's verdict for one (spec, trace) pair.

    ``windows`` covers the heartbeat windows BOTH engines populated
    (>= ``min_window_n`` completions each, inside the trace horizon);
    the gate needs at least two such windows — a comparison with fewer
    says nothing about the shape — and every one of them must agree on
    windowed tail latency AND five-way tax at DES_TOL.
    """
    scenario: str | None
    trace_hash: str
    cached: bool             # DES half came from the TwinCache
    des_diverged: bool
    live_diverged: bool
    windows: list = field(default_factory=list)

    @property
    def agree(self) -> bool:
        return len(self.windows) >= 2 and all(w.agree for w in self.windows)

    @property
    def worst_p_err(self) -> float:
        return max((w.p_err for w in self.windows), default=float("inf"))

    @property
    def worst_tax_diff(self) -> float:
        return max((w.tax_diff for w in self.windows),
                   default=float("inf"))

    def row(self) -> str:
        name = self.scenario or self.trace_hash
        return (f"{name}:windows={len(self.windows)};"
                f"p_err={self.worst_p_err:.2f};"
                f"tax_diff={self.worst_tax_diff:.2f};"
                f"cached={self.cached};agree={self.agree}")


_FIVE_WAY = ("pre", "ai", "post", "transfer", "queue")


def twin_compare(spec, cache: TwinCache | None = None, q: float = 0.99,
                 min_window_n: int = 4) -> TwinReport:
    """One full turn of the digital-twin loop.

    The DES half is served from ``cache`` when the (spec, trace) pair
    was seen before; the live half ALWAYS re-runs — it is the physical
    system under test, the cached model is the twin. Windows past the
    trace horizon (the live cluster books its final in-service batch a
    beat after the deadline) and windows either engine left sparse are
    excluded; divergence flags are reported, not gated — the live
    inflight-growth detector trips on transient spikes (a flash crowd's
    second half) that the DES's longer-lens detector rides out, and the
    per-window latency gate already catches any REAL disagreement.
    """
    trace = spec.resolve_trace()
    if trace is None:
        raise ValueError("twin_compare needs a spec with a trace or "
                         "scenario")
    key = f"{spec_key(spec)}:{trace.trace_hash()}"
    des = cache.get(key) if cache is not None else None
    cached = des is not None
    if des is None:
        des = des_twin_summary(spec, q)
        if cache is not None:
            cache.put(key, des)
    live = live_twin_summary(spec, q)
    hb = trace.heartbeat_s
    dw = {round(t / hb): (p, n) for t, p, n in des["windows"]}
    lw = {round(t / hb): (p, n) for t, p, n in live["windows"]}
    horizon_k = round(trace.horizon_s / hb)
    out = []
    for k in sorted(set(dw) & set(lw)):
        if k > horizon_k:
            continue
        (dp, dn), (lp, ln) = dw[k], lw[k]
        if dn < min_window_n or ln < min_window_n:
            continue
        dfw = des["five_way"].get(str(k - 1), {})
        lfw = live["five_way"].get(str(k - 1), {})
        tax = max(abs(dfw.get(c, 0.0) - lfw.get(c, 0.0))
                  for c in _FIVE_WAY)
        out.append(WindowComparison(t_end=k * hb, des_p=dp, live_p=lp,
                                    des_n=dn, live_n=ln, tax_diff=tax))
    return TwinReport(scenario=getattr(spec, "scenario", None),
                      trace_hash=trace.trace_hash(), cached=cached,
                      des_diverged=des["diverged"],
                      live_diverged=live["diverged"], windows=out)


def scenario_knee(spec, lo: float = 0.25, hi: float = 8.0,
                  iters: int = 5) -> float:
    """Smallest speedup S at which the trace replays stably (DES).

    A trace fixes the offered load, so S only scales service capacity:
    divergence is monotone DECREASING in S and the interesting knee is
    the smallest S that keeps the replay stable — found by bisecting
    ``stable(s)`` with :func:`find_knee` (whose convention is
    False-at-lo / True-at-hi). Endpoint returns are bounds, as ever:
    ``lo`` back means even lo is stable, ``hi`` means nothing was.
    """
    def stable(s: float) -> bool:
        return not spec.des_sim(speedup=s, sim_time=spec.sim_time,
                                warmup=0.0).run().diverged

    return find_knee(stable, lo, hi, iters)
