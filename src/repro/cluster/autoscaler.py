"""Queue-depth / SLO-driven elastic autoscaler for the serving cluster.

One small controller closes the loop the paper leaves open: AI
acceleration moves the stability knee, faults move it again mid-run —
so replica count can't be a constant. The ``Autoscaler`` watches two
signals already measured by both execution engines (per-replica
backlog and recent p99 latency) and emits a replica delta; the caller
applies it through the ordinary generation-stamped join/leave path, so
— exactly like the fault engine — the consumer-group code never learns
that elasticity exists.

Control law (classic hysteresis band + cooldown, the minimum that
cannot oscillate):

  * scale UP by ``step`` when backlog-per-replica exceeds
    ``up_backlog``, or when the recent p99 breaches the SLO;
  * scale DOWN by ``step`` only when backlog-per-replica is below
    ``down_backlog`` AND backlog did not grow since the previous
    observation (never shrink into rising pressure — a just-drained
    queue under a rate that has crossed capacity looks idle for one
    interval) AND the post-removal backlog would still sit under the
    scale-up threshold AND the recent p99 leaves ``slo_margin``
    headroom under the SLO — the guards the "scale-down never
    violates the SLO" test pins;
  * otherwise hold. Any action arms a ``cooldown_s`` timer during
    which the controller holds regardless of the signals, so a
    rebalance's transient spike can't trigger a second action before
    the first one's effect is visible.

The controller is pure state + arithmetic (no threads, no clocks): the
live cluster drives it from a sampling thread on compressed wall time,
the DES drives it from simulated time, and the unit tests drive it
from a fluid-queue model — one control law, three harnesses.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class AutoscalerConfig:
    """Controller constants (frozen so a spec stays hashable/printable).

    ``up_backlog``/``down_backlog`` are per-replica queue depths in
    messages; the dead band between them is the hysteresis. ``slo_p99_s``
    is optional — without it the controller is purely backlog-driven.
    """
    min_replicas: int = 1
    max_replicas: int = 16
    interval_s: float = 0.25          # model-time between decisions
    cooldown_s: float = 1.0           # model-time lockout after an action
    up_backlog: float = 8.0           # per-replica depth that forces growth
    down_backlog: float = 2.0         # per-replica depth that allows shrink
    step: int = 1
    slo_p99_s: float | None = None    # p99 target (model seconds)
    slo_margin: float = 0.8           # shrink only if p99 <= margin * SLO

    def __post_init__(self):
        if self.min_replicas < 1 or self.max_replicas < self.min_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if self.down_backlog >= self.up_backlog:
            raise ValueError("hysteresis band requires down_backlog <"
                             " up_backlog")
        if self.step < 1:
            raise ValueError("step must be >= 1")

    def controller(self) -> "Autoscaler":
        """Factory the DES calls, so ``repro.core`` never has to import
        this module's class by name (duck-typed wiring, layering kept)."""
        return Autoscaler(self)


@dataclass(frozen=True)
class ScaleAction:
    """One applied decision, stamped with the model time it fired."""
    t: float
    delta: int
    n_before: int
    backlog: float
    reason: str


@dataclass
class Autoscaler:
    """The control law. Call :meth:`decide` once per interval."""
    cfg: AutoscalerConfig
    actions: list = field(default_factory=list)
    _last_action_t: float = float("-inf")
    _prev_backlog: float | None = None

    def decide(self, t: float, backlog: float, n_replicas: int,
               p99: float | None = None) -> int:
        """Return the replica delta to apply at model time ``t``.

        ``backlog`` is the total undelivered-message count across the
        topic; ``p99`` the recent-window tail latency when the harness
        has one (``None`` disables the SLO terms for this decision).
        """
        cfg = self.cfg
        rising = (self._prev_backlog is not None
                  and backlog > self._prev_backlog + 1e-9)
        # one sampling thread (the cluster's autoscale loop) drives
        # decide()/ _record(); the controller is single-threaded state
        self._prev_backlog = backlog  # lint: waive race-check -- controller state owned by the single autoscale-loop thread
        if t - self._last_action_t < cfg.cooldown_s:
            return 0
        per = backlog / max(1, n_replicas)
        slo_breach = (cfg.slo_p99_s is not None and p99 is not None
                      and p99 > cfg.slo_p99_s)

        if (per > cfg.up_backlog or slo_breach) \
                and n_replicas < cfg.max_replicas:
            delta = min(cfg.step, cfg.max_replicas - n_replicas)
            self._record(t, delta, n_replicas, backlog,
                         "slo" if slo_breach else "backlog")
            return delta

        if (per < cfg.down_backlog and not rising
                and n_replicas > cfg.min_replicas):
            delta = min(cfg.step, n_replicas - cfg.min_replicas)
            # guards: removing `delta` replicas must not push the
            # per-replica depth over the growth threshold, and the tail
            # must have real SLO headroom — shrink can never be the
            # cause of the next breach.
            if backlog / max(1, n_replicas - delta) > cfg.up_backlog:
                return 0
            if cfg.slo_p99_s is not None:
                if p99 is None or p99 > cfg.slo_margin * cfg.slo_p99_s:
                    return 0
            self._record(t, -delta, n_replicas, backlog, "drain")
            return -delta

        return 0

    def _record(self, t: float, delta: int, n: int, backlog: float,
                reason: str) -> None:
        self.actions.append(ScaleAction(t, delta, n, backlog, reason))
        self._last_action_t = t  # lint: waive race-check -- controller state owned by the single autoscale-loop thread
