"""Scenario library: named workload shapes as replayable traces.

Each scenario is a seeded builder producing a :class:`WorkloadTrace`
for a given horizon — the shapes "AI on the Edge" reports for video
fleets (diurnal day/night swings, flash crowds, skewed per-camera heat,
bursty on/off duty cycles) — plus the stress signature the shape is
EXPECTED to produce, encoded as a check function over a DES run. The
benchmark (``fig_scenarios``) and the golden tests both call the same
checks, so a scenario that stops stressing what it claims to stress
fails loudly in both places.

Rates are tuned against the default ``ClusterSpec`` at S=1: aggregate
consumer capacity ~61 req/s (8 replicas / 131.5 ms identify), single
partition ~7.6 req/s. Shapes that exceed capacity do so transiently
and drain before the horizon, so no scenario trips the divergence
detector on the default spec.

All randomness flows through ``loadgen._rng`` with a per-scenario salt
(``scenario:<name>``): every scenario draws from its own stream space,
independent of the open/closed-loop producers and of every other
scenario — the property the seeding-audit test asserts pairwise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.cluster.loadgen import _rng
from repro.cluster.trace import (DEFAULT_PAYLOAD_BYTES, TraceEvent,
                                 WorkloadTrace)


def _poisson_thinned(rng, horizon_s: float, rate_fn, rate_max: float,
                     ) -> list[float]:
    """Inhomogeneous-Poisson arrivals by thinning a rate_max process."""
    out: list[float] = []
    t = 0.0
    while True:
        t += rng.expovariate(rate_max)
        if t >= horizon_s:
            return out
        if rng.random() * rate_max <= rate_fn(t):
            out.append(t)


def _trace(name: str, horizon_s: float, arrivals, keys=None,
           payload_bytes: float = DEFAULT_PAYLOAD_BYTES) -> WorkloadTrace:
    """Assemble sorted arrivals (+ optional per-arrival keys) into a
    trace; rids are assigned in arrival order so they are unique and
    stable under the engines' event-order replay."""
    if keys is None:
        pairs = sorted((t, None) for t in arrivals)
    else:
        pairs = sorted(zip(arrivals, keys))
    events = tuple(
        TraceEvent(t=t, rid=i, partition_key=k, payload_bytes=payload_bytes)
        for i, (t, k) in enumerate(pairs))
    return WorkloadTrace(name=name, horizon_s=horizon_s,
                         heartbeat_s=horizon_s / 8, events=events)


# ---- builders --------------------------------------------------------------

def diurnal(horizon_s: float = 6.0, seed: int = 0, *,
            base_rate: float = 16.0, peak_rate: float = 76.0,
            ) -> WorkloadTrace:
    """One day/night cycle: trough at the edges, peak mid-horizon.

    The peak deliberately exceeds aggregate capacity (~61/s at S=1), so
    queues build through the peak and drain on the falling edge — the
    windowed p99 must swing with the rate profile.
    """
    rng = _rng(seed, 0, "scenario:diurnal")
    mid = 0.5 * (base_rate + peak_rate)
    amp = 0.5 * (peak_rate - base_rate)

    def rate(t: float) -> float:
        return mid - amp * math.cos(2 * math.pi * t / horizon_s)

    arrivals = _poisson_thinned(rng, horizon_s, rate, peak_rate)
    return _trace("diurnal", horizon_s, arrivals)


def flash_crowd(horizon_s: float = 6.0, seed: int = 0, *,
                base_rate: float = 22.0, spike_rate: float = 170.0,
                spike_at: float = 0.45, spike_frac: float = 0.12,
                ) -> WorkloadTrace:
    """Steady base load with one short super-capacity spike.

    ``spike_at``/``spike_frac`` are fractions of the horizon. The spike
    (~2.8x capacity) builds a queue that takes several windows to
    drain: queue tax must jump in the spike window and decay after.
    """
    rng = _rng(seed, 0, "scenario:flash_crowd")
    t_spike = spike_at * horizon_s
    t_end = t_spike + spike_frac * horizon_s

    def rate(t: float) -> float:
        return spike_rate if t_spike <= t < t_end else base_rate

    arrivals = _poisson_thinned(rng, horizon_s, rate, spike_rate)
    return _trace("flash_crowd", horizon_s, arrivals)


def camera_fleet(horizon_s: float = 6.0, seed: int = 0, *,
                 n_cameras: int = 12, hot_rate: float = 16.0,
                 cold_rate: float = 1.8, n_keys: int = 8) -> WorkloadTrace:
    """Multi-camera fleet with skewed partition heat.

    Camera 0 is hot (~2x a single partition's capacity) and keys every
    frame to partition key 0; the cool cameras spread over keys
    1..n_keys-1, each far below capacity. Under a retry+breaker spec
    only key 0's partition can melt, so only ITS breaker may open — the
    skewed-heat signature.
    """
    arrivals: list[float] = []
    keys: list[int] = []
    for cam in range(n_cameras):
        rng = _rng(seed, cam, "scenario:camera_fleet")
        rate = hot_rate if cam == 0 else cold_rate
        key = 0 if cam == 0 else 1 + (cam - 1) % (n_keys - 1)
        t = 0.0
        while True:
            t += rng.expovariate(rate)
            if t >= horizon_s:
                break
            arrivals.append(t)
            keys.append(key)
    return _trace("camera_fleet", horizon_s, arrivals, keys)


def burst_drain(horizon_s: float = 6.0, seed: int = 0, *,
                burst_rate: float = 120.0, burst_s: float = 0.8,
                drain_s: float = 1.2, base_rate: float = 4.0,
                ) -> WorkloadTrace:
    """Square-wave duty cycle: super-capacity bursts, near-idle drains.

    Each burst banks ~(burst_rate - capacity) * burst_s of backlog; the
    drain phase has enough headroom to clear it before the next burst,
    so the in-flight depth must oscillate — build, drain to near-empty,
    repeat — rather than ratchet upward.
    """
    rng = _rng(seed, 0, "scenario:burst_drain")
    cycle = burst_s + drain_s

    def rate(t: float) -> float:
        return burst_rate if (t % cycle) < burst_s else base_rate

    arrivals = _poisson_thinned(rng, horizon_s, rate, burst_rate)
    return _trace("burst_drain", horizon_s, arrivals)


# ---- stress-signature checks ----------------------------------------------
# Each check takes (sim, result, trace) from a DES run of the
# scenario's spec and returns a list of violations (empty = signature
# holds). Thresholds carry ~2x margin under the measured values so
# seed-to-seed wiggle cannot flip them, while a scenario that lost its
# stress entirely still fails.

def _windows(sim, trace, min_n: int = 3):
    from repro.core.metrics import windowed_percentile
    win = windowed_percentile(sim.completions, 0.99, trace.heartbeat_s)
    return [(t, p, n) for t, p, n in win if n >= min_n]


def _check_diurnal(sim, res, trace) -> list[str]:
    problems = []
    if res.diverged:
        problems.append("diurnal run diverged: the falling edge must "
                        "drain the peak's backlog")
    win = _windows(sim, trace)
    if len(win) < 4:
        return problems + [f"only {len(win)} populated windows"]
    ps = [p for _, p, _ in win]
    if max(ps) < 1.3 * min(ps):
        problems.append(f"windowed p99 never swung with the cycle: "
                        f"max {max(ps):.3f} < 1.3x min {min(ps):.3f}")
    peak_t = max(win, key=lambda w: w[1])[0]
    if peak_t <= 0.3 * trace.horizon_s:
        problems.append(f"worst window ends at t={peak_t:.2f}: the tail "
                        f"must build toward the mid-horizon peak, not "
                        f"peak at the trough")
    return problems


def _check_flash_crowd(sim, res, trace) -> list[str]:
    from repro.core import facerec
    problems = []
    if res.diverged:
        problems.append("flash crowd diverged: base load must leave "
                        "headroom to drain the spike")
    # locate the spike window from the trace itself
    per_win: dict[int, int] = {}
    for ev in trace.events:
        w = int(ev.t // trace.heartbeat_s)
        per_win[w] = per_win.get(w, 0) + 1
    spike_w = max(per_win, key=per_win.get)
    qsec = sim.log.windowed_five_way(facerec.stage_category,
                                     trace.heartbeat_s, fractions=False)
    pre = [qsec[w]["queue"] for w in qsec if w < spike_w and w in per_win]
    if not pre:
        return problems + ["no pre-spike windows to baseline against"]
    base = sorted(pre)[len(pre) // 2]
    spike_q = max(qsec.get(w, {}).get("queue", 0.0)
                  for w in (spike_w, spike_w + 1))
    if spike_q < 3.0 * max(base, 1e-9):
        problems.append(f"queue tax did not spike: {spike_q:.2f} "
                        f"queue-seconds in the crowd window vs "
                        f"pre-spike median {base:.2f}")
    return problems


def _check_camera_fleet(sim, res, trace) -> list[str]:
    problems = []
    opened = {pi for pi, b in sim._breakers.items()
              if any(s != "closed" for _, s in b.timeline)}
    if opened != {0}:
        problems.append(f"breakers opened on partitions {sorted(opened)}; "
                        f"skewed heat must open exactly the hot "
                        f"partition's (0)")
    rel = res.reliability or {}
    if not rel.get("breaker_sheds", 0):
        problems.append("hot partition melted but its breaker never "
                        "shed an attempt")
    return problems


def _check_burst_drain(sim, res, trace) -> list[str]:
    problems = []
    if res.diverged:
        problems.append("burst_drain diverged: drains must clear each "
                        "burst's backlog")
    depths = [d for _, d in sim.depth_samples]
    if not depths:
        return problems + ["no depth samples recorded"]
    # depth counts in-service work and fetch-held records too, so a
    # "drained" valley still carries ~2 msgs/partition of floor
    hi, lo = 40, 16
    if max(depths) < hi:
        problems.append(f"bursts never banked a backlog: max depth "
                        f"{max(depths)} < {hi}")
    # count build->drain oscillations: above hi, later back below lo
    cycles, armed = 0, False
    for d in depths:
        if d >= hi:
            armed = True
        elif armed and d <= lo:
            cycles += 1
            armed = False
    if cycles < 2:
        problems.append(f"in-flight depth oscillated {cycles}x "
                        f"(need >= 2 build->drain cycles)")
    if depths[-1] > lo:
        problems.append(f"final depth {depths[-1]} > {lo}: the last "
                        f"drain window did not clear the backlog")
    return problems


# ---- registry --------------------------------------------------------------

@dataclass(frozen=True)
class Scenario:
    """A named workload shape plus its expected stress signature."""
    name: str
    build: object                  # (horizon_s, seed) -> WorkloadTrace
    check: object                  # (sim, result, trace) -> [violations]
    signature: str                 # one-line expected stress signature
    spec_kw: dict = field(default_factory=dict)   # extra ClusterSpec fields


def _fleet_policies() -> dict:
    """Retry + breaker for the skewed-heat scenario.

    Breaker failures are only recorded through the retry lifecycle's
    attempt timeouts, so the breaker needs a retry policy to see the
    hot partition melt. ``attempt_timeout_s`` sits well above the
    fetch-batching floor (fetch_max_wait 0.5 s + service) so healthy
    partitions never time out; ``open_s`` outlasts the horizon so an
    opened breaker stays open into the result.
    """
    from repro.cluster.reliability import BreakerConfig, RetryPolicy
    return dict(
        retry=RetryPolicy(deadline_s=3.0, attempt_timeout_s=1.0,
                          max_attempts=2, backoff_base_s=0.05,
                          backoff_cap_s=0.2, seed=0),
        breaker=BreakerConfig(window_s=1.0, failure_threshold=0.5,
                              min_volume=4, open_s=30.0, probe_rate=0.05,
                              close_after=3, seed=0))


SCENARIOS: dict[str, Scenario] = {
    "diurnal": Scenario(
        "diurnal", diurnal, _check_diurnal,
        "windowed p99 swings >=1.4x between trough and the mid-horizon "
        "peak, and the falling edge drains the backlog"),
    "flash_crowd": Scenario(
        "flash_crowd", flash_crowd, _check_flash_crowd,
        "queue tax spikes >=3x the pre-spike median in the crowd "
        "window, then the base load drains it"),
    "camera_fleet": Scenario(
        "camera_fleet", camera_fleet, _check_camera_fleet,
        "only the hot camera's partition breaker opens; cool "
        "partitions stay closed", _fleet_policies()),
    "burst_drain": Scenario(
        "burst_drain", burst_drain, _check_burst_drain,
        "in-flight depth oscillates: each burst banks >=20 and each "
        "drain clears it"),
}


def build_trace(name: str, horizon_s: float = 6.0,
                seed: int = 0) -> WorkloadTrace:
    """Build one library scenario's trace (deterministic in args)."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; library: "
                       f"{sorted(SCENARIOS)}")
    return SCENARIOS[name].build(horizon_s, seed)


def scenario_spec(name: str, sim_time: float = 6.0, seed: int = 0, **over):
    """The ClusterSpec that drives BOTH engines for one scenario.

    ``spec.scenario`` carries the name; both engines resolve it to the
    same trace (``ClusterSpec.resolve_trace``) at ``sim_time`` horizon,
    and the scenario's policies (retry/breaker for the skewed-heat
    fleet) ride along.
    """
    from repro.cluster.cluster import ClusterSpec
    kw = dict(SCENARIOS[name].spec_kw)
    kw.update(over)
    return ClusterSpec(scenario=name, sim_time=sim_time, seed=seed, **kw)
