"""Cluster-namespace re-export of the shared tail-latency metrics.

The implementations live in :mod:`repro.core.metrics` (pure stdlib) so
the serving engine can share the LatencyStats/TailSLO vocabulary
without importing the cluster runtime; cluster code and tests address
them here.
"""
from repro.core.metrics import LatencyStats, SLOReport, TailSLO, percentile

__all__ = ["LatencyStats", "SLOReport", "TailSLO", "percentile"]
