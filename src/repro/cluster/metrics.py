"""Cluster-namespace re-export of the shared tail-latency metrics.

The implementations live in :mod:`repro.core.metrics` (pure stdlib) so
the serving engine can share the LatencyStats/TailSLO vocabulary
without importing the cluster runtime; cluster code and tests address
them here. The fault-recovery accounting (windowed tails, recovery /
drain times) rides the same re-export, as does the reliability
accounting (goodput vs throughput, retry amplification, deadline-miss
rate): both execution engines hand it their completion streams and
report recovery in one vocabulary.
"""
from repro.core.metrics import (LatencyStats, RecoveryReport,
                                ReliabilityReport, SLOReport, TailSLO,
                                goodput_timeline, percentile,
                                recovery_report, reliability_report,
                                windowed_percentile)

__all__ = ["LatencyStats", "RecoveryReport", "ReliabilityReport",
           "SLOReport", "TailSLO", "goodput_timeline", "percentile",
           "recovery_report", "reliability_report", "windowed_percentile"]
