"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab_size=152064, head_dim=128, qkv_bias=True,
        act="silu", norm="rmsnorm", rope_theta=1_000_000.0,
        block_pattern=(LayerSpec(),),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="qwen2.5-14b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
