"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892]. 40 heads x 64 matrix
state; O(1) decode state -> runs long_500k."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab_size=65536, head_dim=64,
        act="silu", norm="layernorm", mlp_kind="rwkv", pos="sincos",
        rwkv_head_dim=64,
        block_pattern=(LayerSpec(kind="rwkv"),),
        supports_long=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="rwkv6-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        rwkv_head_dim=16)
