"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion VQ image tokens [arXiv:2405.09818].

Early fusion means image patches are VQ-quantized into the SAME 65536
vocab, so model inputs are plain token ids; the vision frontend is the
(stubbed) VQ tokenizer upstream of the model. QK-norm per the paper."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=65536, head_dim=128, qk_norm=True,
        act="silu", norm="rmsnorm", rope_theta=10_000.0,
        frontend="vision",
        block_pattern=(LayerSpec(),),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="chameleon-34b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
