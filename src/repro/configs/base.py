"""Config schema: model architecture + benchmark input shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating block pattern."""
    kind: str = "attn"            # attn | mamba | rwkv
    window: int | None = None     # sliding-window size (attn only)
    moe: bool = False             # MoE MLP at this position


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0             # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int
    kv_lora: int
    qk_nope: int
    qk_rope: int
    v_head: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "silu"             # silu | gelu
    mlp_kind: str = "glu"         # glu | plain | rwkv
    pos: str = "rope"             # rope | sincos
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: scale embeddings by sqrt(d)
    block_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    # SSM
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int | None = None
    rwkv_head_dim: int = 64
    # encoder-decoder
    encdec: bool = False
    n_enc_layers: int = 0
    dec_ratio: int = 8            # decoder len = seq_len // dec_ratio
    cross_seq: int = 1500         # stub encoder length for decode shapes
    frontend: str = "none"        # none | audio | vision
    # capability flags
    supports_long: bool = False   # sub-quadratic: may run long_500k
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern length {len(self.block_pattern)}")

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for MODEL_FLOPS = 6·N·D roofline term) ----
    def param_counts(self) -> dict[str, float]:
        """Returns dict with 'total' and 'active' parameter counts."""
        d, V = self.d_model, self.vocab_size
        D = self.head_dim
        H, KV = self.n_heads, self.n_kv_heads
        embed = V * d * (1 if self.tie_embeddings else 2)
        per = {"total": 0.0, "active": 0.0}

        def attn_params():
            if self.mla:
                m = self.mla
                n = (d * m.q_lora + m.q_lora * H * (m.qk_nope + m.qk_rope)
                     + d * (m.kv_lora + m.qk_rope)
                     + m.kv_lora * H * (m.qk_nope + m.v_head)
                     + H * m.v_head * d)
            else:
                n = d * H * D + 2 * d * KV * D + H * D * d
                if self.qkv_bias:
                    n += H * D + 2 * KV * D
            return n

        def mlp_params(moe: bool):
            mult = 3 if self.mlp_kind == "glu" else 2
            if moe and self.moe:
                tot = self.moe.n_experts * mult * d * self.moe.d_expert
                act = self.moe.top_k * mult * d * self.moe.d_expert
                tot += d * self.moe.n_experts          # router
                act += d * self.moe.n_experts
                if self.moe.n_shared:
                    sh = self.moe.n_shared * mult * d * self.moe.d_expert
                    tot += sh
                    act += sh
                return tot, act
            if self.mlp_kind == "rwkv":
                n = 2 * d * self.d_ff + d * d
                return n, n
            n = mult * d * self.d_ff
            return n, n

        def mixer_params(spec: LayerSpec):
            if spec.kind == "attn":
                n = attn_params()
            elif spec.kind == "mamba":
                di = self.ssm_expand * d
                dtr = self.ssm_dt_rank or max(d // 16, 1)
                n = (2 * d * di + di * self.ssm_conv
                     + di * (dtr + 2 * self.ssm_state) + dtr * di
                     + di * self.ssm_state + di + di * d)
            else:  # rwkv time-mix
                n = 4 * d * d + d * d // 2   # r,k,v,o,g(~half) rough but counted exactly in init
            return n

        for spec in self.block_pattern:
            mix = mixer_params(spec)
            mt, ma = mlp_params(spec.moe)
            per["total"] += mix + mt
            per["active"] += mix + ma
        per["total"] *= self.n_repeats
        per["active"] *= self.n_repeats
        if self.encdec:
            # encoder mirrors the decoder stack without cross-attn
            enc = self.n_enc_layers * (attn_params() + mlp_params(False)[0])
            dec_cross = self.n_layers * attn_params()      # cross-attention
            per["total"] += enc + dec_cross
            per["active"] += enc + dec_cross
        per["total"] += embed
        per["active"] += embed
        return per


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def supports_shape(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.supports_long
    return True
