"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba:attention 7:1 interleave
[arXiv:2403.19887].

Block pattern: 8 layers, attention at position 4, Mamba elsewhere; MoE MLP
at every other (odd) position. State caches are O(1) in context for 28/32
layers, so the arch runs long_500k."""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig


def _pattern(window=None):
    out = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        out.append(LayerSpec(kind=kind, moe=(i % 2 == 1)))
    return tuple(out)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=65536, head_dim=128,
        act="silu", norm="rmsnorm", rope_theta=10_000.0,
        block_pattern=_pattern(),
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336),
        ssm_state=16, ssm_expand=2, ssm_conv=4,
        supports_long=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
        ssm_state=4)
