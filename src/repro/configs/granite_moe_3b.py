"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8)
expert d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

E=40 does not divide the 16-way model axis, so expert weights shard on the
d_expert axis instead (tensor-parallel experts) — handled automatically by
the divisibility-aware sharding rules."""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        act="silu", norm="rmsnorm", rope_theta=10_000.0,
        tie_embeddings=True,
        block_pattern=(LayerSpec(moe=True),),
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="granite-moe-3b-a800m-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=4, d_expert=64))
