"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt]. Local layers use a 1024-token sliding window
(rolling decode cache), so the arch qualifies for long_500k."""
from repro.configs.base import LayerSpec, ModelConfig

_W = 1024  # sliding-window size


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        d_ff=15360, vocab_size=262144, head_dim=256,
        act="gelu", norm="rmsnorm", rope_theta=1_000_000.0,
        embed_scale=True, tie_embeddings=True, qk_norm=True,
        block_pattern=tuple([LayerSpec(window=_W)] * 5 + [LayerSpec()]),
        supports_long=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="gemma3-12b-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        block_pattern=tuple([LayerSpec(window=8)] * 5 + [LayerSpec()]))
