"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (MLA) routed d_ff=1536
vocab=102400, MoE 160e top-6 + 2 shared — MLA kv_lora=512
[arXiv:2405.04434].

MLA keeps a 512-d compressed latent cache (+64-d shared rope key) per
position instead of 128 heads x 256; decode uses the absorbed-matrix form
attending directly in latent space."""
from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab_size=102400, head_dim=192,
        act="silu", norm="rmsnorm", rope_theta=10_000.0,
        block_pattern=(LayerSpec(moe=True),),
        moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2),
        mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                      v_head=128),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="deepseek-v2-236b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=24, d_ff=64, vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1),
        mla=MLAConfig(q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8,
                      v_head=16))
