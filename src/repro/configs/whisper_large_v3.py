"""whisper-large-v3 [audio]: enc-dec, 32L d_model=1280 20H (kv=20, i.e.
MHA) d_ff=5120 vocab=51866, conv frontend stubbed [arXiv:2212.04356].

Shape convention (see DESIGN.md): the shape's seq_len is the encoder frame
count for train/prefill (decoder length = seq_len/8) and the decoder
self-cache length for decode shapes (cross-attending 1500 stub frames)."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab_size=51866, head_dim=64,
        act="gelu", norm="layernorm", mlp_kind="plain", pos="sincos",
        encdec=True, n_enc_layers=32, dec_ratio=8, cross_seq=1500,
        frontend="audio", qkv_bias=True,
        block_pattern=(LayerSpec(),),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="whisper-large-v3-smoke", n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=256, cross_seq=12)
