"""Config registry: one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_config(name, smoke=True)`` returns the family-preserving reduced
config used by CPU smoke tests (small widths/depths/experts, tiny vocab).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401  (re-exported)
    LayerSpec, MLAConfig, ModelConfig, MoEConfig, SHAPES, ShapeConfig,
    supports_shape,
)

ARCHS = [
    "llama3-8b",
    "qwen2.5-14b",
    "gemma3-12b",
    "qwen1.5-110b",
    "chameleon-34b",
    "whisper-large-v3",
    "jamba-v0.1-52b",
    "rwkv6-3b",
    "granite-moe-3b-a800m",
    "deepseek-v2-236b",
]

_MODULES = {
    "llama3-8b": "llama3_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-110b": "qwen1_5_110b",
    "chameleon-34b": "chameleon_34b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "rwkv6-3b": "rwkv6_3b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def list_configs() -> list[str]:
    return list(ARCHS)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke_config() if smoke else mod.config()
