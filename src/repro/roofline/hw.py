"""TPU v5e hardware constants (the TARGET platform; container is CPU)."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (intra-pod)
DCN_BW = 25e9                 # bytes/s per pod-crossing link (assumed)
HBM_BYTES = 16 * 2**30        # 16 GiB per chip
VMEM_BYTES = 128 * 2**20      # ~128 MiB VMEM per chip
MXU_DIM = 128                 # systolic array tile
LANE = 128                    # vector lane width
SUBLANE = 8
