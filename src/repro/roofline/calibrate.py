"""Cross-calibration of :mod:`repro.roofline.hlo_cost` against XLA.

The cost model is only trustworthy if it agrees with the compiler's own
accounting where their conventions overlap. This harness lowers a
battery of jitted fixture programs (matmul, scan, nested scan, a
DUS-carry scan, an attention block — the shapes the repo's roofline
terms are built from), runs ``analyze()`` on the optimized HLO text,
and compares it to ``compiled.cost_analysis()`` per term.

Conventions differ in exactly one place: XLA counts a ``while`` body
ONCE; our model multiplies by ``known_trip_count``. So the comparable
quantity is ``analyze(text, count_trips=False)`` — the report carries
both, plus the trip-multiplied numbers the rooflines actually consume.

``scripts/calibrate_cost.py`` is the CLI; the property test in
``tests/test_calibration.py`` gates dot-FLOP agreement at 5%.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.roofline import hlo_cost


def _sd(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _matmul():
    f = jax.jit(lambda a, b: a @ b)
    return f.lower(_sd((32, 64)), _sd((64, 128))).compile()


def _scan():
    def f(xs, w):
        def body(c, x):
            return jnp.tanh(c @ w) + x, ()
        c, _ = jax.lax.scan(body, xs[0], xs)
        return c
    return jax.jit(f).lower(_sd((7, 8, 16)), _sd((16, 16))).compile()


def _nested_scan():
    def f(xs, w):
        def outer(c, x):
            def inner(ci, xi):
                return ci @ w, ()
            ci, _ = jax.lax.scan(inner, c, x)
            return ci, ()
        c, _ = jax.lax.scan(outer, xs[0, 0], xs)
        return c
    return jax.jit(f).lower(_sd((3, 5, 8, 8)), _sd((8, 8))).compile()


def _dus_carry():
    def f(buf, xs):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(
                b, xs[i][None], i, axis=0), ()
        b, _ = jax.lax.scan(body, buf, jnp.arange(16))
        return b
    return jax.jit(f).lower(_sd((16, 1024)), _sd((16, 1024))).compile()


def _attention():
    from repro.kernels import ops

    def f(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="xla")
    return jax.jit(f).lower(_sd((2, 128, 4, 32)), _sd((2, 128, 2, 32)),
                            _sd((2, 128, 2, 32))).compile()


@dataclass(frozen=True)
class Fixture:
    name: str
    build: object                   # () -> compiled
    gate: str = "flops"             # term the 5% gate applies to ("" = none)
    note: str = ""


FIXTURES = (
    Fixture("matmul", _matmul, note="single dot, no control flow"),
    Fixture("scan", _scan, note="while trip=7, dot+tanh body"),
    Fixture("nested_scan", _nested_scan, note="while trip=3 x while trip=5"),
    Fixture("dus_carry", _dus_carry, gate="",
            note="in-place DUS carry; flops ~0, bytes-model fixture"),
    Fixture("attention", _attention, note="qk/av dots + softmax block"),
)


def xla_cost_terms(compiled) -> dict:
    """{'flops', 'bytes'} from ``compiled.cost_analysis()``."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


@dataclass
class CalibRow:
    name: str
    gate: str
    note: str
    ours: dict = field(default_factory=dict)      # trip-multiplied terms
    ours_flat: dict = field(default_factory=dict)  # count_trips=False terms
    xla: dict = field(default_factory=dict)
    deltas: dict = field(default_factory=dict)     # relative, vs ours_flat

    @property
    def gate_delta(self) -> float | None:
        if not self.gate:
            return None
        return self.deltas.get(self.gate)

    def ok(self, tolerance: float = 0.05) -> bool:
        d = self.gate_delta
        return d is None or abs(d) <= tolerance


def _rel(ours: float, theirs: float) -> float:
    if theirs == 0.0:
        return 0.0 if ours == 0.0 else float("inf")
    return (ours - theirs) / theirs


def calibrate_one(fx: Fixture) -> CalibRow:
    compiled = fx.build()
    tripped, flat = hlo_cost.analyze_pair(compiled.as_text())
    x = xla_cost_terms(compiled)
    row = CalibRow(name=fx.name, gate=fx.gate, note=fx.note)
    row.ours = {"dot_flops": tripped.dot_flops, "flops": tripped.flops,
                "bytes": tripped.hbm_bytes}
    row.ours_flat = {"dot_flops": flat.dot_flops, "flops": flat.flops,
                     "bytes": flat.hbm_bytes}
    row.xla = x
    row.deltas = {"flops": _rel(flat.flops, x["flops"]),
                  "dot_flops": _rel(flat.dot_flops, x["flops"]),
                  "bytes": _rel(flat.hbm_bytes, x["bytes"])}
    return row


def calibrate(fixtures=FIXTURES) -> list:
    return [calibrate_one(fx) for fx in fixtures]


def report(rows, tolerance: float = 0.05) -> list:
    """Human-readable per-term delta table (one string per line)."""
    out = [f"{'fixture':<12} {'ours(dot)':>12} {'ours(flops)':>12} "
           f"{'xla(flops)':>12} {'d_flops':>8} {'ours(B)':>12} "
           f"{'xla(B)':>12} {'d_bytes':>8}  gate"]
    for r in rows:
        verdict = "-" if not r.gate else \
            ("OK" if r.ok(tolerance) else "FAIL")
        out.append(
            f"{r.name:<12} {r.ours_flat['dot_flops']:>12.4g} "
            f"{r.ours_flat['flops']:>12.4g} {r.xla['flops']:>12.4g} "
            f"{r.deltas['flops']:>+8.1%} {r.ours_flat['bytes']:>12.4g} "
            f"{r.xla['bytes']:>12.4g} {r.deltas['bytes']:>+8.1%}  "
            f"{verdict}")
        if r.ours["flops"] != r.ours_flat["flops"]:
            mult = (r.ours["flops"] / r.ours_flat["flops"]
                    if r.ours_flat["flops"] else 0.0)
            out.append(f"{'':<12} trip-multiplied: "
                       f"flops={r.ours['flops']:.4g} "
                       f"bytes={r.ours['bytes']:.4g} "
                       f"(x{mult:.1f} over XLA's count-body-once)")
    return out
