"""Structured parser for post-optimization HLO text.

Phase 1 of the cost-model subsystem (:mod:`repro.roofline.hlo_cost` is
phase 2). Turns ``compiled.as_text()`` into typed records — one
:class:`Instruction` per line with its opcode, output shape leaves,
operand references (with the inline operand types jax >= 0.4.3x
prints), and the attributes the cost pass needs (``known_trip_count``,
contracting dims, ``dynamic_slice_sizes``, callee computations) — so
the cost rules operate on IR instead of ad-hoc string scans.

The parser is deliberately tolerant of both operand styles:

  * modern:  ``dot(f32[8,16]{1,0} %lhs, f32[16,16]{1,0} %rhs)``
  * legacy:  ``dot(%lhs, %rhs)``  (shapes resolved via def-use)

Unknown opcodes/attributes parse fine and simply carry no extra
structure; the cost pass decides what to charge.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_REF_RE = re.compile(r"%([\w.\-]+)")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{$")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_RHS_C_RE = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_DSS_RE = re.compile(r"dynamic_slice_sizes=\{([\d,]*)\}")
_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")
_COND_RE = re.compile(r"\bcondition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"\b(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

# ops that forward their operand's buffer (or a re-typed view of it);
# def-use chains are resolved through these
ALIAS_OPS = frozenset({"bitcast", "bitcast-convert", "convert", "copy",
                       "reshape", "get-tuple-element"})


@dataclass(frozen=True)
class TensorShape:
    dtype: str
    dims: tuple

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * DTYPE_BYTES.get(self.dtype, 0)


def parse_shapes(text: str) -> tuple:
    """Every tensor leaf in ``text`` (a tuple type yields all leaves)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        out.append(TensorShape(
            dt, tuple(int(d) for d in dims.split(",") if d)))
    return tuple(out)


def _leaf_elems(shapes) -> int:
    return sum(s.elems for s in shapes)


def _leaf_bytes(shapes) -> int:
    return sum(s.bytes for s in shapes)


def _match_paren(s: str, i: int, open_ch: str = "(", close_ch: str = ")"):
    """Index of the close matching ``s[i]`` (== open_ch), or -1."""
    depth = 0
    for j in range(i, len(s)):
        c = s[j]
        if c == open_ch:
            depth += 1
        elif c == close_ch:
            depth -= 1
            if depth == 0:
                return j
    return -1


def _split_top(s: str, sep: str = ",") -> list:
    """Split on ``sep`` at bracket depth 0 (over (), {}, [])."""
    parts, depth, start = [], 0, 0
    for j, c in enumerate(s):
        if c in "({[":
            depth += 1
        elif c in ")}]":
            depth -= 1
        elif c == sep and depth == 0:
            parts.append(s[start:j])
            start = j + 1
    parts.append(s[start:])
    return [p.strip() for p in parts if p.strip()]


@dataclass(frozen=True)
class Operand:
    ref: str | None          # %name it refers to (None for literals)
    shapes: tuple            # inline-type leaves ((), when legacy style)

    @property
    def bytes(self) -> int:
        return _leaf_bytes(self.shapes)


def _int_tuple(m) -> tuple:
    if not m:
        return ()
    return tuple(int(d) for d in m.group(1).split(",") if d)


@dataclass
class Instruction:
    name: str
    opcode: str
    is_root: bool
    shapes: tuple                    # output leaves (tuple flattened)
    operands: tuple                  # Operand records, in order
    raw: str                         # full source line (metadata included)
    trip_count: int | None = None
    lhs_contracting: tuple = ()
    rhs_contracting: tuple = ()
    lhs_batch: tuple = ()
    dynamic_slice_sizes: tuple = ()
    body: str | None = None          # while body computation
    condition: str | None = None     # while condition computation
    callees: tuple = ()              # calls= / to_apply= targets
    branches: tuple = ()             # conditional branch computations

    @property
    def out_elems(self) -> int:
        return _leaf_elems(self.shapes)

    @property
    def out_bytes(self) -> int:
        return _leaf_bytes(self.shapes)


def parse_instruction(line: str) -> Instruction | None:
    dm = _DEF_RE.match(line)
    if not dm:
        return None
    is_root, name, rest = bool(dm.group(1)), dm.group(2), dm.group(3)
    # output type: a parenthesized tuple or a single space-free token
    if rest.startswith("("):
        close = _match_paren(rest, 0)
        if close < 0:
            return None
        type_str, after = rest[:close + 1], rest[close + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, after = rest[:sp], rest[sp:]
    om = _OPCODE_RE.match(after)
    if not om:
        return None
    opcode = om.group(1)
    op_open = after.index("(", om.start(1))
    op_close = _match_paren(after, op_open)
    if op_close < 0:
        return None
    opnd_text = after[op_open + 1:op_close]
    attr_text = after[op_close + 1:]

    operands = []
    for chunk in _split_top(opnd_text):
        refs = _REF_RE.findall(chunk)
        operands.append(Operand(ref=refs[-1] if refs else None,
                                shapes=parse_shapes(chunk)))

    tm = _TRIP_RE.search(attr_text)
    bm = _BODY_RE.search(attr_text)
    cm = _COND_RE.search(attr_text)
    br = _BRANCH_RE.search(attr_text)
    return Instruction(
        name=name, opcode=opcode, is_root=is_root,
        shapes=parse_shapes(type_str), operands=tuple(operands),
        raw=line,
        trip_count=int(tm.group(1)) if tm else None,
        lhs_contracting=_int_tuple(_LHS_C_RE.search(attr_text)),
        rhs_contracting=_int_tuple(_RHS_C_RE.search(attr_text)),
        lhs_batch=_int_tuple(_LHS_B_RE.search(attr_text)),
        dynamic_slice_sizes=_int_tuple(_DSS_RE.search(attr_text)),
        body=bm.group(1) if bm else None,
        condition=cm.group(1) if cm else None,
        callees=tuple(_CALLS_RE.findall(attr_text)),
        branches=tuple(_REF_RE.findall(br.group(1))) if br else (),
    )


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    params: dict = field(default_factory=dict)      # header name -> leaves
    instructions: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)

    @property
    def root(self) -> Instruction | None:
        for i in self.instructions:
            if i.is_root:
                return i
        return self.instructions[-1] if self.instructions else None

    def add(self, instr: Instruction):
        self.instructions.append(instr)
        self.by_name[instr.name] = instr

    def shapes_of(self, ref: str | None) -> tuple:
        """Output leaves of the value ``ref`` names (def or header param)."""
        if ref is None:
            return ()
        instr = self.by_name.get(ref)
        if instr is not None:
            return instr.shapes
        return self.params.get(ref, ())

    def operand_shapes(self, instr: Instruction, idx: int) -> tuple:
        """Inline operand type when present, else def-use resolution."""
        if idx >= len(instr.operands):
            return ()
        op = instr.operands[idx]
        if op.shapes:
            return op.shapes
        return self.shapes_of(op.ref)

    def resolve(self, ref: str | None,
                through: frozenset = ALIAS_OPS) -> Instruction | None:
        """The defining instruction, chasing alias ops (convert/bitcast/
        copy/reshape/GTE chains) back to the producing def."""
        seen = 0
        while ref is not None and seen < 32:
            instr = self.by_name.get(ref)
            if instr is None:
                return None
            if instr.opcode in through and instr.operands \
                    and instr.operands[0].ref is not None:
                ref = instr.operands[0].ref
                seen += 1
                continue
            return instr
        return None

    def origin_param(self, ref: str | None) -> str | None:
        """Name of the ``parameter`` the value aliases, if it does."""
        instr = self.resolve(ref)
        if instr is not None and instr.opcode == "parameter":
            return instr.name
        return None


@dataclass
class Module:
    computations: dict = field(default_factory=dict)

    @property
    def entry(self) -> Computation | None:
        for c in self.computations.values():
            if c.is_entry:
                return c
        if self.computations:
            return list(self.computations.values())[-1]
        return None

    def get(self, name: str | None) -> Computation | None:
        if name is None:
            return None
        return self.computations.get(name)


def parse_module(text: str) -> Module:
    mod = Module()
    cur: Computation | None = None
    for raw in text.splitlines():
        s = raw.strip()
        if cur is None:
            hm = _HEADER_RE.match(s)
            if hm:
                cur = Computation(name=hm.group(2),
                                  is_entry=bool(hm.group(1)))
                for chunk in _split_top(hm.group(3)):
                    if ":" not in chunk:
                        continue
                    pname, ptype = chunk.split(":", 1)
                    cur.params[pname.strip()] = parse_shapes(ptype)
                mod.computations[cur.name] = cur
            continue
        if s == "}":
            cur = None
            continue
        instr = parse_instruction(s)
        if instr is not None:
            cur.add(instr)
    return mod
