"""Trip-count-aware cost analysis of optimized HLO (phase 2).

``compiled.cost_analysis()`` counts a ``while`` body ONCE, which silently
undercounts every scanned-layer model by its depth (and collectives inside
the scan by the same factor). This pass runs over the typed IR built by
:mod:`repro.roofline.hlo_parser`, computes per-computation costs (dot
FLOPs, elementwise FLOPs, HBM-boundary bytes, collective bytes by kind),
and rolls them up through the call graph: ``while`` multiplies its body
and condition by ``known_trip_count``; fusions/calls add their callee
once — so trip counts compose multiplicatively through any nesting,
including a ``while`` reached via a wrapping fusion or call.

Cost rules (each one unit-tested against golden HLO in
``tests/fixtures/`` and cross-calibrated against XLA's own
``cost_analysis()`` by :mod:`repro.roofline.calibrate`):

  * dot FLOPs are exact: ``2 * prod(out_dims) * prod(lhs contracting
    dims)`` — batch dims already live in the output shape. The lhs shape
    comes from the inline operand type; legacy text without inline types
    resolves the operand through convert/bitcast/copy chains.
  * elementwise FLOPs cover the common float ops (1 flop/elem) — this is
    what makes SSM/RWKV scans visible, which are elementwise-dominated.
    Fusion internals contribute their FLOPs via the ``calls=`` edge while
    bytes are charged only at the fusion boundary.
  * bytes are an HBM-traffic model: operands + outputs at fusion/call-site
    boundaries (internals of a fusion are on-chip). Fusions are
    slice-aware: a parameter only read through (dynamic-)slice charges
    the slice; a dynamic-update-slice root aliases its buffer in place
    and charges the update slice read+write (XLA:CPU's bf16-legalization
    ``convert`` wrappers around the root are unwrapped first).
  * collective bytes use the op's full (gathered) shape for all-gather /
    all-reduce; reduce-scatter/all-to-all use operand bytes when known.

``analyze(text, count_trips=False)`` disables the while multiplication,
which reproduces XLA's count-the-body-once convention — that is the
comparable quantity for calibration against ``cost_analysis()``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.roofline import hlo_parser as hp

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "rsqrt", "sqrt", "log", "negate", "abs",
    "exponential-minus-one", "log-plus-one", "logistic", "select", "floor",
    "ceil", "round-nearest-afz", "cosine", "sine", "sign", "clamp",
    "compare", "and", "or", "not", "xor",
}

# opcodes that call sub-computations and form an HBM boundary
_CALL_LIKE = {"call", "fusion", "custom-call", "reduce", "sort", "map",
              "reduce-window", "scatter", "select-and-scatter",
              "conditional", "async-start"}

# data movement that genuinely crosses HBM (copy/convert are CPU-lowering
# artifacts a TPU fuses away and charge nothing)
_MOVE_RW = {"transpose", "concatenate", "gather", "pad"}
_MOVE_FREE = {"copy", "reshape", "broadcast", "slice", "dynamic-slice",
              "iota", "convert", "bitcast", "bitcast-convert", "reverse"}


@dataclass
class Cost:
    dot_flops: float = 0.0
    ew_flops: float = 0.0
    hbm_bytes: float = 0.0
    flash_bytes: float = 0.0   # subset of hbm_bytes inside "flashable_*"
    #                            named scopes (regions a Pallas kernel fuses)
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in _COLL_KINDS})

    def add(self, other: "Cost", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.ew_flops += other.ew_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.flash_bytes += other.flash_bytes * mult
        for k in _COLL_KINDS:
            self.coll[k] += other.coll[k] * mult

    @property
    def flops(self) -> float:
        return self.dot_flops + self.ew_flops

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def to_dict(self) -> dict:
        return {"dot_flops": self.dot_flops, "ew_flops": self.ew_flops,
                "flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "coll_bytes": self.coll_bytes, "coll": dict(self.coll)}


def _operand_bytes(comp: hp.Computation, instr: hp.Instruction) -> float:
    return float(sum(
        hp._leaf_bytes(comp.operand_shapes(instr, i))
        for i in range(len(instr.operands))))


def _dot_flops(comp: hp.Computation, instr: hp.Instruction) -> float:
    """2 * prod(out) * prod(contracted lhs dims); the lhs shape comes from
    the inline operand type or the operand's defining instruction."""
    k = 1
    lhs_shapes = comp.operand_shapes(instr, 0)
    if lhs_shapes:
        dims = lhs_shapes[0].dims
        for ci in instr.lhs_contracting:
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * instr.out_elems * k


def _fusion_traffic(callee: hp.Computation, out_elems: int, out_bytes: int):
    """Slice-aware HBM traffic of a fusion: (in_bytes, out_bytes).

    Scan bodies address their carries with dynamic-slice (read one layer
    of a stacked buffer) and dynamic-update-slice (write one layer back,
    aliased in place). Charging the full stacked operand/output per
    iteration over-counts by the layer count, so:
      * a param only read through (dynamic-)slice charges the slice;
      * a root that is a DUS (possibly wrapped in XLA:CPU's bf16
        legalization converts) charges the update slice as the output,
        its buffer operand aliases in place (no read), and the update
        values count as the read side.
    Returns None if the callee has no parseable root."""
    root = callee.root
    if root is None:
        return None
    # unwrap XLA:CPU's bf16-legalization convert/copy wrappers at the root
    target = callee.resolve(
        root.name, through=frozenset({"convert", "bitcast", "copy"})) or root
    root_is_dus = target.opcode == "dynamic-update-slice"

    out_traffic = float(out_bytes)
    if root_is_dus and len(target.operands) >= 2:
        upd = target.operands[1]
        upd_bytes = upd.bytes or hp._leaf_bytes(callee.shapes_of(upd.ref))
        if upd_bytes:
            out_traffic = float(upd_bytes)

    param_bytes = {i.name: float(i.out_bytes) for i in callee.instructions
                   if i.opcode == "parameter"}
    sliced: dict[str, float] = {}
    for instr in callee.instructions:
        if not instr.operands:
            continue
        if instr.opcode == "dynamic-update-slice":
            # the buffer operand of a DUS aliases in place: no read traffic
            src = callee.origin_param(instr.operands[0].ref)
            if src is not None:
                sliced[src] = 0.0
        elif instr.opcode in ("dynamic-slice", "slice"):
            src = callee.origin_param(instr.operands[0].ref)
            if src is not None:
                sliced[src] = min(sliced.get(src, float("inf")),
                                  float(instr.out_bytes))
    in_traffic = 0.0
    for name, b in param_bytes.items():
        if root_is_dus:
            # scatter-update fusion: real reads are the slices it touches;
            # full-size untouched params are aliased carry buffers (and
            # XLA:CPU's bf16<->f32 legalization doubles of them).
            in_traffic += sliced.get(name, 0.0)
        else:
            in_traffic += sliced.get(name, b)
    if root_is_dus:
        in_traffic += out_traffic          # the update values themselves
    return in_traffic, out_traffic


def _comp_cost(comp: hp.Computation, mod: hp.Module, *,
               in_fusion: bool = False):
    """(local Cost, [(callee_name, multiplier)]) for one computation.

    ``in_fusion`` marks a fusion callee: its instructions run on-chip, so
    it contributes FLOPs through the ``calls=`` edge while every byte
    charge is suppressed — bytes are charged once, at the fusion
    boundary, by the caller's slice-aware traffic rule."""
    cost = Cost()
    calls = []
    for instr in comp.instructions:
        opc = instr.opcode
        out_elems, out_bytes = instr.out_elems, instr.out_bytes
        flashable = "flashable" in instr.raw

        base = opc.replace("-start", "").replace("-done", "")
        if base in _COLL_KINDS:
            if opc.endswith("-done"):
                continue
            byts = float(out_bytes)
            if base in ("reduce-scatter", "all-to-all"):
                in_bytes = hp._leaf_bytes(comp.operand_shapes(instr, 0))
                byts = max(byts, float(in_bytes))
            cost.coll[base] += byts
            cost.hbm_bytes += out_bytes
            continue
        if opc == "while":
            trip = instr.trip_count or 1
            if instr.body:
                calls.append((instr.body, trip))
            if instr.condition:
                calls.append((instr.condition, trip))
            continue
        if opc in _CALL_LIKE:
            for c in instr.callees:
                calls.append((c, 1))
            for c in instr.branches:
                calls.append((c, 1))
            # HBM boundary: operands + outputs, slice-aware for fusions
            # (scan carries / KV-cache updates alias in place and read
            # one-layer slices of stacked buffers).
            byts = None
            if opc == "fusion":
                callee = mod.get(instr.callees[0]) if instr.callees else None
                if callee is not None:
                    tr = _fusion_traffic(callee, out_elems, out_bytes)
                    if tr is not None:
                        byts = tr[0] + tr[1]
            if byts is None:
                byts = out_bytes + _operand_bytes(comp, instr)
            cost.hbm_bytes += byts
            if flashable:
                cost.flash_bytes += byts
            if opc == "reduce":
                cost.ew_flops += out_elems  # rough
            continue
        if opc in ("dot", "dot-general"):
            cost.dot_flops += _dot_flops(comp, instr)
            byts = out_bytes + _operand_bytes(comp, instr)
            cost.hbm_bytes += byts
            if flashable:
                cost.flash_bytes += byts
            continue
        if opc == "convolution":
            # flops ~ 2 * out_elems * (in_channels * kernel_spatial)
            cost.dot_flops += 2.0 * out_elems  # lower bound; convs are stubs
            cost.hbm_bytes += out_bytes
            continue
        if opc in _ELEMENTWISE:
            cost.ew_flops += out_elems
            # elementwise at computation top level = one fused kernel anyway;
            # only count boundary bytes for large ops to avoid double count
            continue
        if opc == "dynamic-update-slice":
            # in-place: traffic = the update slice (2nd operand), r+w
            upd = (hp._leaf_bytes(comp.operand_shapes(instr, 1))
                   if len(instr.operands) > 1 else out_bytes)
            cost.hbm_bytes += 2.0 * upd
            if flashable:
                cost.flash_bytes += 2.0 * upd
            continue
        if opc in _MOVE_RW:
            cost.hbm_bytes += 2.0 * out_bytes
            if flashable:
                cost.flash_bytes += 2.0 * out_bytes
            continue
        # _MOVE_FREE, parameter, constant, tuple, get-tuple-element,
        # compare-free bookkeeping: no charge
    if in_fusion:
        cost.hbm_bytes = 0.0
        cost.flash_bytes = 0.0
    return cost, calls


def _local_costs(mod: hp.Module) -> dict:
    """name -> (local Cost, call edges), with fusion callees marked so
    their bytes are suppressed (charged at the fusion boundary only)."""
    fusion_callees = {c for comp in mod.computations.values()
                      for i in comp.instructions if i.opcode == "fusion"
                      for c in i.callees}
    return {name: _comp_cost(c, mod, in_fusion=name in fusion_callees)
            for name, c in mod.computations.items()}


def _rollup(local: dict, entry_name: str, count_trips: bool) -> Cost:
    memo: dict[str, Cost] = {}

    def total(name: str) -> Cost:
        if name in memo:
            return memo[name]
        out = Cost()
        if name not in local:
            return out
        memo[name] = out           # break cycles defensively
        cost, calls = local[name]
        out.add(cost)
        for callee, mult in calls:
            out.add(total(callee), mult if count_trips else 1.0)
        return out

    return total(entry_name)


def analyze_module(mod: hp.Module, *, count_trips: bool = True) -> Cost:
    """Roll per-computation costs up through the call graph from entry."""
    entry = mod.entry
    if entry is None:
        return Cost()
    return _rollup(_local_costs(mod), entry.name, count_trips)


def analyze(hlo_text: str, *, count_trips: bool = True) -> Cost:
    """Parse + cost the module. ``count_trips=False`` reproduces XLA's
    count-a-while-body-once convention (for calibration)."""
    return analyze_module(hp.parse_module(hlo_text), count_trips=count_trips)


def analyze_pair(hlo_text: str) -> tuple:
    """(trip-multiplied, count-body-once) costs from ONE parse + cost
    pass — what from_compiled and the calibration harness use; parsing a
    multi-MB module and walking every computation happens once."""
    mod = hp.parse_module(hlo_text)
    entry = mod.entry
    if entry is None:
        return Cost(), Cost()
    local = _local_costs(mod)
    return (_rollup(local, entry.name, True),
            _rollup(local, entry.name, False))
