"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, which silently
undercounts every scanned-layer model by its depth (and collectives inside
the scan by the same factor). This walker parses the post-partitioning HLO
module, computes per-computation costs (dot FLOPs, elementwise FLOPs,
HBM-boundary bytes, collective bytes by kind), and rolls them up through
the call graph: ``while`` multiplies by its ``known_trip_count``,
fusions/calls add their callee once.

Scope notes:
  * dot FLOPs are exact (2 * prod(out) * prod(contracted lhs dims)).
  * elementwise FLOPs cover the common float ops (1 flop/elem) — this is
    what makes SSM/RWKV scans visible, which are elementwise-dominated.
  * bytes are an HBM-traffic model: operands + outputs at fusion/call-site
    boundaries (internals of a fusion are on-chip).
  * collective bytes use the op's full (gathered) shape for all-gather /
    all-reduce; reduce-scatter/all-to-all use operand bytes when known.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "rsqrt", "sqrt", "log", "negate", "abs",
    "exponential-minus-one", "log-plus-one", "logistic", "select", "floor",
    "ceil", "round-nearest-afz", "cosine", "sine", "sign",
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}\- ])*?)\s*([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def _shape_info(type_str: str):
    """(elements, bytes) summed over every tensor literal in the string."""
    elems = byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclass
class Cost:
    dot_flops: float = 0.0
    ew_flops: float = 0.0
    hbm_bytes: float = 0.0
    flash_bytes: float = 0.0   # subset of hbm_bytes inside "flashable_*"
    #                            named scopes (regions a Pallas kernel fuses)
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in _COLL_KINDS})

    def add(self, other: "Cost", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.ew_flops += other.ew_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.flash_bytes += other.flash_bytes * mult
        for k in _COLL_KINDS:
            self.coll[k] += other.coll[k] * mult

    @property
    def flops(self) -> float:
        return self.dot_flops + self.ew_flops

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def to_dict(self) -> dict:
        return {"dot_flops": self.dot_flops, "ew_flops": self.ew_flops,
                "flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "coll_bytes": self.coll_bytes, "coll": dict(self.coll)}


@dataclass
class _Comp:
    name: str
    lines: list
    symbols: dict           # op name -> type string
    local: Cost | None = None
    calls: list = None      # (callee, mult) pairs


def _split_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)$", s)
            if m and s.endswith("{"):
                name = m.group(1)
                cur = _Comp(name=name, lines=[], symbols={}, calls=[])
                if raw.lstrip().startswith("ENTRY"):
                    cur.is_entry = True
                # header params: "a.1: f32[8,16], b: (s32[], f32[2])"
                hdr = s[s.index("(") + 1:]
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\],{} ]+))",
                                      hdr):
                    cur.symbols[pm.group(1)] = pm.group(2)
                comps[name] = cur
            continue
        if s == "}":
            cur = None
            continue
        cur.lines.append(s)
        dm = _DEF_RE.match(s)
        if dm:
            cur.symbols[dm.group(1)] = dm.group(2)
    return comps


def _dot_flops(line: str, out_elems: int, symbols: dict) -> float:
    m = re.search(r"dot\(\s*%([\w.\-]+)", line)
    k = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if m and cm and m.group(1) in symbols:
        sh = _SHAPE_RE.search(symbols[m.group(1)])
        if sh and sh.group(2):
            dims = [int(d) for d in sh.group(2).split(",")]
            for ci in cm.group(1).split(","):
                if ci:
                    idx = int(ci)
                    if idx < len(dims):
                        k *= dims[idx]
    return 2.0 * out_elems * k


def _fusion_traffic(callee: _Comp, out_elems: int, out_bytes: int):
    """Slice-aware HBM traffic of a fusion.

    Scan bodies address their carries with dynamic-slice (read one layer
    of a stacked buffer) and dynamic-update-slice (write one layer back,
    aliased in place). Charging the full stacked operand/output per
    iteration over-counts by the layer count, so:
      * a param whose only use is a dynamic-slice charges the slice;
      * a root that is a DUS (possibly wrapped in XLA:CPU's bf16
        legalization converts) charges the update slice as the output.
    Returns (in_bytes, out_bytes) or None if the callee is unparseable."""
    if not callee.lines:
        return None
    # ---- output side ----
    root = None
    for line in callee.lines:
        if line.startswith("ROOT"):
            root = line
            break
    if root is None:
        return None
    out_traffic = float(out_bytes)
    target = root
    if " convert(" in root:
        ops = _OPERANDS_RE.findall(root[root.index(" convert("):])
        if ops and ops[0] in callee.symbols:
            target = callee.symbols[ops[0]]
    if "dynamic-update-slice(" in target:
        ops = _OPERANDS_RE.findall(
            target[target.index("dynamic-update-slice("):])
        if len(ops) >= 2:
            upd_elems, _ = _shape_info(callee.symbols.get(ops[1], ""))
            elt = (out_bytes / out_elems) if out_elems else 4.0
            out_traffic = upd_elems * elt
    # ---- input side ----
    sliced_params: dict[str, float] = {}
    param_bytes: dict[str, float] = {}
    alias_src: dict[str, str] = {}      # convert/bitcast chains
    for line in callee.lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rest = dm.group(1), dm.group(2)
        if " parameter(" in rest:
            param_bytes[name] = _shape_info(rest)[1]
            continue
        ops = _OPERANDS_RE.findall(rest)
        if (" convert(" in rest or " bitcast(" in rest
                or " copy(" in rest or " reshape(" in rest) and ops:
            alias_src[name] = ops[0]

    def root_param(name: str) -> str | None:
        seen = 0
        while name in alias_src and seen < 10:
            name = alias_src[name]
            seen += 1
        return name if name in param_bytes else None

    for line in callee.lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rest = dm.group(2)
        if "dynamic-update-slice(" in rest:
            # the buffer operand of a DUS aliases in place: no read traffic
            ops = _OPERANDS_RE.findall(
                rest[rest.index("dynamic-update-slice("):])
            src = root_param(ops[0]) if ops else None
            if src is not None:
                sliced_params[src] = 0.0
        elif "dynamic-slice(" in rest:
            ops = _OPERANDS_RE.findall(rest[rest.index("dynamic-slice("):])
            src = root_param(ops[0]) if ops else None
            if src is not None:
                sliced_params[src] = min(
                    sliced_params.get(src, float("inf")),
                    float(_shape_info(rest)[1]))
    root_is_dus = "dynamic-update-slice(" in target
    in_traffic = 0.0
    for name, b in param_bytes.items():
        if root_is_dus:
            # scatter-update fusion: real reads are the slices it touches;
            # full-size untouched params are aliased carry buffers (and
            # XLA:CPU's bf16<->f32 legalization doubles of them).
            in_traffic += sliced_params.get(name, 0.0)
        else:
            in_traffic += sliced_params.get(name, b)
    if root_is_dus:
        in_traffic += out_traffic          # the update values themselves
    return in_traffic, out_traffic


def _analyze_comp(comp: _Comp, comps: dict | None = None):
    cost = Cost()
    calls = []
    for line in comp.lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rest = dm.group(2)
        om = _OPCODE_RE.match(rest)
        if not om:
            continue
        type_str, opcode = om.group(1), om.group(2)
        out_elems, out_bytes = _shape_info(type_str)
        opc = opcode.lower()
        base = opc.replace("-start", "").replace("-done", "")
        if base in _COLL_KINDS:
            if opc.endswith("-done"):
                continue
            byts = out_bytes
            if base in ("reduce-scatter", "all-to-all"):
                ops = _OPERANDS_RE.findall(rest[len(om.group(0)):])
                in_bytes = sum(_shape_info(comp.symbols.get(o, ""))[1]
                               for o in ops[:1])
                byts = max(byts, in_bytes)
            cost.coll[base] += byts
            cost.hbm_bytes += out_bytes
            continue
        if opc == "while":
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            body = _CALLEE_RE.search(line)
            cond = _COND_RE.search(line)
            if body:
                calls.append((body.group(1), trip))
            if cond:
                calls.append((cond.group(1), trip))
            continue
        if opc in ("call", "fusion", "custom-call", "reduce", "sort", "map",
                   "reduce-window", "scatter", "select-and-scatter",
                   "conditional", "async-start"):
            for cm_ in re.finditer(r"(?:to_apply|calls|body)=%?([\w.\-]+)", line):
                calls.append((cm_.group(1), 1))
            for cm_ in re.finditer(r"branch_computations=\{([^}]*)\}", line):
                for c in _OPERANDS_RE.findall(cm_.group(1)):
                    calls.append((c, 1))
            # HBM boundary: operands + outputs, slice-aware for fusions
            # (scan carries / KV-cache updates alias in place and read
            # one-layer slices of stacked buffers).
            byts = None
            if opc == "fusion" and comps is not None:
                cal = _CALLEE_RE.search(line)
                callee = comps.get(cal.group(1)) if cal else None
                if callee is not None:
                    tr = _fusion_traffic(callee, out_elems, out_bytes)
                    if tr is not None:
                        byts = tr[0] + tr[1]
            if byts is None:
                ops = _OPERANDS_RE.findall(rest[len(om.group(0)):])
                in_bytes = sum(_shape_info(comp.symbols.get(o, ""))[1]
                               for o in ops)
                byts = out_bytes + in_bytes
            cost.hbm_bytes += byts
            if "flashable" in line:
                cost.flash_bytes += byts
            if opc == "reduce":
                cost.ew_flops += out_elems  # rough
            continue
        if opc in ("dot", "dot-general"):
            cost.dot_flops += _dot_flops(rest, out_elems, comp.symbols)
            ops = _OPERANDS_RE.findall(rest[len(om.group(0)):])
            in_bytes = sum(_shape_info(comp.symbols.get(o, ""))[1]
                           for o in ops)
            cost.hbm_bytes += out_bytes + in_bytes
            if "flashable" in line:
                cost.flash_bytes += out_bytes + in_bytes
            continue
        if opc == "convolution":
            # flops ~ 2 * out_elems * (in_channels * kernel_spatial)
            cost.dot_flops += 2.0 * out_elems  # lower bound; convs are stubs
            cost.hbm_bytes += out_bytes
            continue
        if opc in _ELEMENTWISE:
            cost.ew_flops += out_elems
            # elementwise at computation top level = one fused kernel anyway;
            # only count boundary bytes for large ops to avoid double count
            continue
        if opc in ("copy", "transpose", "reshape", "broadcast", "concatenate",
                   "slice", "dynamic-slice", "dynamic-update-slice", "gather",
                   "pad", "iota", "convert", "bitcast", "bitcast-convert",
                   "reverse"):
            # copy/convert are CPU-lowering artifacts TPU fuses away; the
            # rest genuinely move data through HBM.
            if opc == "dynamic-update-slice":
                # in-place: traffic = the update slice (2nd operand), r+w
                ops = _OPERANDS_RE.findall(rest[len(om.group(0)):])
                upd = (_shape_info(comp.symbols.get(ops[1], ""))[1]
                       if len(ops) > 1 else out_bytes)
                cost.hbm_bytes += 2.0 * upd
                if "flashable" in line:
                    cost.flash_bytes += 2.0 * upd
            elif opc in ("transpose", "concatenate", "gather", "pad"):
                cost.hbm_bytes += 2.0 * out_bytes
                if "flashable" in line:
                    cost.flash_bytes += 2.0 * out_bytes
            continue
    comp.local = cost
    comp.calls = calls


def analyze(hlo_text: str) -> Cost:
    comps = _split_computations(hlo_text)
    for c in comps.values():
        _analyze_comp(c, comps)
    entry = None
    for c in comps.values():
        if getattr(c, "is_entry", False):
            entry = c
    if entry is None:  # fall back: last computation
        entry = list(comps.values())[-1]

    memo: dict[str, Cost] = {}

    def total(name: str) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = Cost()
        if comp is None:
            return out
        memo[name] = out           # break cycles defensively
        out.add(comp.local)
        for callee, mult in comp.calls:
            out.add(total(callee), mult)
        return out

    return total(entry.name)
