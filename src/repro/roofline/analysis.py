"""Roofline terms from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

FLOPs/bytes/collective-bytes come from a trip-count-aware walk of the
post-partitioning HLO (:mod:`repro.roofline.hlo_cost`) — XLA's own
``cost_analysis()`` counts a scanned layer stack ONCE, silently
undercounting depth-L models by ~L. XLA's numbers are kept in the
artifact as ``xla_cost`` for reference.

The SPMD module is per-device, so all terms are per-chip directly.

``roofline_fraction`` compares the workload's *intrinsic* best time
(max of useful-FLOP time and unavoidable-bytes time — weights once per
step, plus KV cache for decode) against the dominant compiled term; this
is the score the §Perf hillclimb drives up.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

from repro.roofline import hw, hlo_cost

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per chip
    hlo_bytes: float            # per chip (HBM-boundary model)
    coll_bytes: float           # per chip
    coll_breakdown: dict = field(default_factory=dict)
    model_flops: float = 0.0    # global useful FLOPs (6ND / 2ND)
    ideal_bytes: float = 0.0    # global unavoidable bytes (weights/cache)
    bytes_per_device: float = 0.0
    peak_memory_ok: bool = True
    xla_cost: dict = field(default_factory=dict)
    # cross-calibration vs XLA's count-a-while-body-once convention:
    # analyze(count_trips=False) compared to cost_analysis() per term
    calibration: dict = field(default_factory=dict)
    # Pallas-kernel traffic substitution (§Perf iteration "flash"):
    # flash_bytes = HBM traffic of the XLA-path attention/scan regions
    # (tagged "flashable_*" scopes); kernel_bytes = what the validated
    # Pallas kernels move for the same math (q/k/v/o + state tiles).
    flash_bytes: float = 0.0
    kernel_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / hw.ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_ideal(self) -> float:
        t_f = (self.model_flops / self.chips) / hw.PEAK_FLOPS_BF16
        t_b = (self.ideal_bytes / self.chips) / hw.HBM_BW
        return max(t_f, t_b)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs per chip (remat/redundancy waste)."""
        per_chip = self.model_flops / self.chips
        return per_chip / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        return self.t_ideal / self.t_bound if self.t_bound else 0.0

    @property
    def ai_fraction(self) -> float:
        """Accelerable share of the serialized term sum: the compute term
        is what an s×-faster accelerator shrinks; memory + collective
        terms are the infrastructure tax that stays. This is the measured
        analogue of the paper's per-stage ``ai_fraction`` constants and
        feeds :func:`repro.core.acceleration.profile_from_roofline`."""
        tot = self.t_compute + self.t_memory + self.t_collective
        return self.t_compute / tot if tot else 0.0

    def stage_profile(self):
        """This cell as an Amdahl stage profile (measured, not paper)."""
        from repro.core import acceleration
        return acceleration.StageProfile(
            f"{self.arch}:{self.shape}", self.ai_fraction)

    # ---- Pallas-kernel variant (same compiled artifact, substituted
    # traffic for the tagged regions) ----
    @property
    def t_memory_pallas(self) -> float:
        return max(self.hlo_bytes - self.flash_bytes + self.kernel_bytes,
                   0.0) / hw.HBM_BW

    @property
    def t_bound_pallas(self) -> float:
        return max(self.t_compute, self.t_memory_pallas, self.t_collective)

    @property
    def bottleneck_pallas(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory_pallas,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def roofline_fraction_pallas(self) -> float:
        return self.t_ideal / self.t_bound_pallas if self.t_bound_pallas else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 t_bound=self.t_bound, t_ideal=self.t_ideal,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction,
                 ai_fraction=self.ai_fraction,
                 t_memory_pallas=self.t_memory_pallas,
                 t_bound_pallas=self.t_bound_pallas,
                 bottleneck_pallas=self.bottleneck_pallas,
                 roofline_fraction_pallas=self.roofline_fraction_pallas)
        return d


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (inference), global."""
    counts = cfg.param_counts()
    n = counts["active"]
    tokens = shape.global_batch * shape.seq_len
    if cfg.encdec:
        tokens = shape.global_batch * (shape.seq_len
                                       + shape.seq_len // cfg.dec_ratio)
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch     # decode: one token per sequence


def ideal_bytes_estimate(cfg, shape, param_bytes: float,
                         cache_bytes: float = 0.0) -> float:
    """Unavoidable global HBM traffic per step."""
    if shape.kind == "train":
        # fwd read + bwd read + grad write + opt read(m,v)+write(m,v,p)
        # with f32 master+moments: ~7 passes over f32 params
        return 7.0 * param_bytes
    if shape.kind == "prefill":
        return param_bytes
    return param_bytes + cache_bytes        # decode reads weights + cache


def kernel_ideal_bytes(cfg, shape, chips: int) -> float:
    """Per-chip HBM traffic of the Pallas kernels for this cell's tagged
    regions: q/k/v/o tiles for attention, input/output streams for the
    SSM/RWKV scans, cache reads for decode. Scores and per-step states
    stay in VMEM. Training multiplies by 4 (fwd + remat recompute + a
    ~2x backward); prefill is 1x."""
    B, S = shape.global_batch, shape.seq_len
    elt = 2.0                                     # bf16
    mult = 4.0 if shape.kind == "train" else 1.0
    D = cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    if cfg.mla is not None:
        H = KV = cfg.n_heads
        D = cfg.mla.qk_nope + cfg.mla.qk_rope
    total = 0.0
    n_rep = cfg.n_repeats
    for spec in cfg.block_pattern:
        if spec.kind == "attn":
            if shape.kind == "decode":
                L = min(spec.window, S) if spec.window else S
                total += n_rep * B * L * 2 * KV * D * elt     # cache read
            else:
                tok = B * S
                total += n_rep * mult * tok * D * (2 * H + 2 * KV) * elt
        elif spec.kind == "mamba":
            di = cfg.ssm_expand * cfg.d_model
            tok = B * (1 if shape.kind == "decode" else S)
            total += n_rep * mult * tok * (3 * di + 2 * cfg.ssm_state) * elt
        else:  # rwkv
            tok = B * (1 if shape.kind == "decode" else S)
            total += n_rep * mult * tok * 5 * cfg.d_model * elt
    if cfg.encdec and shape.kind != "decode":
        total += cfg.n_enc_layers * mult * B * S * 4 * H * D * elt
    return total / chips


def from_compiled(arch: str, shape_name: str, mesh_name: str, chips: int,
                  compiled, cfg, shape, *, param_bytes: float = 0.0,
                  cache_bytes: float = 0.0) -> Roofline:
    cost, cost_flat = hlo_cost.analyze_pair(compiled.as_text())
    xla = compiled.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    xla_small = {k: float(xla[k]) for k in ("flops", "bytes accessed")
                 if k in xla}
    # per-artifact calibration record: our count-body-once flops vs XLA's
    # (the trip-multiplied number is what the roofline terms consume).
    # flops_delta is None when the backend reports no flops — "no
    # comparison ran", not "perfect agreement".
    xf = xla_small.get("flops", 0.0)
    calibration = {
        "flops_untripped": cost_flat.flops,
        "xla_flops": xf,
        "flops_delta": (cost_flat.flops - xf) / xf if xf else None,
        "trip_multiplier": (cost.flops / cost_flat.flops
                            if cost_flat.flops else 1.0),
    }
    mem = compiled.memory_analysis()
    bpd = 0.0
    ok = True
    if mem is not None:
        bpd = float(getattr(mem, "temp_size_in_bytes", 0)
                    + getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0))
        ok = bpd <= hw.HBM_BYTES
    coll = {k: cost.coll[k] for k in _COLLECTIVES}
    coll["total"] = cost.coll_bytes
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops, hlo_bytes=cost.hbm_bytes,
        coll_bytes=cost.coll_bytes, coll_breakdown=coll,
        model_flops=model_flops_estimate(cfg, shape),
        ideal_bytes=ideal_bytes_estimate(
            cfg, shape, param_bytes, cache_bytes),
        bytes_per_device=bpd, peak_memory_ok=ok, xla_cost=xla_small,
        calibration=calibration,
        flash_bytes=cost.flash_bytes,
        kernel_bytes=kernel_ideal_bytes(cfg, shape, chips))
