"""First-order recurrence Pallas TPU kernels: Mamba scan and RWKV6 scan.

Both mixers are h_t = a_t * h_{t-1} + b_t recurrences (Mamba: diagonal
state per (channel, N); RWKV6: matrix state per head with per-channel
data-dependent decay). The kernels walk time as the innermost grid
dimension carrying the state in VMEM scratch — the (B, S, Di, N) /
(B, S, H, K, V) intermediates of the XLA associative-scan fallback never
exist in HBM, which is exactly the traffic the roofline's memory term
charges that fallback for.

Tiling: channels ride the 128-lane dimension; each grid step stages a
``blk_t``-step time tile into VMEM and walks it with an unrolled loop.
State stays resident across the whole sequence for a fixed (batch, tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# Mamba selective scan
# --------------------------------------------------------------------------

def _mamba_kernel(delta_ref, dx_ref, a_ref, b_ref, c_ref, h0_ref,
                  y_ref, hout_ref, h_scr, *, blk_t: int, n_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    delta = delta_ref[0].astype(jnp.float32)   # (blk_t, blk_c)
    dx = dx_ref[0].astype(jnp.float32)         # (blk_t, blk_c)
    bt = b_ref[0].astype(jnp.float32)          # (blk_t, N)
    ct = c_ref[0].astype(jnp.float32)          # (blk_t, N)
    A = a_ref[...].astype(jnp.float32)         # (N, blk_c)

    h = h_scr[...]                             # (N, blk_c)
    ys = []
    for t in range(blk_t):
        a_t = jnp.exp(delta[t][None, :] * A)
        h = a_t * h + bt[t][:, None] * dx[t][None, :]
        ys.append(jnp.sum(h * ct[t][:, None], axis=0))
    h_scr[...] = h
    y_ref[0] = jnp.stack(ys).astype(y_ref.dtype)

    @pl.when(ti == n_t - 1)
    def _out():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


def mamba_scan(delta, A, Bt, Ct, x, h0=None, *, blk_t: int = 16,
               blk_c: int = 128, interpret: bool = False):
    """Same contract as :func:`repro.kernels.ref.mamba_scan`."""
    B, S, Di = delta.shape
    N = A.shape[1]
    blk_t = min(blk_t, S)
    blk_c = min(blk_c, Di)
    assert S % blk_t == 0 and Di % blk_c == 0, (S, blk_t, Di, blk_c)
    n_t, n_c = S // blk_t, Di // blk_c
    if h0 is None:
        h0 = jnp.zeros((B, Di, N), jnp.float32)

    dx = (delta * x)
    At = A.T                                    # (N, Di)
    h0t = h0.transpose(0, 2, 1)                 # (B, N, Di)

    kern = functools.partial(_mamba_kernel, blk_t=blk_t, n_t=n_t)
    y, hout = pl.pallas_call(
        kern,
        grid=(B, n_c, n_t),
        in_specs=[
            pl.BlockSpec((1, blk_t, blk_c), lambda b, c, t: (b, t, c)),
            pl.BlockSpec((1, blk_t, blk_c), lambda b, c, t: (b, t, c)),
            pl.BlockSpec((N, blk_c), lambda b, c, t: (0, c)),
            pl.BlockSpec((1, blk_t, N), lambda b, c, t: (b, t, 0)),
            pl.BlockSpec((1, blk_t, N), lambda b, c, t: (b, t, 0)),
            pl.BlockSpec((1, N, blk_c), lambda b, c, t: (b, 0, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_t, blk_c), lambda b, c, t: (b, t, c)),
            pl.BlockSpec((1, N, blk_c), lambda b, c, t: (b, 0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), x.dtype),
            jax.ShapeDtypeStruct((B, N, Di), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, blk_c), jnp.float32)],
        interpret=interpret,
    )(delta, dx, At, Bt, Ct, h0t)
    return y, hout.transpose(0, 2, 1)


# --------------------------------------------------------------------------
# RWKV6 scan (matrix state, data-dependent decay, bonus term)
# --------------------------------------------------------------------------

def _rwkv_kernel(r_ref, w_ref, k_ref, v_ref, u_ref, h0_ref,
                 o_ref, hout_ref, h_scr, *, blk_t: int, n_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)        # (blk_t, K)
    w = w_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)        # (blk_t, V)
    u = u_ref[0].astype(jnp.float32)           # (K,)

    h = h_scr[...]                             # (K, V)
    os_ = []
    for t in range(blk_t):
        kv = k[t][:, None] * v[t][None, :]
        att = h + u[:, None] * kv
        os_.append(jax.lax.dot(r[t][None, :], att)[0])   # (V,)
        h = w[t][:, None] * h + kv
    h_scr[...] = h
    o_ref[0, 0] = jnp.stack(os_).astype(o_ref.dtype)

    @pl.when(ti == n_t - 1)
    def _out():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


def rwkv_scan(r, w, k, v, u, h0=None, *, blk_t: int = 16,
              interpret: bool = False):
    """Same contract as :func:`repro.kernels.ref.rwkv_scan`."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    blk_t = min(blk_t, S)
    assert S % blk_t == 0, (S, blk_t)
    n_t = S // blk_t
    if h0 is None:
        h0 = jnp.zeros((B, H, K, V), jnp.float32)

    rt, wt, kt = (t.transpose(0, 2, 1, 3) for t in (r, w, k))  # (B,H,S,K)
    vt = v.transpose(0, 2, 1, 3)                               # (B,H,S,V)

    kern = functools.partial(_rwkv_kernel, blk_t=blk_t, n_t=n_t)
    o, hout = pl.pallas_call(
        kern,
        grid=(B, H, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, blk_t, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, blk_t, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, blk_t, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, blk_t, V), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, K), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk_t, V), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, V), v.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rt, wt, kt, vt, u, h0)
    return o.transpose(0, 2, 1, 3), hout
