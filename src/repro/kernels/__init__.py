"""Pallas TPU kernel layer (optional substrate).

Compute hot-spots the paper's workloads motivate get a custom kernel
here — attention (flash/decode), linear scans (Mamba/RWKV), matmul
with fused epilogues, bilinear resize, and the pre/post-processing set
(``preproc``: YUV decode, fused letterbox+normalize, pairwise IoU).
Every op is reachable through :mod:`repro.kernels.ops`, which
dispatches between ``ref`` (pure-jnp oracle), ``xla`` (memory-bounded
JAX, lowers anywhere), and ``pallas`` (TPU kernels, ``interpret=True``
on CPU); tilings resolve through the persistent autotune cache
(:mod:`repro.kernels.autotune`).
"""
