"""Public kernel entry points.

Every op has three implementations:
  * ``ref``    — the naive pure-jnp oracle in :mod:`repro.kernels.ref`
                 (small sizes only; ground truth for tests).
  * ``xla``    — a memory-bounded pure-JAX path (chunked / associative scans)
                 that lowers on any backend. This is what the multi-pod
                 dry-run compiles, since Pallas-TPU kernels cannot lower on
                 the CPU backend of this container.
  * ``pallas`` — the Pallas TPU kernel (``interpret=True`` on CPU for tests).

``set_default_impl`` switches the default globally (models call these ops
without an explicit ``impl=``).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

Impl = Literal["ref", "xla", "pallas", "pallas_interpret"]
_DEFAULT_IMPL: Impl = "xla"
NEG_INF = _ref.NEG_INF


def set_default_impl(impl: Impl) -> None:
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def get_default_impl() -> Impl:
    return _DEFAULT_IMPL


@contextlib.contextmanager
def default_impl(impl: Impl):
    prev = _DEFAULT_IMPL
    set_default_impl(impl)
    try:
        yield
    finally:
        set_default_impl(prev)


def _resolve(impl: Impl | None) -> Impl:
    return _DEFAULT_IMPL if impl is None else impl


# --------------------------------------------------------------------------
# Attention (prefill / train)
# --------------------------------------------------------------------------

def attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, KV, D)
    v: jax.Array,            # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,
    scale: float | None = None,
    impl: Impl | None = None,
    q_chunk: int = 1024,
    blk_q: int | None = None,
    blk_k: int | None = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, kv_len=kv_len, scale=scale)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        if blk_q is None or blk_k is None:
            from repro.kernels import autotune
            tuned = autotune.attention_tiling(q.shape[1], k.shape[1],
                                              q.shape[-1], str(q.dtype))
            if tuned is not None:   # else: kernel's own clamped defaults
                blk_q = blk_q if blk_q is not None else tuned["blk_q"]
                blk_k = blk_k if blk_k is not None else tuned["blk_k"]
        blks = {kk: vv for kk, vv in
                (("blk_q", blk_q), ("blk_k", blk_k)) if vv is not None}
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, scale=scale, **blks,
                                  interpret=(impl == "pallas_interpret"))
    return _xla_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, kv_len=kv_len, scale=scale,
                          q_chunk=q_chunk)


def _xla_attention(q, k, v, *, causal, window, q_offset, kv_len, scale, q_chunk):
    """Memory-bounded attention: lax.scan over q chunks.

    Peak score buffer is (B, KV, G, q_chunk, Skv_band) instead of the full
    (Sq, Skv) square. With a sliding window, only the (q_chunk + window) key
    band is sliced per chunk, making local-attention cost O(S·W) not O(S²).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = (1.0 / D**0.5) if scale is None else scale

    if Sq <= q_chunk:
        return _attn_block(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, kv_len=kv_len, scale=scale,
                           k_offset=0)

    n_chunks = -(-Sq // q_chunk)
    pad = n_chunks * q_chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qs = qp.reshape(B, n_chunks, q_chunk, H, D).transpose(1, 0, 2, 3, 4)

    banded = window is not None and Skv > q_chunk + window
    band = None
    if banded:
        band = q_chunk + window
        band = min(band + (-band) % 128, Skv)   # pad band to lane multiple

    def chunk_fn(_, ci_q):
        ci, qc = ci_q
        off = q_offset + ci * q_chunk
        if banded:
            # keys in (off - window, off + q_chunk] → slice a static-size band
            start = jnp.clip(off - window + 1, 0, Skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            o = _attn_block(qc, kc, vc, causal=causal, window=window,
                            q_offset=off - start, kv_len=None, scale=scale,
                            k_offset=0)
        else:
            o = _attn_block(qc, k, v, causal=causal, window=window,
                            q_offset=off, kv_len=kv_len, scale=scale,
                            k_offset=0)
        return None, o

    _, outs = jax.lax.scan(chunk_fn, None,
                           (jnp.arange(n_chunks), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * q_chunk, H,
                                                v.shape[-1])
    return out[:, :Sq]


def _attn_block(q, k, v, *, causal, window, q_offset, kv_len, scale, k_offset):
    """Score block in full-head (MHA-expanded) layout.

    KV heads are broadcast up to H before the score einsum so the (B, H,
    Sq, Skv) score tensor shards cleanly over the model axis even when
    KV < model-axis size (e.g. 8 KV heads on a 16-way axis — in grouped
    (KV, G) layout the leading dim can't shard and the f32 scores blow up
    per-device memory). The Pallas kernel avoids the expansion on TPU.
    """
    from repro.distributed.sharding import shard
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    with jax.named_scope("flashable_attention"):
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
        s = jnp.einsum("bqhd,bshd->bhqs",
                       q.astype(jnp.float32) * scale, k.astype(jnp.float32))
        # primary: shard scores over heads; fallback "attn_q" shards the
        # query rows instead when H doesn't divide the model axis (e.g.
        # 40 or 20 heads on a 16-way axis) — the conflict resolver in
        # spec_for gives heads priority, so this is a no-op otherwise.
        s = shard(s, "batch", "heads", "attn_q", None)
        q_pos = jnp.arange(Sq)[:, None] + q_offset
        k_pos = jnp.arange(Skv)[None, :] + k_offset
        mask = jnp.ones((Sq, Skv), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        if kv_len is not None:
            s = jnp.where((k_pos < kv_len[:, None])[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32))
        return o.astype(q.dtype)


# --------------------------------------------------------------------------
# Decode attention (one new token against a KV cache)
# --------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,          # (B, 1, H, D)
    k: jax.Array,          # (B, L, KV, D) cache
    v: jax.Array,          # (B, L, KV, D)
    *,
    kv_len: jax.Array,     # (B,) number of valid cache entries
    window: int | None = None,
    scale: float | None = None,
    impl: Impl | None = None,
    blk_k: int | None = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        if blk_k is None:
            from repro.kernels import autotune
            blk_k = autotune.decode_tiling(k.shape[1], q.shape[-1],
                                           str(q.dtype))["blk_k"]
        return da.decode_attention(q, k, v, kv_len=kv_len, window=window,
                                   scale=scale, blk_k=blk_k,
                                   interpret=(impl == "pallas_interpret"))
    B, _, H, D = q.shape
    _, L, KV, _ = k.shape
    G = H // KV
    scale = (1.0 / D**0.5) if scale is None else scale
    with jax.named_scope("flashable_decode"):
        s = jnp.einsum("bkgd,bskd->bkgs",
                       (q[:, 0].astype(jnp.float32) * scale).reshape(B, KV, G, D),
                       k.astype(jnp.float32))
        k_pos = jnp.arange(L)[None, :]
        valid = k_pos < kv_len[:, None]
        if window is not None:
            valid &= k_pos > (kv_len[:, None] - 1 - window)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
        return o.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Selective SSM scan (Mamba)
# --------------------------------------------------------------------------

def mamba_scan(
    delta: jax.Array,   # (B, S, Di)
    A: jax.Array,       # (Di, N)
    Bt: jax.Array,      # (B, S, N)
    Ct: jax.Array,      # (B, S, N)
    x: jax.Array,       # (B, S, Di)
    h0: jax.Array | None = None,
    *,
    impl: Impl | None = None,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.mamba_scan(delta, A, Bt, Ct, x, h0)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import linear_scan as ls
        return ls.mamba_scan(delta, A, Bt, Ct, x, h0,
                             interpret=(impl == "pallas_interpret"))
    return _xla_mamba_scan(delta, A, Bt, Ct, x, h0, chunk=chunk)


def _first_order_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def _xla_mamba_scan(delta, A, Bt, Ct, x, h0, *, chunk):
    """Chunked scan: lax.scan over chunks of ``chunk`` steps; inside a chunk
    an associative scan over the first-order recurrence. The (B,C,Di,N)
    tensors are materialized only per-chunk, bounding memory, and only one
    state per chunk boundary is saved for the backward pass."""
    B, S, Di = delta.shape
    N = A.shape[1]
    C = min(chunk, S)
    n = -(-S // C)
    pad = n * C - S

    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2)) if pad else t

    dl, bt, ct, xs = (pad_t(t).reshape(B, n, C, -1).transpose(1, 0, 2, 3)
                      for t in (delta, Bt, Ct, x))
    h = (jnp.zeros((B, Di, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def chunk_fn(h, inp):
        with jax.named_scope("flashable_mamba_scan"):
            dlc, btc, ctc, xc = inp        # (B, C, ·)
            dA = jnp.exp(dlc.astype(jnp.float32)[..., None] * A[None, None])      # (B,C,Di,N)
            dBx = ((dlc * xc).astype(jnp.float32)[..., None]
                   * btc.astype(jnp.float32)[:, :, None])                          # (B,C,Di,N)
            # fold carry into the first element
            dBx = dBx.at[:, 0].add(dA[:, 0] * h)
            a_cum, h_all = jax.lax.associative_scan(_first_order_combine,
                                                    (dA, dBx), axis=1)
            y = jnp.einsum("bcdn,bcn->bcd", h_all, ctc.astype(jnp.float32))
            return h_all[:, -1], y

    h, ys = jax.lax.scan(chunk_fn, h, (dl, bt, ct, xs))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n * C, Di)[:, :S]
    return y.astype(x.dtype), h


# --------------------------------------------------------------------------
# RWKV6 linear-attention scan (data-dependent decay, matrix state)
# --------------------------------------------------------------------------

def rwkv_scan(
    r: jax.Array,   # (B, S, H, K)
    w: jax.Array,   # (B, S, H, K) decay in (0, 1)
    k: jax.Array,   # (B, S, H, K)
    v: jax.Array,   # (B, S, H, V)
    u: jax.Array,   # (H, K)
    h0: jax.Array | None = None,
    *,
    impl: Impl | None = None,
    chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.rwkv_scan(r, w, k, v, u, h0)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import linear_scan as ls
        return ls.rwkv_scan(r, w, k, v, u, h0,
                            interpret=(impl == "pallas_interpret"))
    return _xla_rwkv_scan(r, w, k, v, u, h0, chunk=chunk)


def _xla_rwkv_scan(r, w, k, v, u, h0, *, chunk):
    """Chunked associative scan of h_t = diag(w_t) h_{t-1} + k_t v_t^T."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, S)
    n = -(-S // C)
    pad = n * C - S

    def pad_t(t, one_pad=False):
        if not pad:
            return t
        cfg = [(0, 0), (0, pad), (0, 0), (0, 0)]
        t = jnp.pad(t, cfg, constant_values=1.0 if one_pad else 0.0)
        return t

    rc = pad_t(r).reshape(B, n, C, H, K).transpose(1, 0, 2, 3, 4)
    wc = pad_t(w, one_pad=True).reshape(B, n, C, H, K).transpose(1, 0, 2, 3, 4)
    kc = pad_t(k).reshape(B, n, C, H, K).transpose(1, 0, 2, 3, 4)
    vc = pad_t(v).reshape(B, n, C, H, V).transpose(1, 0, 2, 3, 4)
    h = (jnp.zeros((B, H, K, V), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def chunk_fn(h, inp):
        with jax.named_scope("flashable_rwkv_scan"):
            rr, ww, kk, vv = (t.astype(jnp.float32) for t in inp)   # (B,C,H,·)
            kv = kk[..., :, None] * vv[..., None, :]                # (B,C,H,K,V)
            a = ww[..., :, None]                                    # (B,C,H,K,1)
            b = kv.at[:, 0].add(a[:, 0] * h)
            _, h_all = jax.lax.associative_scan(_first_order_combine, (a, b),
                                                axis=1)
            h_prev = jnp.concatenate([h[:, None], h_all[:, :-1]], axis=1)
            o = jnp.einsum("bchk,bchkv->bchv", rr,
                           h_prev + uf[None, None, :, :, None] * kv)
            return h_all[:, -1], o

    h, os_ = jax.lax.scan(chunk_fn, h, (rc, wc, kc, vc))
    o = os_.transpose(1, 0, 2, 3, 4).reshape(B, n * C, H, V)[:, :S]
    return o.astype(v.dtype), h


def rwkv_decode_step(r, w, k, v, u, h):
    """Single-token RWKV update. r/w/k: (B,H,K), v: (B,H,V), h: (B,H,K,V)."""
    rf, wf, kf, vf = (t.astype(jnp.float32) for t in (r, w, k, v))
    kv = kf[..., :, None] * vf[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", rf, h + u[None, :, :, None].astype(jnp.float32) * kv)
    h = wf[..., :, None] * h + kv
    return o.astype(v.dtype), h


def mamba_decode_step(delta, A, Bt, Ct, x, h):
    """Single-token Mamba update. delta/x: (B,Di), Bt/Ct: (B,N), h: (B,Di,N)."""
    dA = jnp.exp(delta.astype(jnp.float32)[..., None] * A[None])
    dBx = (delta * x).astype(jnp.float32)[..., None] * Bt.astype(jnp.float32)[:, None]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, Ct.astype(jnp.float32))
    return y.astype(x.dtype), h


# --------------------------------------------------------------------------
# Matmul (batched-inference contraction for the micro-batched face models)
# --------------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array, *, bias: jax.Array | None = None,
           epilogue: str = "none", impl: Impl | None = None,
           blk_m: int | None = None, blk_n: int | None = None,
           blk_k: int | None = None) -> jax.Array:
    """(M, K) @ (K, N) with float32 accumulation.

    ``bias`` ((N,)) and ``epilogue`` (``"none"``/``"tanh"``) fuse the
    MLP tail into the contraction — on the Pallas path they run on the
    accumulator in VMEM, skipping an HBM round trip between a layer's
    matmul and its activation.

    Block sizes left as ``None`` resolve to autotuned tilings for this
    (shape, dtype) from :mod:`repro.kernels.autotune` (persistent-cache
    lookup; a miss runs the candidate sweep once and memoizes).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import matmul as mm
        blocks = _tuned_matmul_blocks(a.shape, b.shape, a.dtype,
                                      blk_m, blk_n, blk_k)
        return mm.matmul(a, b, bias=bias, epilogue=epilogue, **blocks,
                         interpret=(impl == "pallas_interpret"))
    # ref and xla coincide: XLA's dot is already the memory-optimal form
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if epilogue == "tanh":
        out = jnp.tanh(out)
    return out.astype(a.dtype)


def _tuned_matmul_blocks(a_shape, b_shape, dtype, blk_m, blk_n, blk_k):
    """Fill unspecified block sizes from the autotune cache."""
    if blk_m is not None and blk_n is not None and blk_k is not None:
        return {"blk_m": blk_m, "blk_n": blk_n, "blk_k": blk_k}
    from repro.kernels import autotune
    tuned = autotune.matmul_tiling(a_shape[0], a_shape[1], b_shape[1],
                                   str(dtype))
    return {"blk_m": blk_m if blk_m is not None else tuned["blk_m"],
            "blk_n": blk_n if blk_n is not None else tuned["blk_n"],
            "blk_k": blk_k if blk_k is not None else tuned["blk_k"]}


# --------------------------------------------------------------------------
# Bilinear resize (video-analytics pre-processing — the paper's resize tax)
# --------------------------------------------------------------------------

def resize_bilinear(img: jax.Array, out_h: int, out_w: int,
                    *, impl: Impl | None = None,
                    blk_oh: int | None = None) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import resize as rs
        if blk_oh is None:
            from repro.kernels import autotune
            blk_oh = autotune.resize_tiling(
                img.shape[-3], img.shape[-2], out_h, out_w,
                str(img.dtype))["blk_oh"]
        return rs.resize_bilinear(img, out_h, out_w, blk_oh=blk_oh,
                                  interpret=(impl == "pallas_interpret"))
    return _ref.resize_bilinear(img, out_h, out_w)
