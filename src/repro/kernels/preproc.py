"""Pallas TPU kernels for the pre/post-processing tax hot-spots.

The paper's §4.3 CPU breakdown charges 17.8% of Face Recognition's
cycles to resizing and a further slice to tensor preparation — work
that stays on the host after the AI is accelerated. These kernels move
the three dense pre/post stages onto the accelerator:

  * :func:`yuv_to_rgb` — frame decode-emulation: the per-pixel 3x3
    color transform from planar 4:4:4 YUV (what the camera/codec
    ships) to the RGB the detector consumes. Pure VPU element-wise
    work, one plane triple per grid step.
  * :func:`letterbox_normalize` — aspect-preserving resize + center
    pad + per-channel affine normalization fused into ONE program.
    Like :mod:`repro.kernels.resize`, the separable bilinear runs as
    two MXU matmuls (``Ly @ img @ Lx^T`` with letterbox-embedded
    operators); the normalization and pad fill run on the accumulator
    while it is still in VMEM, so the frame crosses HBM exactly once.
  * :func:`iou_matrix` — the O(N^2) half of greedy NMS: pairwise IoU
    over component-major boxes, row-blocked over the grid. The greedy
    suppression scan itself is tiny and sequential and stays in the
    surrounding jitted program (:mod:`repro.preprocess.device`).

All kernels take ``interpret=True`` on CPU (tests/this container).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# Planar YUV -> RGB (decode-emulation)
# --------------------------------------------------------------------------

def _yuv_kernel(yuv_ref, o_ref):
    x = yuv_ref[0].astype(jnp.float32)            # (3, H, W)
    y = x[0]
    u = x[1] - 128.0
    v = x[2] - 128.0
    r = y + 1.402 * v
    g = y - 0.344136 * u - 0.714136 * v
    b = y + 1.772 * u
    rgb = jnp.stack([r, g, b], axis=-1)           # (H, W, 3)
    o_ref[0] = jnp.clip(jnp.round(rgb), 0.0, 255.0).astype(o_ref.dtype)


def yuv_to_rgb(yuv: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(B, 3, H, W) planar uint8 -> (B, H, W, 3) uint8 (BT.601 full)."""
    B, _, H, W = yuv.shape
    return pl.pallas_call(
        _yuv_kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, 3, H, W), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, H, W, 3), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, 3), jnp.uint8),
        interpret=interpret,
    )(yuv)


# --------------------------------------------------------------------------
# Fused letterbox resize + normalization
# --------------------------------------------------------------------------

def _letterbox_kernel(blk: int, top: int, ch: int, left: int, cw: int,
                      pad_value: float,
                      img_ref, ly_ref, lx_ref, sb_ref, o_ref):
    img = img_ref[0].astype(jnp.float32)          # (H, W)
    t = jax.lax.dot(ly_ref[...], img)             # (blk, W)
    t = jax.lax.dot(t, lx_ref[...].T)             # (blk, out_w)
    out_w = t.shape[1]
    i = pl.program_id(1)
    rows = i * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, out_w), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (blk, out_w), 1)
    inside = ((rows >= top) & (rows < top + ch)
              & (cols >= left) & (cols < left + cw))
    norm = t * sb_ref[0, 0] + sb_ref[0, 1]
    o_ref[0] = jnp.where(inside, norm,
                         jnp.float32(pad_value)).astype(o_ref.dtype)


def letterbox_normalize(img: jax.Array, ly: jax.Array, lx: jax.Array,
                        sb: jax.Array, geometry: tuple[int, int, int, int],
                        *, pad_value: float = 0.0, blk_oh: int = 128,
                        interpret: bool = False) -> jax.Array:
    """Fused letterbox+normalize over channel-major planes.

    ``img``: (NB, H, W) planes (batch*channel, channel fastest);
    ``ly``/``lx``: letterbox-embedded interpolation operators
    (out_h, H)/(out_w, W); ``sb``: (NB, 2) per-plane [scale, offset]
    (channel-dependent normalization in plane order); ``geometry``:
    (content_h, content_w, top, left) from
    :func:`repro.preprocess.host.letterbox_geometry`. Returns
    (NB, out_h, out_w) float32.
    """
    NB, H, W = img.shape
    out_h, out_w = ly.shape[0], lx.shape[0]
    ch, cw, top, left = geometry
    blk = min(blk_oh, out_h)
    pad = (-out_h) % blk
    if pad:
        ly = jnp.pad(ly, ((0, pad), (0, 0)))
    n_blocks = (out_h + pad) // blk
    kernel = functools.partial(_letterbox_kernel, blk, top, ch, left, cw,
                               pad_value)
    out = pl.pallas_call(
        kernel,
        grid=(NB, n_blocks),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((blk, H), lambda n, i: (i, 0)),
            pl.BlockSpec((out_w, W), lambda n, i: (0, 0)),
            pl.BlockSpec((1, 2), lambda n, i: (n, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, blk, out_w), lambda n, i: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((NB, out_h + pad, out_w),
                                       jnp.float32),
        interpret=interpret,
    )(img, ly, lx, sb)
    return out[:, :out_h]


# --------------------------------------------------------------------------
# Pairwise IoU (the dense half of NMS)
# --------------------------------------------------------------------------

def _iou_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)            # (4, blk)  row block
    b = b_ref[...].astype(jnp.float32)            # (4, N)    all boxes
    ay0, ax0, ay1, ax1 = (a[j][:, None] for j in range(4))
    by0, bx0, by1, bx1 = (b[j][None, :] for j in range(4))
    area_a = (ay1 - ay0) * (ax1 - ax0)
    area_b = (by1 - by0) * (bx1 - bx0)
    ih = jnp.maximum(0.0, jnp.minimum(ay1, by1) - jnp.maximum(ay0, by0))
    iw = jnp.maximum(0.0, jnp.minimum(ax1, bx1) - jnp.maximum(ax0, bx0))
    inter = ih * iw
    union = area_a + area_b - inter
    o_ref[...] = inter / jnp.maximum(union, 1e-12)


def iou_matrix(boxes_t: jax.Array, *, blk_n: int = 128,
               interpret: bool = False) -> jax.Array:
    """(4, N) component-major float32 boxes -> (N, N) pairwise IoU."""
    _, N = boxes_t.shape
    blk = min(blk_n, N)
    pad = (-N) % blk
    if pad:
        boxes_t = jnp.pad(boxes_t, ((0, 0), (0, pad)))
    Np = N + pad
    out = pl.pallas_call(
        _iou_kernel,
        grid=(Np // blk,),
        in_specs=[
            pl.BlockSpec((4, blk), lambda i: (0, i)),
            pl.BlockSpec((4, Np), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Np), jnp.float32),
        interpret=interpret,
    )(boxes_t, boxes_t)
    return out[:N, :N]
