"""Decode attention Pallas TPU kernel (one token vs a long KV cache).

Decode is bandwidth-bound: the kernel streams the cache HBM->VMEM once in
(blk_k x D) tiles and keeps the online-softmax state in VMEM scratch. All
G query heads of a KV group are processed together as the (sublane) rows
of one tile so the MXU sees a (G x D) @ (D x blk_k) matmul per tile
instead of G vector products.

Grid: (batch, kv_heads, kv_blocks). Validity (kv_len) and sliding-window
masks are applied per tile; fully-invalid tiles are skipped before any
VMEM compute via pl.when on the block index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

_LANE = 128


def legal_blk_k(blk_k: int, L: int) -> int:
    """Largest KV tile <= ``blk_k`` whose grid tiles ``L`` exactly.

    The kernel's (batch, kv_heads, kv_blocks) grid requires ``L % blk_k
    == 0``; a requested tile (the default, or an autotuned pick keyed on
    a different cache length) is rounded down to the largest divisor of
    ``L`` — preferring lane-aligned (multiple-of-128) tiles so the MXU
    edge stays full — instead of tripping a trace-time assert on cache
    lengths like 768 that the default 512 does not divide.
    """
    b = min(blk_k, L)
    if b <= 0:
        return L
    if L % b == 0:
        return b
    for c in range(b - b % _LANE, 0, -_LANE):
        if L % c == 0:
            return c
    for c in range(b, 0, -1):
        if L % c == 0:
            return c
    return L


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, window: int | None, blk_k: int, n_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    first_k = ki * blk_k
    run = first_k < kv_len
    if window is not None:
        run &= (ki + 1) * blk_k > kv_len - window

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (blk_k, D)
        v = v_ref[0, 0].astype(jnp.float32)                  # (blk_k, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, blk_k)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos < kv_len
        if window is not None:
            valid &= k_pos > kv_len - 1 - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,          # (B, 1, H, D)
    k: jax.Array,          # (B, L, KV, D)
    v: jax.Array,          # (B, L, KV, Dv)
    *,
    kv_len: jax.Array,     # (B,) valid entries
    window: int | None = None,
    scale: float | None = None,
    blk_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, D = q.shape
    _, L, KV, Dv = v.shape
    G = H // KV
    scale = (1.0 / D**0.5) if scale is None else scale
    blk_k = legal_blk_k(512 if blk_k is None else blk_k, L)
    n_blocks = L // blk_k

    qt = q.reshape(B, KV, G, D)                 # group-major layout
    kt = k.transpose(0, 2, 1, 3)                # (B, KV, L, D)
    vt = v.transpose(0, 2, 1, 3)

    kern = functools.partial(_kernel, scale=scale, window=window,
                             blk_k=blk_k, n_blocks=n_blocks)
    out = pl.pallas_call(
        kern,
        grid=(B, KV, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, blk_k, Dv), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qt, kt, vt)
    return out.reshape(B, 1, H, Dv)
