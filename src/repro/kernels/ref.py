"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (tests sweep
shapes/dtypes and assert_allclose kernel-vs-ref). They favour clarity over
memory efficiency — naive materialization is fine at test sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) with float32 accumulation, result in a.dtype."""
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(a.dtype)


def attention(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Skv, KV, D)
    v: jax.Array,          # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,   # (B,) valid kv length (decode caches)
    scale: float | None = None,
) -> jax.Array:
    """Naive GQA attention. Returns (B, Sq, H, D) in q.dtype.

    ``q_offset`` is the absolute position of q[0] (decode: cache length so
    far).  ``window`` is a sliding-window size (attend to keys in
    (pos - window, pos]).  ``kv_len`` masks out unwritten cache slots.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = (1.0 / D**0.5) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores: (B, KV, G, Sq, Skv)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf.reshape(B, Sq, KV, G, D), kf)
    q_pos = jnp.arange(Sq)[:, None] + q_offset        # (Sq, 1) absolute
    k_pos = jnp.arange(Skv)[None, :]                  # (1, Skv) absolute
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_len is not None:
        valid = k_pos < kv_len[:, None]               # (B, Skv)
        s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def mamba_scan(
    delta: jax.Array,    # (B, S, Di)   post-softplus step sizes
    A: jax.Array,        # (Di, N)      negative-real state matrix (diag)
    Bt: jax.Array,       # (B, S, N)    input->state projection (selective)
    Ct: jax.Array,       # (B, S, N)    state->output projection (selective)
    x: jax.Array,        # (B, S, Di)   inner activations
    h0: jax.Array | None = None,   # (B, Di, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Selective SSM scan (Mamba).  Returns (y (B,S,Di), h_final (B,Di,N)).

    h_t = exp(delta_t * A) * h_{t-1} + (delta_t * x_t) B_t
    y_t = (h_t C_t).sum(N)
    """
    B, S, Di = delta.shape
    N = A.shape[1]
    dA = jnp.exp(delta.astype(jnp.float32)[..., None] * A[None, None])   # (B,S,Di,N)
    dBx = (delta * x).astype(jnp.float32)[..., None] * Bt.astype(jnp.float32)[:, :, None]  # (B,S,Di,N)
    h = jnp.zeros((B, Di, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = dA[:, t] * h + dBx[:, t]
        y = jnp.einsum("bdn,bn->bd", h, Ct[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return ys.transpose(1, 0, 2).astype(x.dtype), h


def rwkv_scan(
    r: jax.Array,    # (B, S, H, K)  receptance
    w: jax.Array,    # (B, S, H, K)  per-step decay in (0,1)
    k: jax.Array,    # (B, S, H, K)
    v: jax.Array,    # (B, S, H, V)
    u: jax.Array,    # (H, K)        bonus for current token
    h0: jax.Array | None = None,     # (B, H, K, V)
) -> tuple[jax.Array, jax.Array]:
    """RWKV6-style linear attention with data-dependent decay.

    o_t = r_t . (h_{t-1} + diag(u) k_t v_t^T);  h_t = diag(w_t) h_{t-1} + k_t v_t^T
    Returns (o (B,S,H,V), h_final (B,H,K,V)).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    h = jnp.zeros((B, H, K, V), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    rf, wf, kf, vf = (a.astype(jnp.float32) for a in (r, w, k, v))
    uf = u.astype(jnp.float32)

    def step(h, t):
        kv = kf[:, t, :, :, None] * vf[:, t, :, None, :]           # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", rf[:, t], h + uf[None, :, :, None] * kv)
        h = wf[:, t, :, :, None] * h + kv
        return h, o

    h, os_ = jax.lax.scan(step, h, jnp.arange(S))
    return os_.transpose(1, 0, 2, 3).astype(v.dtype), h


def resize_bilinear(img: jax.Array, out_h: int, out_w: int) -> jax.Array:
    """Bilinear resize, align_corners=False (OpenCV/TF convention).

    img: (..., H, W, C) -> (..., out_h, out_w, C)
    """
    *lead, H, W, C = img.shape
    x = img.reshape((-1, H, W, C)).astype(jnp.float32)

    def axis_coords(out_n, in_n):
        c = (jnp.arange(out_n) + 0.5) * (in_n / out_n) - 0.5
        c = jnp.clip(c, 0.0, in_n - 1.0)
        lo = jnp.floor(c).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, in_n - 1)
        frac = c - lo
        return lo, hi, frac

    ylo, yhi, yf = axis_coords(out_h, H)
    xlo, xhi, xf = axis_coords(out_w, W)
    top = x[:, ylo][:, :, xlo] * (1 - xf)[None, None, :, None] + x[:, ylo][:, :, xhi] * xf[None, None, :, None]
    bot = x[:, yhi][:, :, xlo] * (1 - xf)[None, None, :, None] + x[:, yhi][:, :, xhi] * xf[None, None, :, None]
    out = top * (1 - yf)[None, :, None, None] + bot * yf[None, :, None, None]
    return out.reshape((*lead, out_h, out_w, C)).astype(img.dtype)
