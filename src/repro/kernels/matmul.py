"""Tiled matmul Pallas TPU kernel — the batched-inference workhorse.

The micro-batched face pipeline turns B per-face MLP calls into one
(B, d_in) @ (d_in, d_out) matmul; this kernel is the on-device form of
that contraction. Classic three-level tiling: the grid iterates
(m, n, k) blocks with k innermost, a float32 VMEM scratch accumulates
partial products across the k dimension, and the MXU sees one
(blk_m, blk_k) @ (blk_k, blk_n) dot per step. Inputs are padded
host-side to block multiples so BlockSpecs stay static; padding is
sliced off after the call.

The kernel also carries a fused epilogue (bias add and/or tanh) applied
to the float32 accumulator on the last k step, so an MLP layer's
activation never round-trips through HBM between the contraction and
the nonlinearity — the embedder's two-matmul MLP uses this to keep its
hidden layer entirely in VMEM.

Block sizes default to autotuned values (see repro.kernels.autotune)
when not given explicitly via :func:`repro.kernels.ops.matmul`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPILOGUES = ("none", "tanh")


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _apply_epilogue(acc, bias, epilogue: str):
    """Float32 epilogue on the accumulator (shared by kernel and oracle)."""
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if epilogue == "tanh":
        acc = jnp.tanh(acc)
    return acc


def _kernel(*refs, n_k_blocks: int, epilogue: str, has_bias: bool):
    if has_bias:
        a_ref, b_ref, bias_ref, o_ref, acc_ref = refs
    else:
        a_ref, b_ref, o_ref, acc_ref = refs
        bias_ref = None
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        out = _apply_epilogue(
            acc_ref[...], bias_ref[...] if has_bias else None, epilogue)
        o_ref[...] = out.astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *, bias: jax.Array | None = None,
           epilogue: str = "none", blk_m: int = 128,
           blk_n: int = 128, blk_k: int = 512,
           interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N); accumulation in float32.

    ``bias`` is an (N,) vector added to the accumulator; ``epilogue``
    in ``EPILOGUES`` optionally applies tanh — both fused into the last
    k step, on-chip.
    """
    assert epilogue in EPILOGUES, epilogue
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    # clamp blocks to the (sublane, lane)-aligned problem size
    blk_m = min(blk_m, _round_up(M, 8))
    blk_n = min(blk_n, _round_up(N, 128))
    blk_k = min(blk_k, _round_up(K, 128))
    Mp, Kp, Np = (_round_up(M, blk_m), _round_up(K, blk_k),
                  _round_up(N, blk_n))
    if (Mp, Kp) != (M, K):
        a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    n_k = Kp // blk_k

    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((blk_m, blk_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((blk_k, blk_n), lambda i, j, k: (k, j)),
    ]
    operands = [a, b]
    if has_bias:
        assert bias.shape == (N,), (bias.shape, N)
        operands.append(jnp.pad(bias, (0, Np - N))[None, :])
        in_specs.append(pl.BlockSpec((1, blk_n), lambda i, j, k: (0, j)))

    out = pl.pallas_call(
        functools.partial(_kernel, n_k_blocks=n_k, epilogue=epilogue,
                          has_bias=has_bias),
        grid=(Mp // blk_m, Np // blk_n, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        scratch_shapes=[pltpu.VMEM((blk_m, blk_n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:M, :N]
