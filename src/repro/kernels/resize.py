"""Bilinear resize Pallas TPU kernel — the pre-processing hot-spot.

The paper measures frame/face resizing at 17.8% of Face Recognition's
end-to-end compute cycles and calls out image pre-processing as an
acceleration target [its ref 62]; on a TPU-resident pipeline the resize
belongs on-device so decoded frames stream HBM->VMEM once.

TPU adaptation: separable bilinear as two dense matmuls — out = Ry @ img
@ Rx^T, with Ry (out_h, in_h) and Rx (out_w, in_w) banded interpolation
matrices built host-side. Gather-style per-pixel addressing is hostile to
the VPU (strided lane access), while the MXU eats 128x128 matmuls; at
typical frame sizes the 2x|rows| nonzeros make the matmul form both
simpler and faster than emulated gathers. The kernel tiles (channel-major)
images over a (batch*channel, out-rows) grid.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interp_matrix(out_n: int, in_n: int) -> np.ndarray:
    """Rows are bilinear weights (align_corners=False)."""
    c = (np.arange(out_n) + 0.5) * (in_n / out_n) - 0.5
    c = np.clip(c, 0.0, in_n - 1.0)
    lo = np.floor(c).astype(np.int32)
    hi = np.minimum(lo + 1, in_n - 1)
    frac = (c - lo).astype(np.float32)
    m = np.zeros((out_n, in_n), np.float32)
    m[np.arange(out_n), lo] += 1.0 - frac
    m[np.arange(out_n), hi] += frac
    return m


def _kernel(img_ref, ry_ref, rx_ref, o_ref):
    img = img_ref[0].astype(jnp.float32)          # (H, W)
    ry = ry_ref[...]                               # (blk_oh, H)
    rx = rx_ref[...]                               # (out_w, W)
    tmp = jax.lax.dot(ry, img)                     # (blk_oh, W)
    o_ref[0] = jax.lax.dot(
        tmp, rx.T).astype(o_ref.dtype)             # (blk_oh, out_w)


def resize_bilinear(img: jax.Array, out_h: int, out_w: int, *,
                    blk_oh: int = 128, interpret: bool = False) -> jax.Array:
    """img: (..., H, W, C) -> (..., out_h, out_w, C)."""
    *lead, H, W, C = img.shape
    x = img.reshape((-1, H, W, C)).transpose(0, 3, 1, 2)   # (N*C planes)
    NB = x.shape[0] * C
    x = x.reshape(NB, H, W)
    ry = jnp.asarray(_interp_matrix(out_h, H))
    rx = jnp.asarray(_interp_matrix(out_w, W))
    blk = min(blk_oh, out_h)
    pad = (-out_h) % blk
    if pad:
        ry = jnp.pad(ry, ((0, pad), (0, 0)))
    n_blocks = (out_h + pad) // blk

    out = pl.pallas_call(
        _kernel,
        grid=(NB, n_blocks),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((blk, H), lambda n, i: (i, 0)),
            pl.BlockSpec((out_w, W), lambda n, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, out_w), lambda n, i: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((NB, out_h + pad, out_w), img.dtype),
        interpret=interpret,
    )(x, ry, rx)
    out = out[:, :out_h]
    out = out.reshape(-1, C, out_h, out_w).transpose(0, 2, 3, 1)
    return out.reshape((*lead, out_h, out_w, C))
