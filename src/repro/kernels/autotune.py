"""Pallas kernel tiling autotuner with a persistent JSON cache.

The Pallas kernels in this package (``matmul``, ``resize_bilinear``,
``flash_attention``, ``decode_attention``) used to hard-code their
block sizes; the right
tiling depends on the problem shape (padding waste, operand re-reads
per block revisit, MXU utilization, VMEM fit), so hard-coded defaults
leave performance on the table exactly where the AI-tax paper says the
glue does. This module sweeps a candidate space per (op, shape, dtype)
and memoizes the winner in a JSON cache, so dispatch in
:mod:`repro.kernels.ops` can resolve ``blk_* = None`` to tuned values.

Two scoring modes:

* ``analytic`` — a deterministic roofline-style model (compute time at
  the MXU-utilization-discounted peak, HBM traffic including block
  revisits and padding waste, a small per-grid-step overhead), the only
  meaningful mode on this CPU container and the one CI uses (seedable:
  same shapes -> same blocks, no timing noise);
* ``measured`` — times the real kernel (``interpret=True`` off-TPU),
  for refreshing the cache on actual hardware.

Cache layout: two layers. The committed seed
(``src/repro/kernels/tilings.json``, refreshed by ``make autotune``)
ships tuned defaults for the repo's hot-path shapes; a user-writable
overlay (``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune.json``)
absorbs shapes tuned at runtime so a repo checkout never dirties
itself. ``scripts/autotune.py --check`` asserts the committed seed is
in sync with what the analytic sweep produces.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from dataclasses import dataclass

from repro.roofline import hw

# Committed seed cache: tuned defaults for the repo's hot-path shapes.
SEED_PATH = pathlib.Path(__file__).resolve().parent / "tilings.json"

# Bump when a kernel's block constraints or a candidate space change
# incompatibly: entries stamped with an older version are ignored at
# load, so a stale user overlay can never shadow a refreshed seed with
# blocks the current kernels would reject.
SCHEMA_VERSION = 1

# Per-grid-step overhead (block switch / pipeline bubble), in seconds.
# Coarse, but it is what makes "few big blocks" beat "many tiny blocks"
# once both fit in VMEM and stream the same bytes.
_GRID_STEP_S = 0.3e-6
# Don't plan more than half of VMEM: double-buffering needs the rest.
_VMEM_BUDGET = hw.VMEM_BYTES // 2

_F32 = 4  # itemsize used for VMEM/HBM planning (accumulators are f32)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _pow2s(lo: int, hi: int) -> list[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def _itemsize(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
            "uint8": 1}.get(str(dtype), 4)


def _mxu_eff(blk: int) -> float:
    """Utilization of the 128-wide MXU dimension for a block edge."""
    return min(blk, hw.MXU_DIM) / hw.MXU_DIM


@dataclass
class TuneResult:
    blocks: dict[str, int]
    score_us: float
    mode: str
    n_candidates: int

    def to_json(self) -> dict:
        return {"blocks": self.blocks, "score_us": round(self.score_us, 3),
                "mode": self.mode, "n_candidates": self.n_candidates,
                "v": SCHEMA_VERSION}


# --------------------------------------------------------------------------
# Cache
# --------------------------------------------------------------------------

class AutotuneCache:
    """Seed (committed, read-only) + overlay (user-writable) JSON cache."""

    def __init__(self, path: str | os.PathLike | None = None,
                 seed_path: str | os.PathLike | None = SEED_PATH):
        env = os.environ.get("REPRO_AUTOTUNE_CACHE")
        self.path = pathlib.Path(
            path if path is not None else
            env if env else
            pathlib.Path.home() / ".cache" / "repro" / "autotune.json")
        self.seed_path = pathlib.Path(seed_path) if seed_path else None
        self._entries: dict[str, dict] | None = None

    def _load(self) -> dict[str, dict]:
        if self._entries is None:
            # lazy load may race a concurrent first lookup: both
            # threads parse the same immutable file and install
            # equivalent dicts — idempotent, worst case a wasted parse
            self._entries = {}  # lint: waive race-check -- idempotent lazy load; duplicate parse of the same file is the worst case
            for p in (self.seed_path, self.path):
                if p is not None and p.is_file():
                    try:
                        raw = json.loads(p.read_text())
                    except (json.JSONDecodeError, OSError):
                        continue  # corrupt cache == empty cache
                    self._entries.update(
                        {k: v for k, v in raw.items()
                         if isinstance(v, dict)
                         and v.get("v") == SCHEMA_VERSION})
        return self._entries

    def lookup(self, key: str) -> dict | None:
        return self._load().get(key)

    def store(self, key: str, entry: dict) -> None:
        """Memoize + persist to the overlay (never the committed seed)."""
        self._load()[key] = entry
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            on_disk = {}
            if self.path.is_file():
                try:
                    on_disk = json.loads(self.path.read_text())
                except json.JSONDecodeError:
                    pass
            on_disk[key] = entry
            fd, tmp = tempfile.mkstemp(dir=self.path.parent, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(on_disk, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass  # read-only filesystem: in-process memo still works


_CACHE: AutotuneCache | None = None


def get_cache() -> AutotuneCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = AutotuneCache()
    return _CACHE


def set_cache(cache: AutotuneCache | None) -> None:
    """Swap the process-wide cache (tests point it at a tmp path)."""
    global _CACHE
    _CACHE = cache


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

def _matmul_clamp(M: int, K: int, N: int, bm: int, bn: int,
                  bk: int) -> tuple[int, int, int]:
    """Mirror the kernel's own block clamping (kernels/matmul.py)."""
    return (min(bm, _round_up(M, hw.SUBLANE)), min(bn, _round_up(N, hw.LANE)),
            min(bk, _round_up(K, hw.LANE)))


def matmul_candidates(M: int, K: int, N: int) -> list[dict[str, int]]:
    seen, out = set(), []
    for bm in _pow2s(64, 512):
        for bn in _pow2s(128, 512):
            for bk in _pow2s(128, 2048):
                cbm, cbn, cbk = _matmul_clamp(M, K, N, bm, bn, bk)
                # VMEM plan: double-buffered input blocks + f32 acc + out
                vmem = 2 * (cbm * cbk + cbk * cbn) * _F32 \
                    + cbm * cbn * 2 * _F32
                if vmem > _VMEM_BUDGET:
                    continue
                if (cbm, cbn, cbk) in seen:
                    continue
                seen.add((cbm, cbn, cbk))
                out.append({"blk_m": cbm, "blk_n": cbn, "blk_k": cbk})
    return out


def matmul_cost_us(M: int, K: int, N: int, dtype: str, blk_m: int,
                   blk_n: int, blk_k: int) -> float:
    """Analytic cost of one tiled matmul at this tiling, in µs.

    HBM traffic counts the block revisits the (m, n, k) grid implies:
    every n-block re-reads all of A, every m-block re-reads all of B;
    padding waste is included because the padded dims depend on the
    blocks. Compute is discounted by MXU-edge utilization (blocks
    thinner than 128 waste systolic columns/rows); f32 inputs run the
    MXU at half its bf16 rate.
    """
    it = _itemsize(dtype)
    bm, bn, bk = _matmul_clamp(M, K, N, blk_m, blk_n, blk_k)
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    steps = (Mp // bm) * (Np // bn) * (Kp // bk)
    byts = it * (Mp * Kp * (Np // bn) + Kp * Np * (Mp // bm)) \
        + it * Mp * Np
    peak = hw.PEAK_FLOPS_BF16 * (0.5 if it >= 4 else 1.0) \
        * _mxu_eff(bm) * _mxu_eff(bn)
    t = max(2.0 * Mp * Np * Kp / peak, byts / hw.HBM_BW) \
        + steps * _GRID_STEP_S
    return t * 1e6


def _measure_matmul(M, K, N, dtype, blocks) -> float:
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import matmul as mm
    interpret = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (M, K), jnp.dtype(dtype))
    b = jax.random.normal(key, (K, N), jnp.dtype(dtype))
    f = jax.jit(lambda a, b: mm.matmul(a, b, interpret=interpret, **blocks))
    f(a, b).block_until_ready()
    repeat = 3
    t0 = time.perf_counter()
    for _ in range(repeat):
        f(a, b).block_until_ready()
    return (time.perf_counter() - t0) / repeat * 1e6


def _bucket_m(M: int) -> int:
    """Leading (batch-like) dim bucketed to its power-of-two, matching
    the facerec pipeline's batch padding, so ragged batches share keys."""
    return 1 << (max(1, M) - 1).bit_length()


def matmul_key(M: int, K: int, N: int, dtype: str) -> str:
    return f"matmul/m{_bucket_m(M)}k{K}n{N}/{dtype}"


def matmul_tiling(M: int, K: int, N: int, dtype: str = "float32", *,
                  cache: AutotuneCache | None = None,
                  mode: str = "analytic") -> dict[str, int]:
    """Best (blk_m, blk_n, blk_k) for this shape; tunes on cache miss."""
    cache = cache or get_cache()
    key = matmul_key(M, K, N, dtype)
    hit = cache.lookup(key)
    if hit is not None:
        return dict(hit["blocks"])
    Mb = _bucket_m(M)
    cands = matmul_candidates(Mb, K, N)
    if mode == "measured":
        scored = [(_measure_matmul(Mb, K, N, dtype, c), c) for c in cands]
    else:
        scored = [(matmul_cost_us(Mb, K, N, dtype, **c), c) for c in cands]
    best_us, best = min(scored, key=lambda sc: (sc[0], sorted(sc[1].items())))
    cache.store(key, TuneResult(best, best_us, mode, len(cands)).to_json())
    return dict(best)


# --------------------------------------------------------------------------
# resize
# --------------------------------------------------------------------------

def resize_key(H: int, W: int, out_h: int, out_w: int, dtype: str) -> str:
    return f"resize/h{H}w{W}oh{out_h}ow{out_w}/{dtype}"


def resize_candidates(H: int, W: int, out_h: int, out_w: int) -> list[int]:
    out = []
    for blk in _pow2s(8, 512):
        blk = min(blk, out_h)
        # per-step VMEM: input plane + Ry block + Rx + out block (f32)
        vmem = (H * W + blk * H + out_w * W + blk * out_w) * _F32 * 2
        if vmem > _VMEM_BUDGET:
            continue
        if blk not in out:
            out.append(blk)
    return out or [min(8, out_h)]


def resize_cost_us(H: int, W: int, out_h: int, out_w: int, dtype: str,
                   blk_oh: int) -> float:
    """Per-plane cost: the input plane streams once per row-block, so
    small blocks multiply the dominant H*W read."""
    it = _itemsize(dtype)
    blk = min(blk_oh, out_h)
    ohp = _round_up(out_h, blk)
    n_blocks = ohp // blk
    byts = n_blocks * H * W * it + ohp * H * _F32 \
        + n_blocks * out_w * W * _F32 + ohp * out_w * it
    flops = 2.0 * ohp * H * W + 2.0 * ohp * W * out_w
    t = max(flops / (hw.PEAK_FLOPS_BF16 * 0.5), byts / hw.HBM_BW) \
        + n_blocks * _GRID_STEP_S
    return t * 1e6


def resize_tiling(H: int, W: int, out_h: int, out_w: int,
                  dtype: str = "float32", *,
                  cache: AutotuneCache | None = None,
                  mode: str = "analytic") -> dict[str, int]:
    cache = cache or get_cache()
    key = resize_key(H, W, out_h, out_w, dtype)
    hit = cache.lookup(key)
    if hit is not None:
        return dict(hit["blocks"])
    cands = resize_candidates(H, W, out_h, out_w)
    scored = [(resize_cost_us(H, W, out_h, out_w, dtype, c), c)
              for c in cands]
    best_us, best_blk = min(scored)
    best = {"blk_oh": best_blk}
    cache.store(key, TuneResult(best, best_us, "analytic",
                                len(cands)).to_json())
    return dict(best)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def attention_key(Sq: int, Skv: int, D: int, dtype: str) -> str:
    return f"attention/sq{Sq}skv{Skv}d{D}/{dtype}"


def attention_candidates(Sq: int, Skv: int, D: int,
                         dtype: str) -> list[dict[str, int]]:
    """(blk_q, blk_k) pairs; the kernel requires exact divisibility."""
    it = _itemsize(dtype)
    out = []
    for bq in _pow2s(64, 512):
        bq = min(bq, Sq)
        if Sq % bq:
            continue
        for bk in _pow2s(64, 512):
            bk = min(bk, Skv)
            if Skv % bk:
                continue
            vmem = (bq * D * it + 2 * bk * D * it + bq * bk * _F32
                    + bq * D * _F32) * 2
            if vmem > _VMEM_BUDGET:
                continue
            if {"blk_q": bq, "blk_k": bk} not in out:
                out.append({"blk_q": bq, "blk_k": bk})
    return out


def attention_cost_us(Sq: int, Skv: int, D: int, dtype: str, blk_q: int,
                      blk_k: int) -> float:
    """Per (batch, head) cost: K/V stream once per q-block revisit."""
    it = _itemsize(dtype)
    n_q, n_k = Sq // blk_q, Skv // blk_k
    byts = Sq * D * it + n_q * 2 * Skv * D * it + Sq * D * it
    flops = 4.0 * Sq * Skv * D
    peak = hw.PEAK_FLOPS_BF16 * (0.5 if it >= 4 else 1.0) \
        * _mxu_eff(blk_q) * _mxu_eff(blk_k)
    t = max(flops / peak, byts / hw.HBM_BW) + n_q * n_k * _GRID_STEP_S
    return t * 1e6


def attention_tiling(Sq: int, Skv: int, D: int, dtype: str = "float32", *,
                     cache: AutotuneCache | None = None,
                     mode: str = "analytic") -> dict[str, int] | None:
    """Best (blk_q, blk_k), or None when nothing divides the sequence
    (the caller falls back to the kernel's own clamped defaults)."""
    cache = cache or get_cache()
    key = attention_key(Sq, Skv, D, dtype)
    hit = cache.lookup(key)
    if hit is not None:
        return dict(hit["blocks"])
    cands = attention_candidates(Sq, Skv, D, dtype)
    if not cands:
        return None
    scored = [(attention_cost_us(Sq, Skv, D, dtype, **c), c) for c in cands]
    best_us, best = min(scored, key=lambda sc: (sc[0], sorted(sc[1].items())))
    cache.store(key, TuneResult(best, best_us, "analytic",
                                len(cands)).to_json())
    return dict(best)


# --------------------------------------------------------------------------
# decode attention (one token vs a KV cache — the serving fast path)
# --------------------------------------------------------------------------

def decode_key(L: int, D: int, dtype: str) -> str:
    return f"decode/l{L}d{D}/{dtype}"


def decode_candidates(L: int, D: int) -> list[dict[str, int]]:
    """Legal ``blk_k`` tiles for a cache of length ``L``.

    Candidates are the kernel-legalized forms of the pow2 sweep (the
    kernel requires ``L % blk_k == 0``; ``legal_blk_k`` rounds each
    request down to the largest divisor-aligned tile), deduplicated —
    so every candidate traces, whatever the cache length.
    """
    from repro.kernels.decode_attention import legal_blk_k
    out = []
    for bk in _pow2s(128, 2048):
        c = legal_blk_k(bk, L)
        # per-step VMEM: double-buffered K+V tiles + f32 softmax state
        vmem = 2 * 2 * c * D * _F32 + 2 * c * _F32
        if vmem > _VMEM_BUDGET:
            continue
        if {"blk_k": c} not in out:
            out.append({"blk_k": c})
    return out or [{"blk_k": legal_blk_k(128, L)}]


def decode_cost_us(L: int, D: int, dtype: str, blk_k: int) -> float:
    """Per (batch, kv-head) cost of one ragged decode step.

    Decode is bandwidth-bound: K and V stream once (2·L·D bytes); the
    grid-step overhead is what separates tilings, so fewer, wider
    blocks win until the tile stops filling the MXU edge or VMEM.
    """
    from repro.kernels.decode_attention import legal_blk_k
    it = _itemsize(dtype)
    c = legal_blk_k(blk_k, L)
    n_blocks = L // c
    byts = 2 * L * D * it + 2 * D * _F32            # K+V stream, q/o resident
    flops = 4.0 * L * D                             # qk^T + pv per group row
    peak = hw.PEAK_FLOPS_BF16 * (0.5 if it >= 4 else 1.0) * _mxu_eff(c)
    t = max(flops / peak, byts / hw.HBM_BW) + n_blocks * _GRID_STEP_S
    return t * 1e6


def decode_tiling(L: int, D: int, dtype: str = "float32", *,
                  cache: AutotuneCache | None = None,
                  mode: str = "analytic") -> dict[str, int]:
    """Best ``blk_k`` for a (cache_len, head_dim) decode; tunes on miss."""
    cache = cache or get_cache()
    key = decode_key(L, D, dtype)
    hit = cache.lookup(key)
    if hit is not None:
        return dict(hit["blocks"])
    cands = decode_candidates(L, D)
    scored = [(decode_cost_us(L, D, dtype, **c), c) for c in cands]
    best_us, best = min(scored, key=lambda sc: (sc[0], sorted(sc[1].items())))
    cache.store(key, TuneResult(best, best_us, "analytic",
                                len(cands)).to_json())
    return dict(best)


# --------------------------------------------------------------------------
# Battery: the repo's hot-path shapes (refreshed by `make autotune`)
# --------------------------------------------------------------------------

def hot_path_battery() -> dict[str, dict]:
    """Tune the shapes the pipeline/serving hot paths actually hit.

    Returns key -> entry for the committed seed cache. Deterministic
    (analytic mode), so `scripts/autotune.py --check` can diff it
    against the committed file byte-for-byte.
    """
    from repro.core import facerec

    d_thumb = facerec.THUMB * facerec.THUMB * 3       # embedder layer 1
    d_crop = facerec.CROP_SIZE ** 2 * 3               # fused resize-fold
    # (K, N) contractions on the identify hot loop; M is the pow2 batch
    # bucket, swept over the sizes timeout-flushed batches actually
    # produce (small) up to steady-state batching (large)
    layers = [
        (d_thumb, 256),             # Embedder layer 1 (batched thumbs)
        (256, facerec.EMBED_DIM),   # Embedder layer 2
        (d_crop, 256),              # FusedIdentifier folded layer 1
    ]
    shapes_mm = [(m, k, n) for k, n in layers for m in (1, 8, 64, 512)]
    shapes_rz = [
        (216, 384, 108, 192),       # ingest downscale (VideoStream res)
        (1080, 1920, 540, 960),     # paper's full-HD ingest
        (48, 48, 32, 32),           # crop -> THUMB normalization
    ]
    shapes_at = [
        (2048, 2048, 128),          # prefill block
        (1024, 1024, 64),
    ]
    shapes_dec = [
        (1024, 64),                 # serving-engine decode cache
        (2048, 128),                # production decode cache
        (768, 64),                  # non-pow2 cache (legalized tiling)
        (4096, 128),                # long-context decode
    ]
    with tempfile.TemporaryDirectory() as tmp:
        scratch = AutotuneCache(path=pathlib.Path(tmp) / "battery.json",
                                seed_path=None)
        for M, K, N in shapes_mm:
            matmul_tiling(M, K, N, "float32", cache=scratch)
            matmul_tiling(M, K, N, "bfloat16", cache=scratch)
        for H, W, oh, ow in shapes_rz:
            resize_tiling(H, W, oh, ow, "float32", cache=scratch)
        for Sq, Skv, D in shapes_at:
            attention_tiling(Sq, Skv, D, "bfloat16", cache=scratch)
        for L, D in shapes_dec:
            decode_tiling(L, D, "float32", cache=scratch)
            decode_tiling(L, D, "bfloat16", cache=scratch)
        return dict(scratch._load())
