"""Flash attention Pallas TPU kernel (prefill/train path).

Online-softmax tiling adapted for the TPU memory hierarchy: q/k/v tiles
are staged HBM->VMEM by BlockSpecs; the running (m, l, acc) state lives in
VMEM scratch across the kv grid dimension; scores never touch HBM. Block
shapes default to (128, 128) — MXU-aligned (128x128 systolic array) and
lane-aligned (last dim multiples of 128).

Grid: (batch, heads, q_blocks, kv_blocks), kv innermost so the scratch
accumulator carries across kv steps for a fixed q block. GQA is handled
by indexing the kv head as h // group in the BlockSpec index maps — no
KV expansion in memory (unlike the XLA fallback path).

Causal/windowed blocks that are fully masked are skipped via
``pl.when`` on the block indices (no MXU work, no VMEM traffic for the
skipped tiles' compute).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            q_offset: int, blk_q: int, blk_k: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0) \
        + q_offset
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)

    # skip blocks that are entirely masked
    first_q = qi * blk_q + q_offset
    last_q = first_q + blk_q - 1
    first_k = ki * blk_k
    run = jnp.asarray(True)
    if causal:
        run &= first_k <= last_q
    if window is not None:
        run &= (ki + 1) * blk_k - 1 > first_q - window

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (blk_q, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (blk_k, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (blk_k, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        mask = jnp.ones((blk_q, blk_k), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, KV, D)
    v: jax.Array,            # (B, Skv, KV, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Skv, KV, Dv = v.shape
    assert H % KV == 0
    group = H // KV
    scale = (1.0 / D**0.5) if scale is None else scale
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    assert Sq % blk_q == 0 and Skv % blk_k == 0, (Sq, blk_q, Skv, blk_k)
    n_q, n_k = Sq // blk_q, Skv // blk_k

    # (B, H, S, D) layout: block over batch/head/sequence
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, n_q, n_k)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, blk_q=blk_q, blk_k=blk_k, n_kv_blocks=n_k)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, blk_k, Dv),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),     # running max m
            pltpu.VMEM((blk_q, 1), jnp.float32),     # running denom l
            pltpu.VMEM((blk_q, Dv), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
