"""Async sharded checkpointing with elastic restore (no orbax).

Design (what a 1000-node deployment needs):
  * each host writes ONLY its addressable shards (`.npy` per leaf-shard),
    plus a JSON manifest with the tree structure, global shapes, dtypes
    and step metadata;
  * writes happen on a background thread off the training loop — the train
    step donates buffers, so we snapshot to host RAM first (device_get)
    and overlap serialization with subsequent steps;
  * atomicity via write-to-tmp + rename; the manifest is written last, so
    a partially-written checkpoint is never visible;
  * ELASTIC restore: the manifest stores global arrays; `restore` takes
    the *current* shardings and lays shards out for whatever mesh shape
    the job restarted with (scale up/down = different device counts);
  * retention: keep the last N checkpoints (crash-looping protection).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(k) for k in path), leaf) for path, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---- save --------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot to host then serialize asynchronously."""
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        flat, _ = _flatten(host_tree)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for name, arr in flat:
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---- restore -----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional matching tree of NamedShardings for the
        CURRENT mesh — this is the elastic-rescale path: the checkpoint
        stores global arrays, and jax.device_put lays out whatever shard
        each device owns under the new mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flatten(tree_like)
        sflat = None
        if shardings is not None:
            sflat = [s for _, s in _flatten(shardings)[0]]
        leaves = []
        for i, (name, like) in enumerate(flat):
            info = manifest["leaves"].get(name)
            if info is None:
                raise KeyError(f"leaf {name!r} missing from checkpoint")
            arr = np.load(os.path.join(d, info["file"]))
            want_dtype = getattr(like, "dtype", arr.dtype)
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {like.shape}")
            arr = arr.astype(want_dtype)
            if sflat is not None:
                leaves.append(jax.device_put(arr, sflat[i]))
            else:
                leaves.append(jnp.asarray(arr))
        return treedef.unflatten(leaves), manifest["step"]
