"""Train-step factory: loss + grads + AdamW under explicit shardings.

``make_train_fns`` returns (train_step, shardings) where shardings carry
NamedShardings for params/opt/batch so callers can jit with explicit
in/out shardings (and the dry-run can ``.lower().compile()`` against
ShapeDtypeStructs without allocating anything).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.distributed import sharding as shd
from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


@dataclass(frozen=True)
class TrainShardings:
    params: Any
    opt: Any
    batch: Any
    mesh: Mesh
    rules: shd.Rules


def batch_shardings(model: Model, specs: dict, mesh: Mesh, rules: shd.Rules):
    def one(name, s):
        if name == "frames":
            return NamedSharding(mesh, shd.spec_for(
                ("batch", None, None), s.shape, mesh, rules))
        return NamedSharding(mesh, shd.spec_for(
            ("batch",) + (None,) * (len(s.shape) - 1), s.shape, mesh, rules))
    return {k: one(k, v) for k, v in specs.items()}


def make_train_shardings(model: Model, mesh: Mesh,
                         rules: shd.Rules | None = None,
                         batch_specs: dict | None = None) -> TrainShardings:
    rules = rules or shd.TRAIN_RULES
    axes = model.param_axes()
    aparams = model.abstract_params()
    psh = shd.tree_shardings(axes, aparams, mesh, rules)
    osh = OptState(m=psh, v=psh, count=NamedSharding(mesh, PS()))
    bsh = (batch_shardings(model, batch_specs, mesh, rules)
           if batch_specs else None)
    return TrainShardings(psh, osh, bsh, mesh, rules)


def make_train_step(model: Model, hp: AdamWConfig, sh: TrainShardings,
                    *, grad_accum: int = 1):
    """Returns train_step(params, opt, batch) -> (params, opt, metrics).

    ``grad_accum > 1`` splits the global batch into microbatches and
    accumulates gradients through a scan — the standard lever for fitting
    activation memory at large global batch (each microbatch's activations
    are freed before the next), at the cost of serializing compute."""

    def grads_of(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(model.loss)(params, batch)

        def micro(i, b):
            return jax.tree.map(
                lambda t: t.reshape(grad_accum, -1, *t.shape[1:])[i], b)

        def body(carry, i):
            acc_loss, acc_g = carry
            loss, g = jax.value_and_grad(model.loss)(params, micro(i, batch))
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, g), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                    jnp.arange(grad_accum))
        scale = 1.0 / grad_accum
        return loss * scale, jax.tree.map(lambda t: t * scale, g)

    def train_step(params, opt, batch):
        with shd.use_sharding(sh.mesh, sh.rules):
            loss, grads = grads_of(params, batch)
            params, opt, gnorm = adamw_update(grads, opt, params, hp)
        return params, opt, {"loss": loss, "grad_norm": gnorm,
                             "step": opt.count}

    return train_step


def jit_train_step(model: Model, hp: AdamWConfig, sh: TrainShardings):
    step = make_train_step(model, hp, sh)
    return jax.jit(
        step,
        in_shardings=(sh.params, sh.opt, sh.batch),
        out_shardings=(sh.params, sh.opt,
                       NamedSharding(sh.mesh, PS())),
        donate_argnums=(0, 1),
    )
