"""Fault-tolerant training loop.

Large-scale runnability features (designed for 1000+ nodes, exercised at
container scale by the tests):
  * checkpoint/restart: periodic async checkpoints; on start, auto-resume
    from the latest manifest (elastic: onto a different mesh if needed);
  * straggler/hang mitigation: a watchdog thread monitors step heartbeats
    and raises/records when a step exceeds ``hang_timeout`` (on a real
    cluster this triggers the coordinator's restart path — here it feeds
    the fault-injection tests);
  * data-pipeline replay: the loader is seekable by step so restarts
    resume mid-epoch deterministically;
  * metric history for loss-spike detection (skip-update guard).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.train.checkpoint import Checkpointer
from repro.train.optimizer import AdamWConfig, init_opt_state


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    hang_timeout: float = 300.0
    spike_factor: float = 8.0        # skip update if loss > spike * median
    log_every: int = 10


class Watchdog:
    """Heartbeat monitor: detects hung/straggling steps."""

    def __init__(self, timeout: float):
        self.timeout = timeout
        self.last_beat = time.monotonic()
        self.hangs: list[float] = []
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self.last_beat = time.monotonic()

    def _run(self):
        while not self._stop.wait(min(self.timeout / 4, 5.0)):
            gap = time.monotonic() - self.last_beat
            if gap > self.timeout:
                self.hangs.append(gap)
                # beat() stores a fresh monotonic stamp from the
                # trainer thread; a torn read is impossible for a
                # float slot and a stale one just delays detection by
                # one poll interval
                self.last_beat = time.monotonic()  # lint: waive race-check -- heartbeat timestamp; atomic slot swap, staleness only delays the next hang report

    def stop(self):
        self._stop.set()


class Trainer:
    def __init__(self, model, train_step, loader, tc: TrainerConfig,
                 shardings=None, init_params_fn=None):
        self.model = model
        self.train_step = train_step
        self.loader = loader
        self.tc = tc
        self.shardings = shardings
        self.init_params_fn = init_params_fn or (
            lambda: model.init(jax.random.PRNGKey(0)))
        self.ckpt = Checkpointer(tc.ckpt_dir, keep=tc.keep)
        self.history: list[dict] = []

    def restore_or_init(self):
        """Returns (params, opt_state, start_step)."""
        params = self.init_params_fn()
        opt = init_opt_state(params)
        latest = self.ckpt.latest_step()
        if latest is None:
            return params, opt, 0
        sh = None
        if self.shardings is not None:
            sh = {"params": self.shardings.params, "opt": self.shardings.opt}
        (state), step = self.ckpt.restore(
            {"params": params, "opt": opt},
            shardings=sh)
        return state["params"], state["opt"], step

    def run(self):
        params, opt, start = self.restore_or_init()
        self.loader.seek(start)
        dog = Watchdog(self.tc.hang_timeout).start()
        losses: list[float] = []
        try:
            for step in range(start, self.tc.steps):
                batch = self.loader.next_batch()
                t0 = time.perf_counter()
                new_params, new_opt, metrics = self.train_step(
                    params, opt, batch)
                loss = float(metrics["loss"])
                dog.beat()
                # loss-spike guard: drop the update, keep old state
                med = float(np.median(losses[-32:])) if losses else loss
                if np.isfinite(loss) and loss <= self.tc.spike_factor * max(med, 1e-9):
                    params, opt = new_params, new_opt
                    losses.append(loss)
                    skipped = False
                else:
                    skipped = True
                rec = {"step": step + 1, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "dt": time.perf_counter() - t0, "skipped": skipped}
                self.history.append(rec)
                if (step + 1) % self.tc.log_every == 0:
                    print(f"step {rec['step']:6d} loss {rec['loss']:.4f} "
                          f"gnorm {rec['grad_norm']:.3f} dt {rec['dt']*1e3:.0f}ms"
                          + (" [skipped]" if skipped else ""))
                if (step + 1) % self.tc.ckpt_every == 0:
                    self.ckpt.save(step + 1, {"params": params, "opt": opt})
            self.ckpt.save(self.tc.steps, {"params": params, "opt": opt},
                           blocking=True)
        finally:
            dog.stop()
            self.ckpt.wait()
        return params, opt, self.history
