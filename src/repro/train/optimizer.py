"""AdamW with global-norm clipping and schedules — pure JAX, no optax.

Optimizer state (m, v) mirrors the parameter tree, so the same sharding
specs apply leaf-for-leaf (ZeRO-style sharded states fall out of the
2-D parameter sharding for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init_opt_state(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(m=z, v=jax.tree.map(jnp.copy, z),
                    count=jnp.zeros((), jnp.int32))


def schedule(hp: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(hp.warmup_steps, 1)
    prog = jnp.clip((step - hp.warmup_steps)
                    / max(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = hp.min_lr_ratio + (1 - hp.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * jnp.where(step < hp.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(grads, opt: OptState, params, hp: AdamWConfig):
    """Returns (new_params, new_opt, grad_norm)."""
    count = opt.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.clip_norm / (gnorm + 1e-9))
    lr = schedule(hp, count)
    b1c = 1 - hp.b1 ** count.astype(jnp.float32)
    b2c = 1 - hp.b2 ** count.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + hp.eps)
        if p.ndim >= 2:          # decoupled weight decay on matrices only
            upd = upd + hp.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt.m)
    flat_v = treedef.flatten_up_to(opt.v)
    out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), gnorm
