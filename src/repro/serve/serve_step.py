"""Serving-step factories: prefill and single-token decode under shardings.

Decode is the latency-critical path the paper's AI-tax analysis targets:
the KV cache is donated (updated in place) and sequence-sharded under the
serve rules so cache softmax lowers to distributed-LSE partial reductions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.distributed import sharding as shd
from repro.models.model import Model


@dataclass(frozen=True)
class ServeShardings:
    params: Any
    cache: Any
    mesh: Mesh
    rules: shd.Rules


def make_serve_shardings(model: Model, mesh: Mesh, batch: int, cache_len: int,
                         rules: shd.Rules | None = None) -> ServeShardings:
    rules = rules or shd.SERVE_RULES
    psh = shd.tree_shardings(model.param_axes(), model.abstract_params(),
                             mesh, rules)
    cax = model.cache_axes()
    cabs = model.abstract_cache(batch, cache_len)
    csh = shd.tree_shardings(cax, cabs, mesh, rules)
    return ServeShardings(psh, csh, mesh, rules)


def make_prefill(model: Model, sh: ServeShardings, cache_len: int):
    def prefill(params, batch):
        with shd.use_sharding(sh.mesh, sh.rules):
            return model.prefill(params, batch, cache_len=cache_len)
    return prefill


def make_decode_step(model: Model, sh: ServeShardings):
    def decode_step(params, cache, tokens):
        with shd.use_sharding(sh.mesh, sh.rules):
            return model.decode_step(params, cache, tokens)
    return decode_step


def jit_decode_step(model: Model, sh: ServeShardings, batch: int):
    tok_sh = NamedSharding(sh.mesh, shd.spec_for(("batch", None), (batch, 1),
                                                 sh.mesh, sh.rules))
    logit_sh = NamedSharding(sh.mesh, shd.spec_for(
        ("batch", "vocab"), (batch, model.cfg.vocab_size), sh.mesh, sh.rules))
    return jax.jit(
        make_decode_step(model, sh),
        in_shardings=(sh.params, sh.cache, tok_sh),
        out_shardings=(logit_sh, sh.cache),
        donate_argnums=(1,),
    )
