"""Batched serving engine: continuous-batching decode over a KV cache.

Production concerns covered at container scale:
  * request queue with admission to fixed batch slots;
  * continuous batching (``scheduler="continuous"``, the default): ONE
    batched KV cache of shape (slots, cache_len, ...) plus a host-side
    per-slot occupancy vector, ONE jitted ragged decode step per
    scheduler tick over all occupied slots (through
    ``ops.decode_attention``, the Pallas ragged decode kernel's entry
    point), and prefill-on-admit that writes a freed slot's cache rows
    while the other slots keep decoding — requests join and leave the
    running batch at token boundaries, finished slots are masked via
    ``kv_len`` rather than drained;
  * the pre-batching scheduler (``scheduler="slot"``) is kept as the
    measured baseline: one jitted decode call per slot per token, the
    per-token host round-trips the AI-tax paper predicts dominate once
    the AI core is fast (``benchmarks/fig_decode_batching.py`` measures
    the gap);
  * per-request AI-tax events (queue wait, prefill, decode — batched
    decode spans amortized per slot) via the same EventLog as the
    paper's pipeline, with every device->host fetch both counted
    (``d2h_syncs``/``d2h_bytes``) and logged as transfer events so the
    ledger accounts every boundary byte;
  * straggler mitigation hook: slots exceeding ``max_tokens`` are
    evicted, where ``max_tokens`` bounds the total generated tokens
    (prefill's token included — ``max_tokens=1`` emits exactly one
    token and never runs a decode step).

The engine is model-agnostic: any ``repro.models.model.Model`` works
(encoder-decoder caches keep the lock-step scalar layout, so those
models fall back to the slot scheduler). On the container it runs tiny
configs on CPU; the step functions are the same ones the dry-run
lowers for the production mesh.
"""
from __future__ import annotations

import functools
import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.batching import Batcher
from repro.core.events import EventLog
from repro.core.metrics import LatencyStats, SLOReport, TailSLO


# Jitted step functions live at module level with the (frozen, hashable)
# Model as a static argument: every engine over the same model shares one
# compiled executable instead of paying a per-instance retrace — the
# decode-batching benchmark times steady-state dispatch, not compilation.
@functools.partial(jax.jit, static_argnums=0)
def _step_fused(model, params, cache, tokens):
    logits, cache = model.decode_step(params, cache, tokens)
    return jnp.argmax(logits.reshape(-1)).astype(jnp.int32), cache


@functools.partial(jax.jit, static_argnums=0)
def _step_plain(model, params, cache, tokens):
    return model.decode_step(params, cache, tokens)


@functools.partial(jax.jit, static_argnums=0)
def _step_batched_fused(model, params, blocks, packed):
    # packed (2, B) int32: row 0 the feedback tokens, row 1 per-slot
    # kv_len — one h2d upload per tick instead of two
    logits, blocks = model.decode_step_ragged(params, blocks,
                                              packed[0][:, None], packed[1])
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), blocks


@functools.partial(jax.jit, static_argnums=0)
def _step_batched_plain(model, params, blocks, packed):
    return model.decode_step_ragged(params, blocks, packed[0][:, None],
                                    packed[1])


@functools.partial(jax.jit, static_argnums=0)
def _insert_slot(model, blocks, one_blocks, slot):
    return model.insert_prefill(blocks, one_blocks, slot)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_tokens: int = 16          # bound on generated tokens (prefill incl.)
    t_submit: float = 0.0
    t_first: float = 0.0          # first token ready (TTFT = t_first - t_submit)
    tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, *, batch_slots: int = 4,
                 cache_len: int = 128, greedy: bool = True,
                 fast_path: bool = True, max_queue: int | None = None,
                 degrade=None, scheduler: str = "continuous"):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.cache_len = cache_len
        self.log = EventLog()
        if scheduler not in ("continuous", "slot"):
            raise ValueError(f"scheduler must be continuous/slot: {scheduler!r}")
        if model.cfg.encdec and scheduler == "continuous":
            # encoder-decoder caches are lock-step scalar-cur_len trees;
            # the ragged batched layout is decoder-only
            scheduler = "slot"
        self.scheduler = scheduler
        # graceful degradation (duck-typed DegradePolicy, same ladder
        # as the serving cluster): under queue pressure, admitted
        # requests get max_tokens clamped by the current level's
        # service_factor — shorter generations shed work before
        # admission control sheds requests — with the accuracy cost
        # logged as a zero-span "degrade" event per clamped request
        self.degrade = degrade
        self._deg_depth = 0
        self.degrade_timeline: list[tuple[float, int, str]] = []
        # admission bound: submissions beyond max_queue pending requests
        # are rejected at the door (logged as zero-span "reject" events,
        # so ai_tax()/latency_report() see the shed load); None = accept
        # everything and let queue wait absorb the pressure
        self.max_queue = max_queue
        self.rejected = 0
        self._admit_lock = threading.Lock()   # atomic check-then-put
        # admission shares the streaming pipeline's Batcher: submissions
        # land on a topic-like queue and are drained non-blocking into
        # whatever slots are free each scheduler step
        self._pending: queue.Queue = queue.Queue()
        self.admission = Batcher(self._pending, batch_size=batch_slots,
                                 timeout_s=0.0)
        self.active: list[Request | None] = [None] * batch_slots
        self.greedy = greedy
        # ground truth of physical device->host fetches: every blocking
        # read increments these, and the transfer ledger must account
        # the same bytes (tests assert ledger == counters — the
        # unlogged per-token cur_len sync of the pre-batching engine
        # can't silently come back)
        self.d2h_syncs = 0
        self.d2h_bytes = 0
        # continuous-batching state: per-slot occupancy and the token
        # each slot feeds back next tick, BOTH host-resident — reading
        # them never touches the device
        self._kv_len = np.zeros(batch_slots, np.int32)
        self._last_tok = np.zeros(batch_slots, np.int32)
        self._blocks = None          # batched (slots, cache_len, ...) cache
        # fast_path: greedy token selection is fused into the jitted
        # decode program, so one int32 per slot crosses device->host per
        # step; the unfused path fetches the full logit rows and
        # argmaxes on the host (the classic glue-code pattern the paper
        # taxes)
        self.fast_path = fast_path
        self._decode = functools.partial(
            _step_fused if fast_path else _step_plain, model)
        if scheduler == "continuous":
            self._decode_batch = functools.partial(
                _step_batched_fused if fast_path else _step_batched_plain,
                model)
            self._insert = functools.partial(_insert_slot, model)

    def submit(self, req: Request) -> bool:
        """Queue a request; False when admission control sheds it."""
        req.t_submit = time.perf_counter()
        with self._admit_lock:
            if (self.max_queue is not None
                    and self._pending.qsize() >= self.max_queue):
                self.rejected += 1
                reject = True
            else:
                self._pending.put(req)
                reject = False
        if reject:
            self.log.log(req.rid, "reject", req.t_submit, req.t_submit,
                         int(req.prompt.nbytes))
        return not reject

    @property
    def queue_depth(self) -> int:
        return self._pending.qsize()

    # -- degradation ladder -------------------------------------------------
    def _degrade_tick(self) -> None:
        """Re-evaluate the ladder on the per-slot backlog analogue (no
        breakers here, so the open-fraction input is 0)."""
        if self.degrade is None:
            return
        depth = self.degrade.decide(
            self.queue_depth / max(self.slots, 1), 0.0, self._deg_depth)
        if depth != self._deg_depth:
            self._deg_depth = depth
            self.degrade_timeline.append(
                (time.perf_counter(), depth,
                 self.degrade.level(depth).name))

    def _degrade_clamp(self, req: Request) -> None:
        if self.degrade is None or self._deg_depth <= 0:
            return
        lvl = self.degrade.level(self._deg_depth)
        cap = max(1, int(req.max_tokens * lvl.service_factor))
        if cap < req.max_tokens:
            req.max_tokens = cap
            t = time.perf_counter()
            self.log.log(req.rid, "degrade", t, t,
                         accuracy_proxy=lvl.accuracy_proxy, level=lvl.name)

    # -- single-sequence prefill per admit ----------------------------------
    def _prefill_one(self, req: Request):
        t0 = time.perf_counter()
        tokens = jnp.asarray(req.prompt[None, :])
        self.log.log_transfer(req.rid, "h2d", int(tokens.nbytes), "prefill")
        logits, cache = self.model.prefill(self.params, {"tokens": tokens},
                                           cache_len=self.cache_len)
        jax.block_until_ready(logits)
        self.log.log(req.rid, "prefill", t0, time.perf_counter(),
                     int(req.prompt.nbytes))
        if self.fast_path:
            # argmax on device; only the winning index crosses
            idx = jnp.argmax(logits[0]).astype(jnp.int32)
            nxt = int(idx)
            self.d2h_syncs += 1
            self.d2h_bytes += int(idx.nbytes)
            self.log.log_transfer(req.rid, "d2h", int(idx.nbytes), "prefill")
        else:
            row = np.asarray(logits[0])
            self.d2h_syncs += 1
            self.d2h_bytes += int(row.nbytes)
            self.log.log_transfer(req.rid, "d2h", int(row.nbytes), "prefill")
            nxt = int(np.argmax(row))
        req.tokens.append(nxt)
        req.t_first = time.perf_counter()
        return cache, nxt

    def _finished_early(self, req: Request, finished: list) -> bool:
        """Post-prefill finish check — the generated-token bound counts
        the prefill-produced token, so ``max_tokens=1`` (e.g. a degrade
        clamp) finishes here and never runs a decode step; a prompt
        already at cache capacity likewise never decodes into a full
        cache."""
        if (len(req.tokens) >= req.max_tokens
                or len(req.prompt) >= self.cache_len - 1):
            req.done = True
            finished.append(req)
            return True
        return False

    # -- schedulers ---------------------------------------------------------
    def run(self, max_steps: int = 512) -> list[Request]:
        """Processes the queue to completion (or step limit)."""
        if self.scheduler == "continuous":
            return self._run_continuous(max_steps)
        return self._run_slot(max_steps)

    def _admit_free_slots(self, finished: list) -> list[int]:
        """Drain the submission topic into free slots; returns the slots
        admitted this tick (prefill done, first token emitted)."""
        free = [i for i in range(self.slots) if self.active[i] is None]
        admitted = []
        if not free:
            return admitted
        for i, req in zip(free, self.admission.poll(len(free))):
            self.log.log(req.rid, "wait", req.t_submit, time.perf_counter())
            self._degrade_clamp(req)
            cache, _ = self._prefill_one(req)
            if self._finished_early(req, finished):
                continue
            self.active[i] = req
            admitted.append((i, cache))
        return admitted

    def _run_continuous(self, max_steps: int) -> list[Request]:
        """One jitted ragged decode step per tick over all occupied
        slots; admissions prefill into freed slots between ticks."""
        finished: list[Request] = []
        steps = 0
        while (any(self.active) or not self._pending.empty()) \
                and steps < max_steps:
            self._degrade_tick()
            for i, cache in self._admit_free_slots(finished):
                req = self.active[i]
                if self._blocks is None:
                    self._blocks = self.model.init_cache(
                        self.slots, self.cache_len)["blocks"]
                slot = jnp.asarray(i, jnp.int32)
                self.log.log_transfer(req.rid, "h2d", int(slot.nbytes),
                                      "admit")
                # device-side row insert: resident slots' rows untouched
                self._blocks = self._insert(self._blocks, cache["blocks"],
                                            slot)
                self._kv_len[i] = len(req.prompt)
                self._last_tok[i] = req.tokens[-1]
            idx = [i for i in range(self.slots)
                   if self.active[i] is not None]
            if idx:
                rids = [self.active[i].rid for i in idx]
                t0 = time.perf_counter()
                packed = jnp.asarray(
                    np.stack([self._last_tok, self._kv_len]))
                out, self._blocks = self._decode_batch(
                    self.params, self._blocks, packed)
                jax.block_until_ready(out)
                t1 = time.perf_counter()
                out_host = np.asarray(out)       # the ONE d2h per tick
                self.d2h_syncs += 1
                self.d2h_bytes += int(out_host.nbytes)
                self.log.log_batch_span(rids, "decode", t0, t1)
                # boundary bytes, padding (idle lanes) included: the
                # whole slot vector crosses in one batched transfer
                self.log.log_batch_transfers(
                    rids, "decode", h2d=int(packed.nbytes),
                    d2h=int(out_host.nbytes), t=t0)
                nxt = out_host if self.fast_path else out_host.argmax(-1)
                for i in idx:
                    req = self.active[i]
                    tok_i = int(nxt[i])
                    req.tokens.append(tok_i)
                    self._last_tok[i] = tok_i
                    self._kv_len[i] += 1
                    if (len(req.tokens) >= req.max_tokens
                            or self._kv_len[i] >= self.cache_len - 1):
                        # leave at a token boundary: the slot's rows stay
                        # in the cache, masked out by kv_len=0 until a
                        # new admission overwrites them
                        req.done = True
                        finished.append(req)
                        self.active[i] = None
                        self._kv_len[i] = 0
                        self._last_tok[i] = 0
            steps += 1
        return finished

    def _run_slot(self, max_steps: int) -> list[Request]:
        """Baseline scheduler: one jitted decode call per slot per token
        (per-token host round-trips — what continuous batching removes).
        Cache occupancy is tracked host-side; the device is only read
        for token values, and every such read is on the ledger."""
        finished: list[Request] = []
        caches: list = [None] * self.slots
        occ = [0] * self.slots       # host-side cur_len mirror: no d2h read
        steps = 0
        while (any(self.active) or not self._pending.empty()) \
                and steps < max_steps:
            self._degrade_tick()
            for i, cache in self._admit_free_slots(finished):
                caches[i] = cache
                occ[i] = len(self.active[i].prompt)
            # lock-step decode over occupied slots
            for i, req in enumerate(self.active):
                if req is None:
                    continue
                t0 = time.perf_counter()
                tok = jnp.asarray([[req.tokens[-1]]], jnp.int32)
                self.log.log_transfer(req.rid, "h2d", int(tok.nbytes),
                                      "decode")
                if self.fast_path:
                    nxt_dev, caches[i] = self._decode(self.params, caches[i],
                                                      tok)
                    jax.block_until_ready(nxt_dev)
                    self.log.log(req.rid, "decode", t0, time.perf_counter())
                    self.d2h_syncs += 1
                    self.d2h_bytes += int(nxt_dev.nbytes)
                    self.log.log_transfer(req.rid, "d2h",
                                          int(nxt_dev.nbytes), "decode")
                    nxt = int(nxt_dev)
                else:
                    logits, caches[i] = self._decode(self.params, caches[i],
                                                     tok)
                    jax.block_until_ready(logits)
                    self.log.log(req.rid, "decode", t0, time.perf_counter())
                    row = np.asarray(logits[0])
                    self.d2h_syncs += 1
                    self.d2h_bytes += int(row.nbytes)
                    self.log.log_transfer(req.rid, "d2h", int(row.nbytes),
                                          "decode")
                    nxt = int(np.argmax(row))
                req.tokens.append(nxt)
                occ[i] += 1
                if len(req.tokens) >= req.max_tokens \
                        or occ[i] >= self.cache_len - 1:
                    req.done = True
                    finished.append(req)
                    self.active[i] = None
                    caches[i] = None
                    occ[i] = 0
            steps += 1
        return finished

    def tax_report(self) -> dict:
        return self.log.ai_tax(ai_stages={"prefill", "decode"})

    def ttft_samples(self) -> list[float]:
        """Per-request time-to-first-token (submit -> prefill token),
        for every request that produced one."""
        # finished or still-resident requests both carry t_first
        seen = {}
        for ev in self.log.events:
            if ev.stage == "prefill":
                seen[ev.request_id] = ev.t_end
        subs = {}
        for ev in self.log.events:
            if ev.stage == "wait":
                subs[ev.request_id] = ev.t_start
        return [t - subs[rid] for rid, t in seen.items() if rid in subs]

    def latency_report(self, slo: TailSLO | None = None,
                       ) -> tuple[LatencyStats, SLOReport | None]:
        """Per-request e2e (submit -> last decode) tail percentiles.

        Same LatencyStats/TailSLO machinery as the serving cluster, so
        a single engine and an N-replica deployment report their tails
        in the same vocabulary. Rejected requests count toward the SLO
        drop-fraction bound, not the latency distribution.
        """
        e2e = self.log.end_to_end(
            stages=["wait", "prefill", "decode"])
        stats = LatencyStats.from_samples(e2e)
        offered = stats.n + self.rejected
        drop_fraction = self.rejected / offered if offered else 0.0
        return stats, (slo.check(stats, drop_fraction)
                       if slo is not None else None)
