"""Batched serving engine: continuous-batching decode over a KV cache.

Production concerns covered at container scale:
  * request queue with admission to fixed batch slots (continuous
    batching: a finished slot is refilled on the next step, no global
    drain);
  * prefill-on-admit, decode in lock-step across slots;
  * per-request AI-tax events (queue wait, prefill, per-token decode) via
    the same EventLog as the paper's pipeline;
  * straggler mitigation hook: slots exceeding ``max_tokens`` are evicted.

The engine is model-agnostic: any ``repro.models.model.Model`` works. On
the container it runs tiny configs on CPU; the step functions are the
same ones the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.batching import Batcher
from repro.core.events import EventLog
from repro.core.metrics import LatencyStats, SLOReport, TailSLO


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_tokens: int = 16
    t_submit: float = 0.0
    tokens: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, *, batch_slots: int = 4,
                 cache_len: int = 128, greedy: bool = True,
                 fast_path: bool = True, max_queue: int | None = None,
                 degrade=None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.cache_len = cache_len
        self.log = EventLog()
        # graceful degradation (duck-typed DegradePolicy, same ladder
        # as the serving cluster): under queue pressure, admitted
        # requests get max_tokens clamped by the current level's
        # service_factor — shorter generations shed work before
        # admission control sheds requests — with the accuracy cost
        # logged as a zero-span "degrade" event per clamped request
        self.degrade = degrade
        self._deg_depth = 0
        self.degrade_timeline: list[tuple[float, int, str]] = []
        # admission bound: submissions beyond max_queue pending requests
        # are rejected at the door (logged as zero-span "reject" events,
        # so ai_tax()/latency_report() see the shed load); None = accept
        # everything and let queue wait absorb the pressure
        self.max_queue = max_queue
        self.rejected = 0
        self._admit_lock = threading.Lock()   # atomic check-then-put
        # admission shares the streaming pipeline's Batcher: submissions
        # land on a topic-like queue and are drained non-blocking into
        # whatever slots are free each scheduler step
        self._pending: queue.Queue = queue.Queue()
        self.admission = Batcher(self._pending, batch_size=batch_slots,
                                 timeout_s=0.0)
        self.active: list[Request | None] = [None] * batch_slots
        self.greedy = greedy
        # fast_path: greedy token selection is fused into the jitted
        # decode program, so one int32 crosses device->host per token;
        # the unfused path fetches the full logit row and argmaxes on
        # the host (the classic glue-code pattern the paper taxes)
        self.fast_path = fast_path
        if fast_path:
            def _decode_fused(params, cache, tokens):
                logits, cache = model.decode_step(params, cache, tokens)
                return jnp.argmax(logits.reshape(-1)).astype(jnp.int32), cache
            self._decode = jax.jit(_decode_fused)
        else:
            self._decode = jax.jit(model.decode_step)

    def submit(self, req: Request) -> bool:
        """Queue a request; False when admission control sheds it."""
        req.t_submit = time.perf_counter()
        with self._admit_lock:
            if (self.max_queue is not None
                    and self._pending.qsize() >= self.max_queue):
                self.rejected += 1
                reject = True
            else:
                self._pending.put(req)
                reject = False
        if reject:
            self.log.log(req.rid, "reject", req.t_submit, req.t_submit,
                         int(req.prompt.nbytes))
        return not reject

    @property
    def queue_depth(self) -> int:
        return self._pending.qsize()

    # -- single-sequence prefill per admit; decode batched over slots ------
    def _prefill_one(self, req: Request):
        t0 = time.perf_counter()
        tokens = jnp.asarray(req.prompt[None, :])
        self.log.log_transfer(req.rid, "h2d", int(tokens.nbytes), "prefill")
        logits, cache = self.model.prefill(self.params, {"tokens": tokens},
                                           cache_len=self.cache_len)
        jax.block_until_ready(logits)
        self.log.log(req.rid, "prefill", t0, time.perf_counter(),
                     int(req.prompt.nbytes))
        if self.fast_path:
            # argmax on device; only the winning index crosses
            idx = jnp.argmax(logits[0])
            self.log.log_transfer(req.rid, "d2h", int(idx.nbytes), "prefill")
            nxt = int(idx)
        else:
            row = np.asarray(logits[0])
            self.log.log_transfer(req.rid, "d2h", int(row.nbytes), "prefill")
            nxt = int(np.argmax(row))
        req.tokens.append(nxt)
        return cache, nxt

    def run(self, max_steps: int = 512) -> list[Request]:
        """Processes the queue to completion (or step limit)."""
        finished: list[Request] = []
        caches: list = [None] * self.slots
        steps = 0
        while (any(self.active) or not self._pending.empty()) \
                and steps < max_steps:
            # degradation ladder: queue depth per slot is the engine's
            # per-replica backlog analogue (no breakers here, so the
            # open fraction input is 0)
            if self.degrade is not None:
                depth = self.degrade.decide(
                    self.queue_depth / max(self.slots, 1), 0.0,
                    self._deg_depth)
                if depth != self._deg_depth:
                    self._deg_depth = depth
                    self.degrade_timeline.append(
                        (time.perf_counter(), depth,
                         self.degrade.level(depth).name))
            # admit: drain the submission topic into free slots
            free = [i for i in range(self.slots) if self.active[i] is None]
            if free:
                for i, req in zip(free, self.admission.poll(len(free))):
                    self.log.log(req.rid, "wait", req.t_submit,
                                 time.perf_counter())
                    if self.degrade is not None and self._deg_depth > 0:
                        lvl = self.degrade.level(self._deg_depth)
                        cap = max(1, int(req.max_tokens
                                         * lvl.service_factor))
                        if cap < req.max_tokens:
                            req.max_tokens = cap
                            t = time.perf_counter()
                            self.log.log(req.rid, "degrade", t, t,
                                         accuracy_proxy=lvl.accuracy_proxy,
                                         level=lvl.name)
                    caches[i], _ = self._prefill_one(req)
                    self.active[i] = req
            # lock-step decode over occupied slots
            for i, req in enumerate(self.active):
                if req is None:
                    continue
                t0 = time.perf_counter()
                tok = jnp.asarray([[req.tokens[-1]]], jnp.int32)
                self.log.log_transfer(req.rid, "h2d", int(tok.nbytes),
                                      "decode")
                if self.fast_path:
                    nxt_dev, caches[i] = self._decode(self.params, caches[i],
                                                      tok)
                    jax.block_until_ready(nxt_dev)
                    self.log.log(req.rid, "decode", t0, time.perf_counter())
                    self.log.log_transfer(req.rid, "d2h",
                                          int(nxt_dev.nbytes), "decode")
                    nxt = int(nxt_dev)
                else:
                    logits, caches[i] = self._decode(self.params, caches[i],
                                                     tok)
                    jax.block_until_ready(logits)
                    self.log.log(req.rid, "decode", t0, time.perf_counter())
                    row = np.asarray(logits[0])
                    self.log.log_transfer(req.rid, "d2h", int(row.nbytes),
                                          "decode")
                    nxt = int(np.argmax(row))
                req.tokens.append(nxt)
                at_cap = int(caches[i]["cur_len"]) >= self.cache_len - 1
                if len(req.tokens) >= req.max_tokens or at_cap:
                    req.done = True
                    finished.append(req)
                    self.active[i] = None
                    caches[i] = None
            steps += 1
        return finished

    def tax_report(self) -> dict:
        return self.log.ai_tax(ai_stages={"prefill", "decode"})

    def latency_report(self, slo: TailSLO | None = None,
                       ) -> tuple[LatencyStats, SLOReport | None]:
        """Per-request e2e (submit -> last decode) tail percentiles.

        Same LatencyStats/TailSLO machinery as the serving cluster, so
        a single engine and an N-replica deployment report their tails
        in the same vocabulary. Rejected requests count toward the SLO
        drop-fraction bound, not the latency distribution.
        """
        e2e = self.log.end_to_end(
            stages=["wait", "prefill", "decode"])
        stats = LatencyStats.from_samples(e2e)
        offered = stats.n + self.rejected
        drop_fraction = self.rejected / offered if offered else 0.0
        return stats, (slo.check(stats, drop_fraction)
                       if slo is not None else None)
