"""Paper Tables 3/4 + §7.3: TCO of homogeneous vs purpose-built edge data
center. Paper: equipment $33,577,760 vs $27,878,431; purpose-built yearly
TCO ~16.6% lower while supporting 32x accelerated AI."""
from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.tco import (
    TCOComparison, homogeneous_design, paper_comparison, purpose_built_design,
)


def run() -> list[str]:
    out = []
    h1, us = timed(homogeneous_design, 1024, 1)
    out.append(row("tab3/homogeneous_equipment", us,
                   f"ours=${h1.equipment_cost:,.0f};paper=$33,577,760"))
    p, us = timed(purpose_built_design)
    out.append(row("tab4/purpose_built_equipment", us,
                   f"ours=${p.equipment_cost:,.0f};paper=$27,878,431"))
    c32 = paper_comparison(support_32x=True)
    out.append(row("sec7/tco_saving_vs_32x_homogeneous", 0.0,
                   f"saving={c32.saving_fraction:.3f};paper>0.15"))
    cbase = TCOComparison(homogeneous_design(1024, 1), purpose_built_design())
    out.append(row("sec7/tco_saving_vs_base_homogeneous", 0.0,
                   f"saving={cbase.saving_fraction:.3f};paper=0.166"))
    out.append(row("sec7/yearly_tco_homogeneous", 0.0,
                   f"ours=${cbase.homogeneous.yearly_tco/1e6:.1f}M;paper=$12.9M"))
    out.append(row("sec7/yearly_tco_purpose_built", 0.0,
                   f"ours=${cbase.purpose_built.yearly_tco/1e6:.1f}M;paper=$10.8M"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
