"""Shared benchmark plumbing: timing, CSV row helpers, BENCH recorder."""
from __future__ import annotations

import json
import pathlib
import time

# Repo-root file the cluster benchmarks merge their gateable scalars
# into; ``scripts/bench_diff.py`` compares the working tree's copy
# against HEAD's so perf/recovery regressions show up in review.
BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_cluster.json"

# Serving-engine benchmarks (decode batching) keep their scalars in a
# sibling file; ``bench_diff`` globs every BENCH_*.json so both are
# compared against HEAD the same way.
BENCH_SERVE_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serve.json"


def timed(fn, *args, repeat: int = 1, **kw):
    """Returns (result, us_per_call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"


class BenchRecorder:
    """Accumulates one benchmark's scalar metrics and merges them into
    the committed ``BENCH_cluster.json``.

    Each metric carries its own regression policy:
      * ``better`` — "higher"/"lower" for direction-aware gating, or
        ``None`` for informational values diffed but never gated;
      * ``tol``   — relative drift allowed in the bad direction before
        ``bench_diff`` fails;
      * ``gate``  — set ``False`` for noisy values (live-cluster
        timings on a shared box) that should be visible in diffs but
        must not block CI.

    Sections are stamped with the ``mode`` they ran under (smoke/full);
    the differ only compares sections whose modes match, so a local
    full run never gets graded against CI's smoke baseline.
    """

    def __init__(self, section: str, mode: str = "full",
                 path: pathlib.Path | str | None = None):
        self.section = section
        self.mode = mode
        self.path = pathlib.Path(path) if path else BENCH_PATH
        self.metrics: dict[str, dict] = {}

    def record(self, name: str, value, better: str | None = None,
               tol: float = 0.25, gate: bool = True) -> None:
        if better not in (None, "higher", "lower"):
            raise ValueError(f"better must be higher/lower/None: {better!r}")
        self.metrics[name] = {
            "value": round(float(value), 6),
            "better": better,
            "tol": tol,
            "gate": bool(gate and better is not None),
        }

    def flush(self) -> pathlib.Path:
        data = {}
        if self.path.exists():
            data = json.loads(self.path.read_text())
        data[self.section] = {"mode": self.mode, "metrics": self.metrics}
        self.path.write_text(json.dumps(data, indent=2, sort_keys=True)
                             + "\n")
        return self.path
