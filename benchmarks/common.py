"""Shared benchmark plumbing: timing + CSV row helpers."""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 1, **kw):
    """Returns (result, us_per_call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
