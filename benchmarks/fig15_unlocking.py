"""Paper Fig 15: unlocking higher speedups — (a) more drives per broker,
(b) more brokers, (c) smaller thumbnails. Reported as the max stable
acceleration factor per configuration (paper: drives 1/2/3/4 ->
<8x/12x/24x/32x; brokers 3->8 raise the limit monotonically; thumbnail
halving roughly doubles it)."""
from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.broker import BrokerConfig
from repro.core.queueing import max_stable_speedup
from repro.core.simulator import FaceRecWorkload

PAPER_DRIVES = {1: "<8", 2: "12", 3: "24", 4: "32"}


def run() -> list[str]:
    out = []
    wl = FaceRecWorkload()
    for d in (1, 2, 3, 4):
        s, us = timed(max_stable_speedup, wl,
                      BrokerConfig(drives_per_broker=d))
        out.append(row(f"fig15a/drives{d}", us,
                       f"max_stable={s:.1f};paper_unlocks={PAPER_DRIVES[d]}"))
    for n in (3, 4, 6, 8):
        s, us = timed(max_stable_speedup, wl, BrokerConfig(n_brokers=n))
        out.append(row(f"fig15b/brokers{n}", us, f"max_stable={s:.1f}"))
    for frac in (1.0, 0.5, 0.25, 0.125):
        s, us = timed(max_stable_speedup,
                      FaceRecWorkload(face_bytes=37_300 * frac),
                      BrokerConfig())
        out.append(row(f"fig15c/face_x{frac}", us, f"max_stable={s:.1f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
