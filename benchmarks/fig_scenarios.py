"""Scenario library replay: every workload shape is a gated twin test.

Each library scenario (diurnal cycle, flash crowd, skewed camera
fleet, burst/drain duty cycle) resolves to ONE versioned trace that
drives BOTH execution engines — the DES replays it event-by-event,
the live cluster replays it through real threads on a compressed wall
clock. Three gates per scenario, all RuntimeError on failure:

  * signature — the DES run must exhibit the shape's expected stress
    (flash crowd spikes queue tax, skewed heat opens only the hot
    partition's breaker, ...): a scenario that stops stressing what it
    claims to stress is a broken fixture, not a soft regression;
  * twin      — live-vs-DES windowed p99 AND five-way tax fractions
    agree at ``DES_TOL`` on every heartbeat window both engines
    populate (``crossval.twin_compare``), and both engines emit the
    same heartbeat grid;
  * cache     — the second twin pass for the same (spec, trace) pair
    must be served from the ``TwinCache`` (the modeled half runs once
    per spec revision; the recurring cost is one live run).

Full mode adds the per-scenario replay knee: the smallest speedup S
at which the trace replays stably in the DES. Gateable scalars land
in ``BENCH_cluster.json`` (section ``scenarios``) for
``scripts/bench_diff.py``; ``--smoke`` is the CI entry point
(``make scenarios-smoke``) — same code paths, same horizon (the live
half is wall-clock bound at ~1.5s per scenario either way).
"""
from __future__ import annotations

import argparse

from benchmarks.common import BenchRecorder, row, timed
from repro.cluster.crossval import (DES_TOL, TwinCache, scenario_knee,
                                    twin_compare)
from repro.cluster.scenarios import SCENARIOS, scenario_spec


def _signature_row(name: str, rec: BenchRecorder) -> str:
    """DES run + the scenario's own stress-signature check."""
    spec = scenario_spec(name)
    trace = spec.resolve_trace()
    sim = spec.des_sim(speedup=1.0, sim_time=spec.sim_time, warmup=0.0)
    res, us = timed(sim.run)
    problems = SCENARIOS[name].check(sim, res, trace)
    if problems:
        raise RuntimeError(
            f"scenario {name!r} lost its stress signature "
            f"({SCENARIOS[name].signature}): " + "; ".join(problems))
    rec.record(f"{name}.n_events", trace.n_events, better=None)
    rec.record(f"{name}.offered_rate", trace.offered_rate, better=None)
    return row(
        f"{name}/signature", us,
        f"events={trace.n_events};rate={trace.offered_rate:.1f}/s;"
        f"hash={trace.trace_hash()};diverged={res.diverged};ok=True")


def _twin_row(name: str, cache: TwinCache, rec: BenchRecorder) -> str:
    """Live-vs-DES twin gate over the heartbeat windows."""
    spec = scenario_spec(name)
    rep, us = timed(twin_compare, spec, cache)
    if not rep.agree:
        rows = "; ".join(w.row() for w in rep.windows if not w.agree)
        raise RuntimeError(
            f"scenario {name!r} failed the twin gate at DES_TOL="
            f"{DES_TOL}: {rows or 'fewer than 2 comparable windows'}")
    rec.record(f"{name}.twin_p_err", rep.worst_p_err, better="lower",
               tol=1.0, gate=False)        # live: diffable, not CI-gating
    rec.record(f"{name}.twin_tax_diff", rep.worst_tax_diff,
               better="lower", tol=1.0, gate=False)
    return row(f"{name}/twin", us, rep.row())


def run(smoke: bool = False) -> list[str]:
    rec = BenchRecorder("scenarios", mode="smoke" if smoke else "full")
    cache = TwinCache()
    out = []
    for name in SCENARIOS:
        out.append(_signature_row(name, rec))
        out.append(_twin_row(name, cache, rec))
    if cache.hits:
        raise RuntimeError("TwinCache hit during first passes — cache "
                           "keys are colliding across scenarios")

    # second pass for one scenario: the DES half must come from cache
    rep2, us = timed(twin_compare, scenario_spec("diurnal"), cache)
    if not rep2.cached:
        raise RuntimeError("second twin pass re-ran the DES: TwinCache "
                           "key (spec hash, trace hash) is unstable")
    if not rep2.agree:
        raise RuntimeError("cached twin pass disagrees: " + rep2.row())
    out.append(row("diurnal/twin_cached", us,
                   rep2.row() + f";hits={cache.hits}"))

    if not smoke:
        for name in SCENARIOS:
            knee, us = timed(scenario_knee, scenario_spec(name), iters=4)
            rec.record(f"{name}.replay_knee", knee, better=None)
            out.append(row(f"{name}/knee", us, f"min_stable_S={knee:.2f}"))
    rec.flush()
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (skips the replay-knee sweep)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke)))
