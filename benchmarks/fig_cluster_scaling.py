"""Cluster scaling & tail latency: measured vs modeled (paper §5.3-5.5
at deployment scale). Three sections:

  * ``cluster/…`` — live multi-replica runs across acceleration S:
    p50/p95/p99 tail latency, throughput, and measured broker-storage /
    consumer utilization printed next to the closed-form rho — the
    per-point overlay;
  * ``knee/…``    — the headline closed loop: live cluster, DES, and
    closed-form queueing each locate the destabilizing S for
    (replicas × drives) configurations, with agreement within the
    tolerances documented in ``repro.cluster.crossval``;
  * ``tco/…``     — the DES-measured knees per drive count feed
    ``tco.measured_comparison``, so the Tables 3/4 purpose-built
    comparison is provisioned from executed measurements instead of
    the paper's "4 drives supports 32x" constant.

``--smoke`` shrinks runs/iterations for CI; same code paths throughout.
"""
from __future__ import annotations

import argparse

from benchmarks.common import BenchRecorder, row, timed
from repro.cluster.cluster import ClusterSpec, ServingCluster
from repro.cluster.crossval import DES_TOL, LIVE_TOL, des_knee, knee_comparison
from repro.core import tco
from repro.core.broker import BrokerConfig


def _live_rows(smoke: bool, rec: BenchRecorder) -> list[str]:
    out = []
    speedups = (4.0,) if smoke else (1.0, 4.0, 6.0, 9.0)
    sim_time = 3.0 if smoke else 6.0
    for s in speedups:
        spec = ClusterSpec(speedup=s, sim_time=sim_time, warmup=1.0)
        res, us = timed(ServingCluster(spec).run)
        # live numbers are diffable but never CI-gating (shared box)
        rec.record(f"live.S{s:g}.p99_s", res.latency.p99, better="lower",
                   gate=False)
        rec.record(f"live.S{s:g}.throughput", res.throughput,
                   better="higher", gate=False)
        out.append(row(
            f"cluster/R{spec.n_replicas}_d1_S{s:g}", us,
            f"p50_ms={res.latency.p50*1e3:.0f};"
            f"p95_ms={res.latency.p95*1e3:.0f};"
            f"p99_ms={res.latency.p99*1e3:.0f};"
            f"thr={res.throughput:.0f}/s;"
            f"store_util={res.utilization['broker_storage_write']:.2f};"
            f"store_rho={res.predicted_rho['broker_storage_write']:.2f};"
            f"cons_util={res.utilization['consumers']:.2f};"
            f"cons_rho={res.predicted_rho['consumers']:.2f};"
            f"diverged={res.diverged}"))
    return out


def _knee_rows(smoke: bool, rec: BenchRecorder) -> list[str]:
    out = []
    configs = ((1, 8),) if smoke else ((1, 8), (2, 10))
    for drives, replicas in configs:
        spec = ClusterSpec(bk=BrokerConfig(drives_per_broker=drives),
                           n_replicas=replicas,
                           sim_time=4.0 if smoke else 6.0)
        cmp_, us = timed(knee_comparison, spec,
                         des_iters=4 if smoke else 6,
                         live_iters=2 if smoke else 4)
        rec.record(f"knee.R{replicas}_d{drives}.des", cmp_.des,
                   better="higher", tol=DES_TOL)
        rec.record(f"knee.R{replicas}_d{drives}.live", cmp_.live,
                   better="higher", gate=False)
        out.append(row(f"knee/{cmp_.row().split(':')[0]}", us,
                       cmp_.row().split(":", 1)[1]
                       + f";tol_des={DES_TOL};tol_live={LIVE_TOL}"))
    return out


def _tco_rows(smoke: bool, rec: BenchRecorder) -> list[str]:
    drives = (1, 2) if smoke else (1, 2, 3, 4)
    target = 12.0 if smoke else 32.0
    knees = {}
    for d in drives:
        spec = ClusterSpec(bk=BrokerConfig(drives_per_broker=d))
        knees[d], _ = timed(des_knee, spec,
                            iters=4 if smoke else 6,
                            sim_time=10.0 if smoke else 20.0)
        # a knee that disagrees with the closed form by more than the
        # documented tolerance is a measurement failure (e.g. an
        # unreached bisection bracket), not an input to provisioning
        closed = spec.closed_form_knee()
        if abs(knees[d] - closed) / closed > DES_TOL:
            raise RuntimeError(
                f"DES knee {knees[d]:.2f} for drives={d} fails the "
                f"{DES_TOL:.0%} cross-validation gate (closed form "
                f"{closed:.2f}); refusing to provision TCO from it")
    # 5% margin = the bisection's knee-detection resolution (documented
    # in tco.provision_drives): the paper's 32x sits exactly ON the
    # 4-drive knee, so reading the measurement needs its error bar
    d = tco.provision_drives(target, knees, tolerance=0.05)
    comp, us = timed(tco.measured_comparison, target, knees, tolerance=0.05)
    out = [row("tco/measured_knees", 0.0,
               ";".join(f"d{k}={v:.1f}" for k, v in sorted(knees.items()))
               + f";target_S={target:g}")]
    derived = (f"drives={d};"
               f"equipment=${comp.homogeneous.equipment_cost:,.0f};"
               f"saving={comp.saving_fraction:.3f}")
    if not smoke:
        paper = tco.paper_comparison(support_32x=True)
        match = (comp.homogeneous.equipment_cost
                 == paper.homogeneous.equipment_cost)
        derived += (f";paper_equipment="
                    f"${paper.homogeneous.equipment_cost:,.0f};"
                    f"matches_paper={match}")
    out.append(row("tco/measured_provisioning", us, derived))
    for k, v in knees.items():
        rec.record(f"tco.knee_d{k}", v, better="higher", tol=DES_TOL)
    rec.record("tco.saving_fraction", comp.saving_fraction,
               better="higher", tol=0.10)
    return out


def run(smoke: bool = False) -> list[str]:
    rec = BenchRecorder("cluster_scaling", mode="smoke" if smoke else "full")
    out = (_live_rows(smoke, rec) + _knee_rows(smoke, rec)
           + _tco_rows(smoke, rec))
    rec.flush()
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized runs (fewer configs, shorter windows)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke)))
