"""Paper Fig 6: end-to-end frame latency breakdown (real video stats:
0.64 faces/frame, spiky). Paper: ingestion 18.8ms, detection 74.8ms,
broker wait 126.1ms (>33%), identification 131.5ms; e2e 351ms."""
from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.broker import BrokerConfig
from repro.core.simulator import ClusterSim, FaceRecWorkload

PAPER = {"ingest": 0.0188, "detect": 0.0748, "wait": 0.1261,
         "identify": 0.1315}


def run() -> list[str]:
    wl = FaceRecWorkload(face_dist="empirical", faces_per_frame=0.64)
    sim = ClusterSim(wl, BrokerConfig(), speedup=1, scale=0.04,
                     sim_time=25, warmup=6)
    res, us = timed(sim.run)
    bd = res.stage_means
    out = []
    for stage in ("ingest", "detect", "wait", "identify"):
        ours = bd.get(stage, 0.0)
        out.append(row(f"fig06/{stage}", us,
                       f"ours_ms={ours*1e3:.1f};paper_ms={PAPER[stage]*1e3:.1f}"))
    e2e = res.mean_latency
    out.append(row("fig06/e2e", us,
                   f"ours_ms={e2e*1e3:.1f};paper_ms=351;"
                   f"wait_share={res.waiting_share:.2f};paper_share>0.33"))
    out.append(row("fig06/p99", us, f"ours_ms={res.p99_latency*1e3:.0f};"
                   "paper_ms=2210"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
