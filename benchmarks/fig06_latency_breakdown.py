"""Paper Fig 6: end-to-end frame latency breakdown (real video stats:
0.64 faces/frame, spiky). Paper: ingestion 18.8ms, detection 74.8ms,
broker wait 126.1ms (>33%), identification 131.5ms; e2e 351ms.

Stage rows are sourced from the measured event log through the shared
five-way attribution (``repro.core.events.five_way_fractions`` +
``facerec.stage_category``) — the same machinery the live pipeline and
``TaxedStep`` report through — so this figure can never drift from the
stages the system actually executes. Paper milliseconds are attached
where the paper states them."""
from __future__ import annotations

from benchmarks.common import row, timed
from repro.core import facerec
from repro.core.broker import BrokerConfig
from repro.core.events import FIVE_WAY, five_way_fractions
from repro.core.simulator import ClusterSim, FaceRecWorkload

PAPER_MS = {"ingest": 18.8, "detect": 74.8, "wait": 126.1,
            "identify": 131.5}


def run() -> list[str]:
    wl = FaceRecWorkload(face_dist="empirical", faces_per_frame=0.64)
    sim = ClusterSim(wl, BrokerConfig(), speedup=1, scale=0.04,
                     sim_time=25, warmup=6)
    res, us = timed(sim.run)
    bd = res.stage_means
    cat = {s: facerec.stage_category(s) for s in bd}
    order = {c: i for i, c in enumerate(FIVE_WAY)}
    out = []
    for stage in sorted(bd, key=lambda s: (order[cat[s]], s)):
        derived = f"ours_ms={bd[stage]*1e3:.1f};cat={cat[stage]}"
        if stage in PAPER_MS:
            derived += f";paper_ms={PAPER_MS[stage]:.1f}"
        out.append(row(f"fig06/{stage}", us, derived))
    fr = five_way_fractions(bd, facerec.stage_category)
    out.append(row("fig06/fractions", us,
                   ";".join(f"{c}={fr[c]:.3f}" for c in FIVE_WAY)))
    e2e = res.mean_latency
    out.append(row("fig06/e2e", us,
                   f"ours_ms={e2e*1e3:.1f};paper_ms=351;"
                   f"wait_share={res.waiting_share:.2f};paper_share>0.33"))
    out.append(row("fig06/p99", us, f"ours_ms={res.p99_latency*1e3:.0f};"
                   "paper_ms=2210"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
